"""Reproduce the paper's headline analysis in one command.

    PYTHONPATH=src python examples/coaxial_study.py

Prints the Fig 5 / Fig 7 / Fig 8 / Table 5 headline numbers next to the
paper's reported values, plus the TPU-side channelized-decode plan the
framework derives from the same queueing insight.
"""

from repro.core import coaxial, cpu_model, planner


PAPER = {
    "coaxial-4x": 1.52, "coaxial-2x": 1.26, "coaxial-asym": 1.67,
    "50ns": 1.33, "edp": 0.72,
}


def main():
    print(f"{'metric':34s} {'paper':>8s} {'ours':>8s}")
    # One batched sweep solves every (design, latency, core-count) cell.
    sw = coaxial.default_sweep()
    c4 = sw.comparison(coaxial.COAXIAL_4X)
    c2 = sw.comparison(coaxial.COAXIAL_2X)
    ca = sw.comparison(coaxial.COAXIAL_ASYM)
    c50 = sw.comparison(coaxial.COAXIAL_4X, iface_lat=50.0)
    edp = coaxial.edp_report(coaxial.COAXIAL_4X, cmp=c4)
    rows = [
        ("geomean speedup, COAXIAL-4x", PAPER["coaxial-4x"],
         c4.geomean_speedup),
        ("geomean speedup, COAXIAL-2x", PAPER["coaxial-2x"],
         c2.geomean_speedup),
        ("geomean speedup, COAXIAL-asym", PAPER["coaxial-asym"],
         ca.geomean_speedup),
        ("geomean speedup @50ns premium", PAPER["50ns"],
         c50.geomean_speedup),
        ("EDP ratio (Table 5)", PAPER["edp"], edp["edp_ratio"]),
    ]
    for name, paper, ours in rows:
        print(f"{name:34s} {paper:8.2f} {ours:8.2f}")
    print()
    lbm = c4.row("lbm")
    print(f"lbm: {lbm['base_latency_ns']:.0f}ns -> {lbm['latency_ns']:.0f}ns, "
          f"speedup {lbm['speedup']:.2f}x (paper: ~3x, queuing-dominated)")

    # Beyond the paper: a named-axis sweep (every design x LLC capacities,
    # one XLA trace) reduced to its area/speedup Pareto frontier, and the
    # gradient of the same differentiable model at COAXIAL-4x.
    spec = coaxial.sweep_spec(design=coaxial.all_designs(),
                              llc_mb_per_core=(0.5, 1.0, 2.0, 4.0))
    front = coaxial.solve_spec(spec).pareto(cost="rel_area")
    best = front[-1]
    print(f"\npareto frontier (designs x LLC, {len(front)} points): best "
          f"{best['design']}@{best['llc_mb_per_core']:g}MB/core = "
          f"{best['geomean_speedup']:.2f}x at {best['rel_area']:.2f}x area")
    g = coaxial.design_gradient(
        coaxial.COAXIAL_4X, ("dram_channels", "llc_mb_per_core",
                             "iface_lat_ns"))
    print("d(geomean speedup)/d(field) at coaxial-4x: " +
          ", ".join(f"{k}={v:+.4f}" for k, v in g.items()))

    plan = planner.plan_decode_kv(
        kv_bytes=8 * 32768 * 8 * 128 * 2 * 2 * 88,   # mistral-large decode
        qkv_flops=4 * 88 * 8 * 32768 * 96 * 128,
        combine_bytes=88 * 8 * 96 * 130 * 4)
    print(f"TPU channelized decode (mistral-large 32k): "
          f"{plan.n_channels} KV channels -> {plan.speedup:.1f}x predicted")


if __name__ == "__main__":
    main()
