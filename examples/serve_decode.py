"""Serving example: batched prefill + greedy decode across architectures,
including the attention-free (RWKV6) and hybrid (Zamba2) families whose
O(1)-state decode is what the long_500k dry-run cell exercises.

    PYTHONPATH=src python examples/serve_decode.py
"""

import sys

sys.argv = [sys.argv[0]]
from repro.launch import serve


def main():
    for arch in ("stablelm-1.6b", "rwkv6-1.6b", "zamba2-2.7b"):
        print(f"=== {arch} ===")
        serve.main(["--arch", arch, "--smoke", "--batch", "2",
                    "--prompt-len", "32", "--gen", "8"])


if __name__ == "__main__":
    main()
