"""Quickstart: train a tiny LM for 100 steps on CPU and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py

Uses the same public API the production launcher uses: config registry ->
Model -> sharded train step -> synthetic data pipeline.
"""

import sys

sys.argv = [sys.argv[0]]
from repro.launch import train


def main():
    losses = train.main([
        "--arch", "stablelm-1.6b", "--smoke", "--steps", "100",
        "--batch", "8", "--seq", "64", "--lr", "5e-3",
    ])
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"quickstart OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
