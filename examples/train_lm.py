"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with checkpointing and crash-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

This is the (b)-deliverable end-to-end example: a real (non-smoke) config
family -- stablelm-1.6b scaled to ~110M by depth/width so CPU finishes in
minutes -- full FSDP sharding rules, AdamW, async checkpoints, restart.
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import PrefetchIterator, SyntheticDataset
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-lm")
    args = ap.parse_args()

    # ~110M params: stablelm family, 8 layers x 768 wide, 16k vocab.
    import repro.configs.stablelm_1_6b as base
    cfg = dataclasses.replace(
        base.CONFIG, name="stablelm-110m", n_layers=8, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=2048, vocab=16384,
        dtype="float32")

    # Register-free path: drive the launcher internals directly.
    from repro.distributed.step import (TrainStepConfig, init_train_state,
                                        make_train_step)
    from repro.optim.adamw import AdamWConfig
    from repro.models.model import Model

    model = Model(cfg)
    step_cfg = TrainStepConfig(opt=AdamWConfig(
        lr=3e-4, total_steps=args.steps, warmup_steps=20),
        param_dtype=cfg.dtype)
    state = init_train_state(model, jax.random.PRNGKey(0), step_cfg)
    step = jax.jit(make_train_step(model, step_cfg), donate_argnums=(0,))

    ds = SyntheticDataset(cfg, batch=2, seq=128)
    it = PrefetchIterator(ds)
    print(f"[train_lm] {cfg.name}: {model.param_count():,} params")
    try:
        for _ in range(args.steps):
            n, batch = next(it)
            state, metrics = step(state, batch)
            if n % 20 == 0:
                print(f"[train_lm] step {n:4d} loss {float(metrics['loss']):.4f}")
    finally:
        it.close()
    print(f"[train_lm] final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
