"""Fig 5: COAXIAL-4x vs DDR baseline -- the paper's main result.

Paper: 1.52x geomean, lbm ~3x, 10/35 above 2x, 4 regressions (gcc worst).

Sliced from the shared :func:`coaxial.default_sweep` grid -- the whole
fig5/7/8/9 + table5 report costs one XLA compile.
"""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    us, sw = time_call(coaxial.default_sweep, warmup=0, iters=1)
    cmp = sw.comparison(coaxial.COAXIAL_4X)
    for i, n in enumerate(cmp.names):
        emit(f"fig5.{n}.speedup", us / len(cmp.names),
             f"{cmp.speedup[i]:.3f}")
    s = cmp.summary()
    emit("fig5.geomean_speedup", us, f"{cmp.geomean_speedup:.3f}")
    emit("fig5.n_above_2x", 0.0, cmp.n_above_2x)
    emit("fig5.n_regressions", 0.0, cmp.n_regressions)
    emit("fig5.queue_share", 0.0, f"{s['queue_share_of_latency']:.3f}")
    emit("fig5.mean_queue_base_ns", 0.0, f"{s['mean_base_queue_ns']:.1f}")
    emit("fig5.mean_queue_coax_ns", 0.0, f"{s['mean_queue_ns']:.1f}")


if __name__ == "__main__":
    main()
