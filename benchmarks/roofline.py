"""§Roofline: the three roofline terms per (arch x shape) cell.

Reads the dry-run artifacts (results/dryrun/*.json: per-chip HLO flops,
bytes, parsed collective bytes) and derives, per cell:

    compute term    = HLO_FLOPs / (chips * 197e12)
    memory term     = HLO_bytes / (chips * 819e9)
    collective term = collective_bytes / (chips * 50e9)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE; 2*N*D for inference-shape
cells, which run forward-only) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs.

Note: ``cost_analysis`` on an SPMD module reports per-chip values, so the
numerator is already per-chip and the formulas divide by one chip's peaks;
the two conventions agree (both numerator and denominator drop the x chips).
"""

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import get_config, get_shape
from repro.core import hw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Whole-job useful FLOPs for the cell, per chip (to match HLO flops)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    if shape.kind == "train":
        per_token = 6 * n
        tokens = shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        per_token = 2 * n
        tokens = shape.seq_len * shape.global_batch
    else:  # decode: one token per sequence
        per_token = 2 * n
        tokens = shape.global_batch
    return per_token * tokens


def load_cells(mesh: str = "16x16", variant: str = "baseline"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if (cell.get("mesh") == mesh and
                cell.get("variant", "baseline") == variant):
            cells.append(cell)
    return cells


def analyze(cell: dict) -> dict | None:
    if cell["status"] != "ok":
        return None
    chips = cell["chips"]
    # All numerators are per chip (parsed from the per-partition HLO with
    # loop-trip scaling).  The memory term uses the fused-boundary proxy
    # (hbm_bytes) when present; bytes_per_chip (all-op boundary) is the
    # unfused upper bound kept for reference.
    compute_s = cell["flops_per_chip"] / hw.TPU_PEAK_FLOPS
    hbm = cell.get("hbm_bytes_per_chip", 0.0) or cell["bytes_per_chip"]
    memory_s = hbm / hw.TPU_HBM_BW
    coll_s = cell["collectives"]["total"] / hw.TPU_ICI_BW_PER_LINK
    mf = model_flops(cell["arch"], cell["shape"]) / chips
    terms = dict(
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bound_s=max(compute_s, memory_s, coll_s),
        model_flops_per_chip=mf,
        useful_ratio=mf / cell["flops_per_chip"]
        if cell["flops_per_chip"] else 0.0,
        mfu_bound=mf / hw.TPU_PEAK_FLOPS /
        max(compute_s, memory_s, coll_s, 1e-30),
    )
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    return terms


def main():
    cells = load_cells()
    if not cells:
        emit("roofline.no_dryrun_artifacts", 0.0, "run repro.launch.dryrun")
        return
    for cell in cells:
        key = f"roofline.{cell['arch']}.{cell['shape']}"
        t = analyze(cell)
        if t is None:
            emit(key + ".status", 0.0, cell["status"].split(":")[0])
            continue
        emit(key + ".compute_ms", 0.0, f"{t['compute_s']*1e3:.3f}")
        emit(key + ".memory_ms", 0.0, f"{t['memory_s']*1e3:.3f}")
        emit(key + ".collective_ms", 0.0, f"{t['collective_s']*1e3:.3f}")
        emit(key + ".dominant", 0.0, t["dominant"].replace("_s", ""))
        emit(key + ".useful_ratio", 0.0, f"{t['useful_ratio']:.3f}")
        emit(key + ".roofline_fraction", 0.0, f"{t['mfu_bound']:.3f}")


if __name__ == "__main__":
    main()
