"""LUT store economics + grid convergence: the resolution endgame rows.

Three experiments on the persistent QueueLUT store
(:mod:`repro.core.lutstore`):

1. **Cold vs warm build** -- the default surface is built directly (one
   batched DES run, ``lut.build_cold_s``) and then re-resolved through
   the store (``lut.build_warm_s``); with ``$REPRO_LUT_CACHE`` set the
   warm read is a file load, bit-identical to the build
   (``lut.store_bitident``) and free of DES traces
   (``lut.warm_traces``).  The cold/warm pair feeds the BENCH
   trajectory: store regressions show up as the warm row drifting
   toward the cold one.

2. **Grid ladder** -- stride-coarsened versions of the default grids are
   resolved INCREMENTALLY off the full surface (every coarse cell is
   donated, zero DES) and judged two ways: interpolation error against
   one batched direct-DES probe run at interval midpoints, and
   fixed-point drift of the two headline metrics (fig7 geomean speedup,
   wave-model token p99) against the full-grid surface.

3. **Adaptive refinement** -- :func:`repro.core.queuelut.
   refine_queue_lut` from the coarse grids, reported round by round; the
   final round's metric deltas are the ISSUE's convergence criterion
   (< 1% on the last refinement step).  ``report --section lut`` renders
   the same trajectory as markdown.

All DES work honours ``REPRO_DES_STEPS``/``REPRO_DES_ENGINE``; because
this module resolves the SAME default-surface key the drift / harvest /
designer / serving sections use, running it first in ``benchmarks.run``
warms the in-process layer (and the store) for everything after it.
"""

import time

import numpy as np

from benchmarks.common import des_budget, des_engine, emit, emit_derived
from repro.core import hw, lutstore, memsim, queuelut

#: Grid-ladder strides over the default grids.  Stride 2 is plain
#: ``g[::2]`` -- the refinement loop's starting grids, so its surface is
#: shared (same store key); stride 4 keeps each axis's endpoints so the
#: hull does not shrink.
LADDER_STRIDES = (4, 2)


def _coarsen(grid: tuple, stride: int) -> tuple:
    if stride == 2:
        return tuple(grid[::2])
    sub = list(grid[::stride])
    if sub[-1] != grid[-1]:
        sub.append(grid[-1])
    return tuple(sub)


def ladder_grids(stride: int) -> dict:
    """Stride-coarsened default grids (stride 1 = the default surface)."""
    g = dict(rho=queuelut.DEFAULT_RHO_GRID,
             kappa=queuelut.DEFAULT_KAPPA_GRID,
             outstanding=queuelut.DEFAULT_OUTSTANDING_GRID,
             eta=queuelut.DEFAULT_ETA_GRID)
    if stride == 1:
        return g
    return {k: _coarsen(v, stride) for k, v in g.items()}


def bench_budget() -> tuple:
    """(steps, engine) of the shared bench default surface."""
    engine = des_engine(queuelut.DEFAULT_ENGINE)
    return des_budget(queuelut.DEFAULT_STEPS, engine), engine


def cold_warm() -> dict:
    """Cold direct build vs store-backed warm resolution of the default
    surface; returns the row dict (times s, traces, bit-identity)."""
    steps, engine = bench_budget()
    t0 = time.perf_counter()
    cold = queuelut.build_queue_lut(steps=steps, engine=engine)
    cold_s = time.perf_counter() - t0
    # Resolve through the store: persists the surface on first contact.
    queuelut.default_queue_lut(steps=steps, engine=engine)
    queuelut.clear_lut_cache()
    n0 = memsim.sim_trace_count()
    t0 = time.perf_counter()
    warm = queuelut.default_queue_lut(steps=steps, engine=engine)
    warm_s = time.perf_counter() - t0
    warm_traces = memsim.sim_trace_count() - n0
    bitident = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(cold, warm) if a is not None)
    return dict(cold_s=cold_s, warm_s=warm_s, warm_traces=warm_traces,
                bitident=bitident, lut=warm)


def _probe_points(stride: int = 2) -> list:
    """Interval-midpoint probes (anchored off-axis) over the default
    grids -- every ``stride``-th interval per axis, to bound the direct
    DES probe batch."""
    pts = []
    for axis, grid in ladder_grids(1).items():
        for j in range(0, len(grid) - 1, stride):
            c = dict(queuelut.PROBE_ANCHOR)
            c.pop("harvest_duty")
            c[axis] = queuelut._midpoint(axis, grid[j], grid[j + 1])
            pts.append(c)
    return pts


def ladder_rows(finest: "queuelut.QueueLUT", steps: int,
                engine: str) -> list:
    """One row per rung: cells, interpolation error vs direct DES,
    fixed-point drift of both headline metrics vs the finest surface."""
    probes = _probe_points()
    names = ("rho", "kappa", "outstanding", "eta")
    coords = np.asarray([[p[n] for n in names] for p in probes])
    cha = memsim.stack_channels(
        [memsim.ChannelConfig(**p) for p in probes])
    stats = memsim.simulate_cells(
        cha, steps=int(steps), seed=0, reps=queuelut.DEFAULT_REPS,
        engine=engine,
        stream_ids=queuelut.cell_stream_ids(names, coords),
        chunk=memsim.canonical_chunk(engine))
    des_wait = np.maximum(
        np.asarray(stats.mean_ns, np.float64) - hw.DRAM_SERVICE_NS,
        0.0)
    ref = queuelut.headline_metrics(finest)
    rows = []
    for stride in LADDER_STRIDES + (1,):
        lut = (finest if stride == 1 else queuelut.resolve_lut(
            **ladder_grids(stride), steps=steps, engine=engine,
            base_lut=finest))       # all cells donated: zero DES
        lut_wait = np.asarray([float(lut.wait(
            p["rho"], p["kappa"], p["outstanding"], p["eta"]))
            for p in probes])
        # Same normalization as refine_queue_lut: relative to the total
        # access latency the solver consumes.
        err = (np.abs(lut_wait - des_wait)
               / (des_wait + hw.DRAM_SERVICE_NS))
        m = queuelut.headline_metrics(lut)
        rows.append(dict(
            stride=stride,
            cells=int(np.prod([len(g) for g in
                               ladder_grids(stride).values()])),
            interp_err_max=float(err.max()),
            interp_err_mean=float(err.mean()),
            gm=m["geomean_speedup"], tok99_ms=m["token_p99_ms"],
            gm_drift_pct=100.0 * (m["geomean_speedup"]
                                  / ref["geomean_speedup"] - 1.0),
            tok99_drift_pct=100.0 * (m["token_p99_ms"]
                                     / ref["token_p99_ms"] - 1.0)))
    return rows


def refine_history(steps: int, engine: str) -> list:
    """The adaptive loop's round-by-round trajectory (ISSUE criterion:
    final-step metric deltas < 1%)."""
    _, hist = queuelut.refine_queue_lut(steps=steps, engine=engine,
                                        tol=0.01)
    return hist


def main():
    steps, engine = bench_budget()
    root = lutstore.cache_dir()
    emit_derived("lut.store", "disabled" if root is None else "enabled")
    cw = cold_warm()
    emit("lut.build_cold_s", cw["cold_s"] * 1e6, f"{cw['cold_s']:.3f}")
    emit("lut.build_warm_s", cw["warm_s"] * 1e6, f"{cw['warm_s']:.3f}")
    emit_derived("lut.warm_traces", cw["warm_traces"])
    emit_derived("lut.store_bitident", int(cw["bitident"]))
    for r in ladder_rows(cw["lut"], steps, engine):
        tag = f"lut.ladder.s{r['stride']}"
        emit_derived(f"{tag}.cells", r["cells"])
        emit_derived(f"{tag}.interp_err_max", f"{r['interp_err_max']:.4f}")
        emit_derived(f"{tag}.gm_drift_pct", f"{r['gm_drift_pct']:+.2f}")
        emit_derived(f"{tag}.tok99_drift_pct",
                     f"{r['tok99_drift_pct']:+.2f}")
    hist = refine_history(steps, engine)
    for r in hist:
        extra = ("" if "d_geomean" not in r else
                 f"|d_gm={r['d_geomean']:.4f}|d_p99={r['d_token_p99']:.4f}")
        emit_derived(
            f"lut.refine.round{r['round']}",
            f"cells={r['cells']}|gm={r['geomean_speedup']:.4f}"
            f"|tok99={r['token_p99_ms']:.1f}ms"
            f"|err={r['worst_err']:.3f}{extra}")
    final = hist[-1]
    emit_derived("lut.refine.final_d_gm_pct",
                 f"{100.0 * final.get('d_geomean', 0.0):.3f}")
    emit_derived("lut.refine.final_d_tok99_pct",
                 f"{100.0 * final.get('d_token_p99', 0.0):.3f}")
    emit_derived("lut.refine.converged", int(final["converged"]))


if __name__ == "__main__":
    main()
