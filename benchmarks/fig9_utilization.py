"""Fig 9: speedup vs active cores (8% / 33% / 66% / 100% utilization).

Paper: -17% at 1 core; 1.27x at 8 cores; 1.52x at 12."""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    for n in (1, 4, 8, 12):
        us, cmp = time_call(
            lambda c=n: coaxial.evaluate(coaxial.COAXIAL_4X, n_active=c),
            iters=1)
        emit(f"fig9.cores{n}.geomean_speedup", us,
             f"{cmp.geomean_speedup:.3f}")


if __name__ == "__main__":
    main()
