"""Fig 9: speedup vs active cores (8% / 33% / 66% / 100% utilization).

Paper: -17% at 1 core; 1.27x at 8 cores; 1.52x at 12.  The core-count axis
is one dimension of the shared sweep grid.
"""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    us, sw = time_call(coaxial.default_sweep, warmup=0, iters=1)
    for n in sw.cores:
        cmp = sw.comparison(coaxial.COAXIAL_4X, n_active=n)
        emit(f"fig9.cores{n}.geomean_speedup", us,
             f"{cmp.geomean_speedup:.3f}")
        us = 0.0


if __name__ == "__main__":
    main()
