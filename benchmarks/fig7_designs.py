"""Fig 7: design points -- COAXIAL-2x / 4x / asym (+5x iso-pin).

Paper geomeans: 1.26 / 1.52 / 1.67."""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    for sys in (coaxial.COAXIAL_2X, coaxial.COAXIAL_4X, coaxial.COAXIAL_5X,
                coaxial.COAXIAL_ASYM):
        us, cmp = time_call(lambda s=sys: coaxial.evaluate(s), iters=1)
        emit(f"fig7.{sys.name}.geomean_speedup", us,
             f"{cmp.geomean_speedup:.3f}")


if __name__ == "__main__":
    main()
