"""Fig 7: design points -- every registered design vs the DDR baseline.

Paper geomeans: 1.26 (2x) / 1.52 (4x) / 1.67 (asym).  All slices of the one
shared sweep; registry additions show up here automatically.
"""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    us, sw = time_call(coaxial.default_sweep, warmup=0, iters=1)
    for sys in sw.designs:
        if sys.name == sw.baseline_name:
            continue
        cmp = sw.comparison(sys)
        emit(f"fig7.{sys.name}.geomean_speedup", us,
             f"{cmp.geomean_speedup:.3f}")
        us = 0.0


if __name__ == "__main__":
    main()
