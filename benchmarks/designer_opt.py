"""The gradient-based designer as a benchmark section (repro.core.designer).

Runs one end-to-end optimize: start at the tail-aware Pareto knee,
projected-gradient-ascend (channels, LLC) under the default area budget
and the serving SLO, re-verify the optimum with one direct event-driven
DES run.  Emits the returned design, its cost/speedup/tail numbers, the
model-vs-DES verification error, and the one-trace invariant -- the
rows CI's trajectory diff watches for ascent-quality drift.  The LUT
build honors ``REPRO_DES_STEPS`` like every other DES-backed section.
"""

from benchmarks.common import des_budget, des_engine, emit, emit_derived, \
    time_call
from repro.core import designer, queuelut

AREA_BUDGET = 1.2
SLO_MS = 500.0
ARCH = "stablelm-1.6b"


def main():
    engine = des_engine("event")
    steps = des_budget(queuelut.DEFAULT_STEPS, engine)
    us, res = time_call(
        lambda: designer.optimize_design(
            area_budget=AREA_BUDGET, slo_ms=SLO_MS, arch=ARCH,
            steps=steps, engine=engine),
        warmup=0, iters=1)
    emit("designer.optimize", us, res.iters)
    d = res.design
    emit_derived("designer.start", f"{res.start.name}@"
                 f"{res.start.llc_mb_per_core:g}MB")
    emit_derived("designer.opt.channels",
                 f"{float(d.dram_channels):.3f}")
    emit_derived("designer.opt.llc_mb", f"{float(d.llc_mb_per_core):.3f}")
    emit_derived("designer.opt.rel_area", f"{res.rel_area:.3f}")
    emit_derived("designer.opt.gm_speedup", f"{res.gm_speedup:.3f}")
    emit_derived("designer.opt.token_p99_ms", f"{res.token_p99_ms:.2f}")
    emit_derived("designer.meets", int(res.meets_budget and res.meets_slo))
    emit_derived("designer.converged", int(res.converged))
    emit_derived("designer.verify.rel_err",
                 f"{res.verify['rel_err']:+.4f}")
    emit_derived("designer.verify.ok", int(res.verify["ok"]))
    emit_derived("designer.traces", designer.designer_trace_count())


if __name__ == "__main__":
    main()
