"""Beyond-paper: the planner's channelized-KV decode trade (DESIGN.md SS3).

COAXIAL's Fig-2a argument on TPU: spreading a 32k-token KV cache over N
chips' HBM vs paying the flash-decode combine premium.  Derived column:
predicted decode-step speedup at the planner's chosen channel count."""

from benchmarks.common import emit, emit_derived, time_call
from repro.configs import get_config
from repro.core import planner


def main():
    for arch, batch_per_chip in [("mistral-large-123b", 8),
                                 ("stablelm-1.6b", 8),
                                 ("qwen2-vl-72b", 8)]:
        cfg = get_config(arch)
        hd = cfg.resolved_head_dim
        s = 32768
        kv_bytes = (2 * cfg.n_layers * batch_per_chip * s *
                    cfg.n_kv_heads * hd * 2)
        qkv_flops = 4 * cfg.n_layers * batch_per_chip * s * \
            cfg.n_heads * hd
        combine_bytes = (cfg.n_layers * batch_per_chip * cfg.n_heads *
                         (hd + 2) * 4)
        us, plan = time_call(lambda kb=kv_bytes, qf=qkv_flops,
                             cb=combine_bytes: planner.plan_decode_kv(
                                 kv_bytes=kb, qkv_flops=qf,
                                 combine_bytes=cb), iters=1)
        emit(f"channelized.{arch}.n_channels", us, plan.n_channels)
        emit_derived(f"channelized.{arch}.speedup", f"{plan.speedup:.2f}")
        emit_derived(f"channelized.{arch}.baseline_us",
                     f"{plan.baseline.total_s * 1e6:.1f}")
        emit_derived(f"channelized.{arch}.step_us",
                     f"{plan.cost.total_s * 1e6:.1f}")


if __name__ == "__main__":
    main()
