"""STREAM Pallas kernels (paper SS5 workloads): interpret-mode correctness
timing + modeled TPU roofline fractions.

On CPU the us_per_call column is interpret-mode overhead (not TPU time);
the derived column reports the bytes each call would move and the fraction
of the 819 GB/s HBM roofline the kernel's access pattern sustains by
construction (pure streaming => 1.0 modeled)."""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import hw
from repro.kernels import ops
from repro.kernels.stream import stream_bytes


def main():
    shape = (2048, 512)
    a = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    for name, fn in [
        ("copy", lambda: ops.stream_copy(a)),
        ("scale", lambda: ops.stream_scale(a, 2.0)),
        ("add", lambda: ops.stream_add(a, b)),
        ("triad", lambda: ops.stream_triad(a, b, 2.0)),
    ]:
        us, _ = time_call(fn, iters=1)
        nbytes = stream_bytes(name, shape)
        t_roof_us = nbytes / hw.TPU_HBM_BW * 1e6
        emit(f"stream.{name}.bytes", us, nbytes)
        emit(f"stream.{name}.tpu_roofline_us", 0.0, f"{t_roof_us:.2f}")


if __name__ == "__main__":
    main()
