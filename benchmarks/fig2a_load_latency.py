"""Fig 2a: DDR5-4800 load-latency curve -- parametric model vs DES memsim.

Paper anchors: 3x average latency at 50% load, 4x at 60%; p90 4.7x / 7.1x.

Both curves come out of ONE batched distribution sweep
(``coaxial.validate_calibration``), which also cross-checks the DES
against the closed form; the per-anchor deltas are emitted as
``fig2a.crosscheck.*`` rows so calibration drift surfaces in the CI
report.  ``REPRO_DES_ENGINE=event`` (the CI smoke setting) runs the
sweep on the per-request event engine, which raises the effective
sample count at unchanged CI time; the engine used is emitted as a row.
"""

from benchmarks.common import des_engine, des_steps, emit, time_call
from repro.core import coaxial, queueing


def main():
    rhos = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
    steps = des_steps(200_000)
    engine = des_engine()
    us, val = time_call(
        lambda: coaxial.validate_calibration(
            rhos=rhos, steps=steps, engine=engine,
            reps=max(2, min(64, 9_600_000 // steps))),
        iters=1)
    per = us / len(rhos)
    emit("fig2a.engine", 0.0, engine)
    for a in val["anchors"]:
        r = a["rho"]
        emit(f"fig2a.rho{r:.1f}.param_mean_ns", per,
             f"{a['closed_mean_ns']:.1f}")
        emit(f"fig2a.rho{r:.1f}.des_mean_ns", per, f"{a['des_mean_ns']:.1f}")
        emit(f"fig2a.rho{r:.1f}.param_p90_ns", per,
             f"{a['closed_p90_ns']:.1f}")
        emit(f"fig2a.rho{r:.1f}.des_p90_ns", per, f"{a['des_p90_ns']:.1f}")
    # Cross-check rows: param-vs-DES relative deltas per anchor (percent).
    for a in val["anchors"]:
        r = a["rho"]
        emit(f"fig2a.crosscheck.rho{r:.1f}.mean_delta_pct", 0.0,
             f"{100.0 * a['mean_err']:.1f}")
        emit(f"fig2a.crosscheck.rho{r:.1f}.p90_delta_pct", 0.0,
             f"{100.0 * a['p90_err']:.1f}")
        emit(f"fig2a.crosscheck.rho{r:.1f}.stdev_delta_pct", 0.0,
             f"{100.0 * a['stdev_err']:.1f}")
    emit("fig2a.crosscheck.max_abs_mean_err_pct", 0.0,
         f"{100.0 * val['max_abs_mean_err']:.1f}")
    emit("fig2a.crosscheck.max_abs_p90_err_pct", 0.0,
         f"{100.0 * val['max_abs_p90_err']:.1f}")
    # NOTE: this bench sweeps rho up to 0.9 for the curve; the gated
    # cross-check envelope (val["ok"]) covers rho <= 0.8, so only the
    # per-anchor and max-delta rows are emitted here -- the gate itself
    # is enforced at the validated anchors in tests.
    emit("fig2a.crosscheck.max_abs_stdev_err_pct", 0.0,
         f"{100.0 * val['max_abs_stdev_err']:.1f}")
    emit("fig2a.anchor.3x_at_50pct", 0.0,
         f"{float(queueing.avg_latency_ns(0.5)) / 40.0:.2f}")
    emit("fig2a.anchor.4x_at_60pct", 0.0,
         f"{float(queueing.avg_latency_ns(0.6)) / 40.0:.2f}")


if __name__ == "__main__":
    main()
