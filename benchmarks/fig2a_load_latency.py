"""Fig 2a: DDR5-4800 load-latency curve -- parametric model vs DES memsim.

Paper anchors: 3x average latency at 50% load, 4x at 60%; p90 4.7x / 7.1x.
"""

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import memsim, queueing


def main():
    rhos = np.array([0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9])
    us, curve = time_call(
        lambda: memsim.load_latency_curve(rhos=rhos, steps=120_000), iters=1)
    for i, r in enumerate(rhos):
        par = float(queueing.avg_latency_ns(r))
        p90 = float(queueing.p90_latency_ns(r))
        emit(f"fig2a.rho{r:.1f}.param_mean_ns", us / len(rhos), f"{par:.1f}")
        emit(f"fig2a.rho{r:.1f}.des_mean_ns", us / len(rhos),
             f"{curve['mean_ns'][i]:.1f}")
        emit(f"fig2a.rho{r:.1f}.param_p90_ns", us / len(rhos), f"{p90:.1f}")
        emit(f"fig2a.rho{r:.1f}.des_p90_ns", us / len(rhos),
             f"{curve['p90_ns'][i]:.1f}")
    emit("fig2a.anchor.3x_at_50pct", 0.0,
         f"{float(queueing.avg_latency_ns(0.5)) / 40.0:.2f}")
    emit("fig2a.anchor.4x_at_60pct", 0.0,
         f"{float(queueing.avg_latency_ns(0.6)) / 40.0:.2f}")


if __name__ == "__main__":
    main()
