"""Idle-I/O harvesting headline: the duty x channels frontier + drift.

The arXiv 2511.12349 experiment: CXL I/O links sit idle most of the time,
and while idle they can be lent to the memory pool.  The DES models the
loan as a two-state (lent / reclaimed) modulation riding the same MMPP
lattice as the burst chain -- while lent, each request's enqueued work
shrinks by ``base_bw / (base_bw + harvest_bw)``.  This benchmark sweeps
the loan's two knobs against channel count:

* **Frontier**: a fixed offered load is spread over 1/2/4 channels while
  one lendable x8 link's worth of bandwidth (``hw.DDR5_CH_BW_GBPS``) is
  split across them, at lent-time duties 0..0.75.  Each cell's queuing
  delay (DES mean / p99 minus the unloaded service floor, simulated in
  the same batch) is compared against its duty=0 twin.  The paper
  reports a 1.52x mean and ~3x max queuing-delay reduction; the frontier
  row pins where this repro lands.
* **Drift**: the closed-form backend has no harvest law (it ignores the
  design's ``harvest_duty``/``harvest_bw_gbps`` entirely), so solving a
  harvesting coaxial-4x through both backends measures how much headline
  the closed form forfeits -- same shape as ``drift_headline``, one row.

``REPRO_DES_STEPS`` caps both the frontier cells and the drift LUT build
for CI smoke; ``REPRO_DES_ENGINE`` picks the engine.
"""

import dataclasses

import numpy as np

from benchmarks.common import des_budget, des_engine, emit, emit_derived, \
    time_call
from repro.core import coaxial, hw, memsim, queuelut

#: Lent-time fractions on the frontier (0 is the unharvested twin).
DUTY_GRID = (0.0, 0.25, 0.5, 0.75)
#: Channel counts the fixed offered load is spread across.
CHANNELS = (1, 2, 4)
#: Bus utilization the offered traffic drives on a SINGLE channel.
OFFERED_RHO = 0.85
#: Total in-flight population (split across channels with the load).
OUT_TOTAL = 96.0
#: Within-epoch burstiness (serving-like, not Poisson).
KAPPA = 1.8
#: One lendable CXL x8 link's worth of bandwidth, split across channels.
HARVEST_BW_GBPS = hw.DDR5_CH_BW_GBPS
#: Near-idle cell simulated in the same batch: the unloaded service
#: floor subtracted from every mean/p99 to isolate the QUEUING delay.
FLOOR_RHO = 0.02


def frontier_configs() -> list:
    """The duty x channels grid plus the trailing floor cell."""
    cfgs = []
    for ch in CHANNELS:
        for duty in DUTY_GRID:
            cfgs.append(memsim.ChannelConfig(
                rho=OFFERED_RHO / ch, kappa=KAPPA,
                outstanding=OUT_TOTAL / ch,
                harvest_duty=duty,
                harvest_bw_gbps=HARVEST_BW_GBPS / ch))
    cfgs.append(memsim.ChannelConfig(rho=FLOOR_RHO))
    return cfgs


def frontier_sim(steps: int | None = None, engine: str | None = None,
                 reps: int = 4) -> "memsim.LatencyStats":
    """One batched DES run over the whole frontier (+ floor cell).

    ``reps`` independent replicas per cell merge into one histogram --
    at CI smoke budgets the queuing-delay differences (tens of ns) would
    otherwise drown in single-replica sampling noise.
    """
    engine = engine or des_engine("event")
    steps = steps or des_budget(200_000, engine)
    return memsim.simulate(frontier_configs(), steps=steps, seed=0,
                           reps=reps, engine=engine)


def frontier_rows(stats) -> list[dict]:
    """One row per harvested cell: queuing delay vs its duty=0 twin."""
    n_d = len(DUTY_GRID)
    floor_mean = float(stats.mean_ns[-1])
    floor_p99 = float(stats.p99_ns[-1])

    def q(i, field, floor):
        # Queuing delay, floored at one histogram bin so a near-empty
        # queue cannot inflate a reduction ratio to infinity.
        return max(float(getattr(stats, field)[i]) - floor, stats.bin_ns)

    rows = []
    for c, ch in enumerate(CHANNELS):
        i0 = c * n_d + DUTY_GRID.index(0.0)
        for d, duty in enumerate(DUTY_GRID):
            if duty == 0.0:
                continue
            i = c * n_d + d
            rows.append(dict(
                channels=ch, duty=duty,
                q_mean0_ns=q(i0, "mean_ns", floor_mean),
                q_mean_ns=q(i, "mean_ns", floor_mean),
                q_p990_ns=q(i0, "p99_ns", floor_p99),
                q_p99_ns=q(i, "p99_ns", floor_p99)))
    for r in rows:
        r["mean_reduction"] = r["q_mean0_ns"] / r["q_mean_ns"]
        r["p99_reduction"] = r["q_p990_ns"] / r["q_p99_ns"]
    return rows


def headline(rows) -> dict:
    """Geomean + max queuing-delay reduction over the frontier -- the
    numbers to hold against 2511.12349's 1.52x mean / ~3x max."""
    mean_r = np.array([r["mean_reduction"] for r in rows])
    p99_r = np.array([r["p99_reduction"] for r in rows])
    return dict(
        reduction_gm=float(np.exp(np.mean(np.log(mean_r)))),
        reduction_max=float(max(mean_r.max(), p99_r.max())))


def drift_row(steps: int | None = None,
              engine: str | None = None) -> dict:
    """Harvesting coaxial-4x through both queue backends.

    The closed form ignores the harvest fields, so its geomean speedup is
    exactly the unharvested design's -- the drift IS the harvest headline
    the closed form cannot see.  The memsim backend goes through a 5-D
    QueueLUT built here with a two-point duty grid (the queried duty
    sits on-grid) to keep the smoke build at 2x the 4-D surface.
    """
    engine = engine or des_engine(queuelut.DEFAULT_ENGINE)
    steps = steps or des_budget(queuelut.DEFAULT_STEPS)
    duty = 0.5
    h4x = dataclasses.replace(
        coaxial.COAXIAL_4X, name="coaxial-4x+harvest",
        harvest_duty=duty, harvest_bw_gbps=queuelut.HARVEST_REF_BW_GBPS)
    # Store-backed: with $REPRO_LUT_CACHE warm this two-point-duty
    # surface is a file read, not a DES run.
    lut = queuelut.resolve_lut(steps=steps, engine=engine,
                               harvest=(0.0, duty))
    gm = {}
    for qm in ("closed_form", "memsim"):
        sw = coaxial.sweep(
            (coaxial.DDR_BASELINE, coaxial.COAXIAL_4X, h4x),
            queue_model=qm, lut=lut if qm == "memsim" else None)
        gm[qm] = {d.name: float(sw.comparison(d).geomean_speedup)
                  for d in (coaxial.COAXIAL_4X, h4x)}
    closed, memsim_h = gm["closed_form"][h4x.name], gm["memsim"][h4x.name]
    memsim_plain = gm["memsim"][coaxial.COAXIAL_4X.name]
    return dict(metric="coaxial-4x+harvest.gm_speedup",
                closed=closed, memsim=memsim_h,
                drift_pct=100.0 * (memsim_h / closed - 1.0),
                memsim_plain=memsim_plain,
                gain_pct=100.0 * (memsim_h / memsim_plain - 1.0))


def main():
    us, stats = time_call(frontier_sim, warmup=0, iters=1)
    emit("harvest.cells", us, len(frontier_configs()))
    rows = frontier_rows(stats)
    for r in rows:
        emit_derived(
            f"harvest.frontier.ch{r['channels']}.duty{r['duty']:g}",
            f"q{r['q_mean0_ns']:.0f}->q{r['q_mean_ns']:.0f}ns|"
            f"x{r['mean_reduction']:.2f}|p99 x{r['p99_reduction']:.2f}")
    h = headline(rows)
    emit_derived("harvest.headline.reduction_gm",
                 f"{h['reduction_gm']:.2f}")
    emit_derived("harvest.headline.reduction_max",
                 f"{h['reduction_max']:.2f}")
    emit_derived("harvest.headline.paper_claim",
                 "1.52x mean / ~3x max (arXiv 2511.12349)")
    us, r = time_call(drift_row, warmup=0, iters=1)
    emit(f"harvest.drift.{r['metric']}", us,
         f"{r['closed']:.3f}|{r['memsim']:.3f}|{r['drift_pct']:+.1f}%")
    emit_derived("harvest.gain.coaxial-4x.gm_speedup",
                 f"{r['memsim_plain']:.3f}->{r['memsim']:.3f}|"
                 f"{r['gain_pct']:+.1f}%")


if __name__ == "__main__":
    main()
