"""Fig 2b: per-workload latency breakdown (service vs queuing) and
bandwidth utilization on the DDR baseline."""

from benchmarks.common import emit, time_call
from repro.core import cpu_model


def main():
    us, res = time_call(lambda: cpu_model.solve(cpu_model.DDR_BASELINE),
                        iters=1)
    from repro.core.workloads import NAMES
    for i, n in enumerate(NAMES):
        emit(f"fig2b.{n}.queue_ns", us / len(NAMES),
             f"{res.queue_ns[i]:.1f}")
        emit(f"fig2b.{n}.rho", us / len(NAMES), f"{res.rho[i]:.3f}")
    share = (res.queue_ns / res.latency_ns).mean()
    emit("fig2b.mean_queue_share", us, f"{share:.3f}")


if __name__ == "__main__":
    main()
