"""Table 2: pins / relative area, derived per registered design."""

from benchmarks.common import emit
from repro.core import coaxial


def main():
    pins = coaxial.pin_report()
    emit("table2.bw_per_pin_ratio", 0.0, f"{pins['bw_per_pin_ratio']:.2f}")
    for name, row in coaxial.area_report(coaxial.all_designs()).items():
        emit(f"table2.{name}.rel_area", 0.0, f"{row['rel_area']:.3f}")
        emit(f"table2.{name}.rel_pins", 0.0, f"{row['rel_pins']:.3f}")


if __name__ == "__main__":
    main()
