"""Fig 8: sensitivity to the CXL latency premium (30ns vs 50ns).

Paper: 1.52x -> 1.33x geomean.  Both latency columns live in the shared
sweep grid (the 30ns point is the designs' own default premium).
"""

from benchmarks.common import emit, time_call
from repro.core import coaxial, hw


def main():
    us, sw = time_call(coaxial.default_sweep, warmup=0, iters=1)
    for lat in (hw.CXL_LAT_NS, hw.CXL_LAT_PESSIMISTIC_NS):
        cmp = sw.comparison(coaxial.COAXIAL_4X, iface_lat=lat)
        emit(f"fig8.lat{int(lat)}ns.geomean_speedup", us,
             f"{cmp.geomean_speedup:.3f}")
        emit(f"fig8.lat{int(lat)}ns.n_regressions", 0.0, cmp.n_regressions)
        us = 0.0


if __name__ == "__main__":
    main()
