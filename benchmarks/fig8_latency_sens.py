"""Fig 8: sensitivity to the CXL latency premium (30ns vs 50ns).

Paper: 1.52x -> 1.33x geomean."""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    for lat in (30.0, 50.0):
        us, cmp = time_call(
            lambda l=lat: coaxial.evaluate(coaxial.COAXIAL_4X,
                                           iface_lat_ns=l), iters=1)
        emit(f"fig8.lat{int(lat)}ns.geomean_speedup", us,
             f"{cmp.geomean_speedup:.3f}")
        emit(f"fig8.lat{int(lat)}ns.n_regressions", 0.0, cmp.n_regressions)


if __name__ == "__main__":
    main()
