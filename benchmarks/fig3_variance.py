"""Fig 3: performance under bimodal memory latency (constant 150ns mean).

Paper geomeans: 0.86 / 0.78 / 0.71 for stdev 100 / 150 / 200 ns."""

from benchmarks.common import emit, time_call
from repro.core import cpu_model


def main():
    us, out = time_call(cpu_model.variance_experiment, iters=1)
    for (lo, hi), row in out.items():
        emit(f"fig3.stdev{int(row['stdev_ns'])}.geomean", us / 3,
             f"{row['geomean']:.3f}")


if __name__ == "__main__":
    main()
