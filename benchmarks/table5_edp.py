"""Table 5: power and energy-delay product.

Paper: 713W vs 1180W; EDP ratio 0.72.  Reuses the COAXIAL-4x comparison
already solved by the shared sweep instead of re-running the model.
"""

from benchmarks.common import emit, time_call
from repro.core import coaxial


def main():
    us, rep = time_call(
        lambda: coaxial.edp_report(
            coaxial.COAXIAL_4X,
            cmp=coaxial.default_sweep().comparison(coaxial.COAXIAL_4X)),
        warmup=0, iters=1)
    emit("table5.baseline.total_w", us, f"{rep['baseline']['total_w']:.0f}")
    emit("table5.coaxial.total_w", 0.0, f"{rep['coaxial']['total_w']:.0f}")
    emit("table5.baseline.cpi", 0.0, f"{rep['baseline']['cpi']:.2f}")
    emit("table5.coaxial.cpi", 0.0, f"{rep['coaxial']['cpi']:.2f}")
    emit("table5.edp_ratio", 0.0, f"{rep['edp_ratio']:.3f}")


if __name__ == "__main__":
    main()
