"""Shared benchmark utilities: timing + CSV emission + DES step budget.

Every benchmark prints ``name,us_per_call,derived`` rows; ``derived`` is the
figure/table-relevant quantity (a speedup, a latency, a roofline fraction).
"""

import os
import time

import jax


def des_steps(default: int) -> int:
    """Step budget for DES (memsim) benchmarks.

    ``REPRO_DES_STEPS`` caps the default -- CI smoke sets it low to keep
    the whole benchmark run under a few minutes; it can only shrink the
    budget, so local full runs are unaffected by a stale environment.
    """
    cap = os.environ.get("REPRO_DES_STEPS")
    return min(default, int(cap)) if cap else default


def time_call(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6, out


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
