"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows; ``derived`` is the
figure/table-relevant quantity (a speedup, a latency, a roofline fraction).
"""

import time

import jax


def time_call(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6, out


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
