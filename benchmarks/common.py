"""Shared benchmark utilities: timing + CSV emission + DES budgets.

Every benchmark prints ``name,us_per_call,derived`` rows; ``derived`` is the
figure/table-relevant quantity (a speedup, a latency, a roofline fraction).
"""

import os
import time

import jax

#: When a list, :func:`emit` also records ``(name, us, derived)`` rows --
#: ``run.py`` points this at a per-section buffer to build the versioned
#: ``BENCH_<rev>.json`` trajectory point.
ROWS = None


def enable_compile_cache() -> str | None:
    """Opt-in persistent XLA compile cache (the flywheel's warm start).

    ``REPRO_COMPILE_CACHE`` names a directory; when set, every XLA
    executable this process compiles is written there and later runs with
    the same jaxlib reload it instead of re-tracing through LLVM -- the
    DES chunk kernels dominate benchmark startup, so CI caches the
    directory across runs keyed on the jax version.  Unset (the default)
    leaves compilation exactly as before.  The thresholds are zeroed so
    even the small second-stage kernels are cached.
    """
    path = os.environ.get("REPRO_COMPILE_CACHE", "").strip()
    if not path:
        return None
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return path


def enable_lut_cache() -> str | None:
    """Surface the persistent QueueLUT store (the DES-side warm start).

    ``REPRO_LUT_CACHE`` names a directory; when set, every DES-built
    :class:`repro.core.queuelut.QueueLUT` surface is persisted there and
    later sessions read it back bit-identically instead of re-running
    the simulation (see :mod:`repro.core.lutstore`).  The store is read
    directly by ``queuelut.resolve_lut`` -- this helper only resolves
    (and creates) the directory so ``run.py`` can record it in the
    BENCH trajectory point, mirroring :func:`enable_compile_cache`.
    """
    from repro.core import lutstore
    root = lutstore.cache_dir()
    return None if root is None else str(root)


def _engines() -> tuple:
    """The memsim engines (lazy import: a third engine added to memsim
    is budgetable here without touching this module)."""
    from repro.core import memsim
    return memsim.ENGINES


def des_budget(default: int, engine: str = "timestep") -> int:
    """Per-engine DES budget in simulated ns.

    The budget knob is engine-neutral: ``steps`` means simulated time for
    either engine (``memsim`` converts it to a per-request budget for the
    event engine via ``events_for_steps``), so the single
    ``REPRO_DES_STEPS`` cap throttles BOTH engines coherently -- CI smoke
    sets it low to keep the whole benchmark run under a few minutes; it
    can only shrink the budget, so local full runs are unaffected by a
    stale environment.  ``engine`` is validated so a typo'd engine name
    fails here rather than deep inside a sweep.
    """
    if engine not in _engines():
        raise ValueError(f"unknown engine {engine!r}; choose from "
                         f"{_engines()}")
    cap = os.environ.get("REPRO_DES_STEPS")
    return min(default, int(cap)) if cap else default


def des_steps(default: int) -> int:
    """Legacy alias of :func:`des_budget` (timestep units)."""
    return des_budget(default)


def des_engine(default: str = "timestep") -> str:
    """Engine for DES-driven benchmark sections.

    ``REPRO_DES_ENGINE`` overrides the per-benchmark default -- CI smoke
    sets ``event`` so the DES-heavy sections (the fig2a cross-check, the
    drift LUT build) collect more samples in the same wall-clock.
    """
    engine = os.environ.get("REPRO_DES_ENGINE", default)
    if engine not in _engines():
        raise ValueError(f"REPRO_DES_ENGINE={engine!r} is not an engine; "
                         f"choose from {_engines()}")
    return engine


def time_call(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6, out


def emit(name, us, derived):
    """One CSV row.  ``us=None`` marks a derived (non-timed) row: the
    timing field is left EMPTY in the CSV and null in the bench JSON,
    so the trajectory diff never mistakes "not timed" for "0.0 us"."""
    if us is None:
        print(f"{name},,{derived}")
    else:
        print(f"{name},{us:.1f},{derived}")
    if ROWS is not None:
        ROWS.append((str(name), None if us is None else float(us),
                     str(derived)))


def emit_derived(name, derived):
    """Emit a row that carries a derived quantity but no timing."""
    emit(name, None, derived)
