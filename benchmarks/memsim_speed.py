"""Event vs timestep memsim engine: wall-clock at equal statistical budget.

Times the SAME workloads through both engines -- same grids, same
simulated-ns budget per cell (the event engine converts the shared
``steps`` knob to its per-request budget at the rho = 0.5 reference
rate), same replica counts -- and cross-checks that the results agree, so
the speedup rows are apples to apples:

  * ``memsim_speed.lut.*`` -- the default QueueLUT build grid
    (14 x 6 x 6 x 4 cells x ``DEFAULT_REPS`` replicas, ``DEFAULT_STEPS``
    ns per cell), plus the wait-table agreement between the two builds
    at the nodes with meaningful queueing (>10 ns mean wait);
  * ``memsim_speed.fig2a.*`` -- the ``validate_calibration`` anchor run
    (8 rho anchors x 48 replicas), plus each engine's closed-form anchor
    errors at the timed budget (the pass/fail gates are enforced at full
    budget in tests);
  * ``memsim_speed.curve.*`` -- the 19-point single-channel Fig-2a
    load-latency curve, the narrow-batch shape every interactive /
    test-suite call hits.

The speedup is SHAPE-DEPENDENT on CPU: the per-request engine does
``~t_xfer/rho`` fewer sequential iterations, but the per-nanosecond
engine's step cost is width-elastic (its per-step temporaries stay
cache-resident up to a few hundred lanes), so the ratio is largest for
narrow batches and sample-starved low-rho cells and smallest for very
wide batches where the timestep amortizes its per-step cost across
lanes.  All three shapes are reported so the trade is visible in CI.

On top of the engine-vs-engine rows, ``memsim_speed.shard.*`` times the
SAME three shapes sharded over every local device against the 1-device
path (``repro.core.shardsim``; force more host devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  The sharded
results must be BIT-IDENTICAL to the unsharded ones -- the agreement row
raises on any mismatch, so a sharding regression fails the whole
benchmark run, not just a gate deep in a report.

``REPRO_DES_STEPS`` caps every budget (both engines, coherently);
timings are min-of-``REPRO_SPEED_ITERS`` (default 2) to suppress
noisy-neighbor variance.
"""

import os
import time

import numpy as np

from benchmarks.common import des_budget, emit
from repro.core import coaxial, memsim, queuelut, shardsim


def _best_of(fn, iters, warmed=False):
    out = None if warmed else fn()          # compile / cache warmup
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def main():
    iters = int(os.environ.get("REPRO_SPEED_ITERS", "2"))
    lut_steps = des_budget(queuelut.DEFAULT_STEPS)
    val_steps = des_budget(200_000)
    luts, times = {}, {}

    for eng in memsim.ENGINES:
        # The warmup build doubles as the agreement-table surface (any
        # one seed serves the relative-delta rows), so each engine pays
        # warmup + timed builds and nothing extra.
        luts[eng] = queuelut.build_queue_lut(engine=eng, steps=lut_steps)
        times[eng], _ = _best_of(
            lambda eng=eng: queuelut.build_queue_lut(
                engine=eng, steps=lut_steps, seed=1), iters, warmed=True)
    cells = (len(queuelut.DEFAULT_RHO_GRID) * len(queuelut.DEFAULT_KAPPA_GRID)
             * len(queuelut.DEFAULT_OUTSTANDING_GRID)
             * len(queuelut.DEFAULT_ETA_GRID))
    for eng in memsim.ENGINES:
        emit(f"memsim_speed.lut.{eng}_s", times[eng] * 1e6,
             f"{times[eng]:.2f}")
    emit("memsim_speed.lut.cells", 0.0, cells)
    emit("memsim_speed.lut.speedup", 0.0,
         f"{times['timestep'] / times['event']:.2f}")
    # Anchor accuracy of the two builds against each other: relative
    # wait-table deltas where the queue wait is meaningful.
    tw = np.asarray(luts["timestep"].wait_ns)
    ew = np.asarray(luts["event"].wait_ns)
    mask = tw > 10.0
    rel = np.abs(ew - tw)[mask] / tw[mask]
    emit("memsim_speed.lut.wait_delta_median_pct", 0.0,
         f"{100.0 * float(np.median(rel)):.1f}")
    emit("memsim_speed.lut.wait_delta_p90_pct", 0.0,
         f"{100.0 * float(np.quantile(rel, 0.9)):.1f}")

    vals = {}
    for eng in memsim.ENGINES:
        times[eng], vals[eng] = _best_of(
            lambda eng=eng: coaxial.validate_calibration(
                engine=eng, steps=val_steps, seed=1), iters)
    for eng in memsim.ENGINES:
        v = vals[eng]
        emit(f"memsim_speed.fig2a.{eng}_s", times[eng] * 1e6,
             f"{times[eng]:.2f}")
        # Accuracy at the timed budget (the pass/fail gates are enforced
        # at full budget in tests; smoke budgets legitimately miss them).
        emit(f"memsim_speed.fig2a.{eng}_max_mean_err_pct", 0.0,
             f"{100.0 * v['max_abs_mean_err']:.1f}")
        emit(f"memsim_speed.fig2a.{eng}_max_p90_err_pct", 0.0,
             f"{100.0 * v['max_abs_p90_err']:.1f}")
    emit("memsim_speed.fig2a.speedup", 0.0,
         f"{times['timestep'] / times['event']:.2f}")

    for eng in memsim.ENGINES:
        times[eng], _ = _best_of(
            lambda eng=eng: memsim.load_latency_curve(
                engine=eng, steps=val_steps, reps=1, seed=1), iters)
        emit(f"memsim_speed.curve.{eng}_s", times[eng] * 1e6,
             f"{times[eng]:.2f}")
    emit("memsim_speed.curve.speedup", 0.0,
         f"{times['timestep'] / times['event']:.2f}")

    shard_section(iters, lut_steps, val_steps)


def shard_section(iters, lut_steps, val_steps):
    """Sharded vs unsharded wall-clock on the three canonical shapes,
    with a raising bit-equality gate on every result."""
    ndev = shardsim.resolve_devices("auto")
    eng = queuelut.DEFAULT_ENGINE
    shapes = {
        "lut": lambda d: queuelut.build_queue_lut(
            engine=eng, steps=lut_steps, seed=2, devices=d),
        "fig2a": lambda d: coaxial.validate_calibration(
            engine=eng, steps=val_steps, seed=2, devices=d),
        "curve": lambda d: memsim.load_latency_curve(
            engine=eng, steps=val_steps, reps=1, seed=2, devices=d),
    }
    emit("memsim_speed.shard.devices", 0.0, ndev)
    results = {}
    for label, fn in shapes.items():
        t1, r1 = _best_of(lambda: fn(1), iters)
        tn, rn = _best_of(lambda: fn(ndev), iters)
        results[label] = (r1, rn)
        emit(f"memsim_speed.shard.{label}.base_s", t1 * 1e6, f"{t1:.2f}")
        emit(f"memsim_speed.shard.{label}.sharded_s", tn * 1e6,
             f"{tn:.2f}")
        emit(f"memsim_speed.shard.{label}.speedup", 0.0,
             f"{t1 / tn:.2f}")
    # The hard gate: sharded == unsharded, bitwise.  assert_array_equal
    # raises, run.py records the section as failed, CI goes red.
    l1, ln = results["lut"]
    for t1, tn in zip((l1.wait_ns, l1.p90_wait_ns, l1.sigma_ns),
                      (ln.wait_ns, ln.p90_wait_ns, ln.sigma_ns)):
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(tn))
    v1, vn = results["fig2a"]
    for a1, an in zip(v1["anchors"], vn["anchors"]):
        if a1["des_mean_ns"] != an["des_mean_ns"]:
            raise AssertionError(
                f"sharded fig2a anchor drifted: {a1} != {an}")
    c1, cn = results["curve"]
    np.testing.assert_array_equal(c1["mean_ns"], cn["mean_ns"])
    np.testing.assert_array_equal(c1["p90_ns"], cn["p90_ns"])
    emit("memsim_speed.shard.agree", 0.0, 1)


if __name__ == "__main__":
    main()
