"""Channels x LLC Pareto frontier: area cost vs geomean speedup.

A named-axis sweep over (baseline + CXL channel-count designs) x LLC
capacities -- every cell solved in one jitted pass -- reduced to the
non-dominated ``rel_area`` vs geomean-speedup frontier, plus its knee
point (max perpendicular distance from the chord between the frontier's
endpoints: the "buy this one" design).

The LLC axis overrides ``llc_mb_per_core`` for every design in the grid,
so each cell's area accounting moves with it (design_cost_grid) -- the
frontier trades real silicon against real speedup.
"""

import dataclasses

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import coaxial, cpu_model, hw

CHANNELS = range(1, 9)
LLC_MB_PER_CORE = (0.5, 1.0, 2.0, 4.0)


def frontier_sweep() -> "coaxial.SweepResult":
    """The shared channels x LLC grid (also rendered by benchmarks.report)."""
    designs = [cpu_model.DDR_BASELINE] + [
        cpu_model.MemSystem(
            f"pareto-cxl-{ch}x", dram_channels=ch, links=ch,
            link_rd_gbps=hw.CXL_X8_RD_GBPS, link_wr_gbps=hw.CXL_X8_WR_GBPS,
            iface_lat_ns=hw.CXL_LAT_NS, llc_mb_per_core=1.0)
        for ch in CHANNELS
    ]
    spec = coaxial.sweep_spec(design=designs,
                              llc_mb_per_core=LLC_MB_PER_CORE)
    return coaxial.solve_spec(spec)


def knee_point(frontier, *, cost: str = "rel_area") -> dict:
    """Frontier point farthest (perpendicular) from the endpoint chord.

    Kept as a shim: the implementation moved to ``coaxial.knee_point``
    so library code (``repro.core.designer``) can use it too.
    """
    return coaxial.knee_point(frontier, cost=cost)


def main():
    us, sw = time_call(frontier_sweep, warmup=0, iters=1)
    front = sw.pareto(cost="rel_area")
    knee = knee_point(front)
    n_cells = int(np.prod(sw.shape))
    emit("pareto.cells", us, n_cells)
    emit("pareto.frontier_size", 0.0, len(front))
    best = front[-1]
    emit("pareto.best", 0.0,
         f"{best['design']}@{best['llc_mb_per_core']:g}MB="
         f"{best['geomean_speedup']:.3f}x/{best['rel_area']:.3f}area")
    emit("pareto.knee", 0.0,
         f"{knee['design']}@{knee['llc_mb_per_core']:g}MB="
         f"{knee['geomean_speedup']:.3f}x/{knee['rel_area']:.3f}area")

    # Which way should the knee design move?  The same differentiable
    # model, queried with jax.grad through the fixed point.
    knee_sys = dataclasses.replace(
        next(d for d in sw.designs if d.name == knee["design"]),
        llc_mb_per_core=knee["llc_mb_per_core"])
    us_g, g = time_call(
        lambda: coaxial.design_gradient(
            knee_sys, ("dram_channels", "llc_mb_per_core", "iface_lat_ns")),
        warmup=0, iters=1)
    emit("pareto.knee_gradient", us_g,
         ";".join(f"d_{k}={v:+.4f}" for k, v in g.items()))


if __name__ == "__main__":
    main()
