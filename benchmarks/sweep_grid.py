"""Dense design-space grid: the batched sweep engine vs a per-point loop.

Declares a named-axis spec -- (baseline + 10 channel counts) x 10 CXL
latency premiums (110 grid points, all 35 workloads each = 3850 model
solutions) -- and solves it in ONE jitted, vmapped call, then times the
same grid as a Python loop of single-point ``solve()`` calls.
The loop already shares the sweep engine's single-point compilation (the
old code recompiled per design), so the remaining gap is pure dispatch /
fixed-point batching -- the sweep's advantage grows with grid size.
"""

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import coaxial, cpu_model, hw

CHANNELS = range(1, 11)
LATENCIES = tuple(float(l) for l in np.linspace(10.0, 100.0, 10))


def _grid_designs():
    return [
        cpu_model.MemSystem(
            f"grid-cxl-{ch}x", dram_channels=ch, links=ch,
            link_rd_gbps=hw.CXL_X8_RD_GBPS, link_wr_gbps=hw.CXL_X8_WR_GBPS,
            iface_lat_ns=hw.CXL_LAT_NS, llc_mb_per_core=1.0)
        for ch in CHANNELS
    ]


def main():
    # Baseline included explicitly so the batched grid and the per-point
    # loop solve the SAME point set (solve_spec would prepend it anyway).
    designs = [cpu_model.DDR_BASELINE] + _grid_designs()
    spec = coaxial.sweep_spec(design=designs, iface_lat_ns=LATENCIES)
    n_points = len(designs) * len(LATENCIES)

    # Both sides timed compile-warm (warmup=1 pays each path's XLA trace),
    # so the ratio is pure steady-state dispatch + batching.
    t0 = cpu_model.solve_trace_count()
    us_batch, sw = time_call(lambda: coaxial.solve_spec(spec),
                             warmup=1, iters=1)
    traces = cpu_model.solve_trace_count() - t0
    assert sw.shape == (len(designs), len(LATENCIES))

    def loop():
        return [cpu_model.solve(d, iface_lat_ns=lat if d.is_cxl else None)
                for d in designs for lat in LATENCIES]

    us_loop, _ = time_call(loop, warmup=1, iters=1)

    gm = sw.geomean_grid()          # (D, L) incl. prepended baseline
    best = np.unravel_index(np.argmax(gm), gm.shape)
    emit("sweep_grid.points", 0.0, n_points)
    emit("sweep_grid.batched_us", us_batch, f"{us_batch / n_points:.0f}")
    emit("sweep_grid.loop_us", us_loop, f"{us_loop / n_points:.0f}")
    emit("sweep_grid.loop_over_batched", 0.0,
         f"{us_loop / max(us_batch, 1e-9):.1f}")
    emit("sweep_grid.traces_for_grid", 0.0, traces)
    emit("sweep_grid.best_geomean", 0.0,
         f"{sw.designs[best[0]].name}@{sw.iface_lats[best[1]]:.0f}ns="
         f"{gm[best]:.3f}")


if __name__ == "__main__":
    main()
