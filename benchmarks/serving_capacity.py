"""The serving capacity planner as a benchmark section (repro.serving).

Runs one end-to-end plan -- a memory-bound arch against the synthetic
diurnal trace, every candidate (registry + generated CXL grid + measured
2303.15375 devices, with tier splits) -- and emits the planner's answer
plus the headline of the serving story: DDR baseline vs best-pick p99
token latency.  The DES side honors ``REPRO_DES_STEPS`` /
``REPRO_DES_ENGINE`` like every other DES-backed section, so CI smoke
runs the full pipeline cheaply.
"""

from benchmarks.common import des_budget, des_engine, emit, emit_derived, \
    time_call
from repro.core import coaxial
from repro.serving import capacity, traffic

#: Small-model serving point: memory-bound, so the design choice is
#: decided by the queue mechanism rather than the compute floor.
ARCH = "stablelm-1.6b"
SLO_MS = 500.0


def main():
    engine = des_engine("event")
    steps = des_budget(capacity.DEFAULT_STEPS, engine)
    trace = traffic.synthetic_diurnal(n_epochs=4)
    # The plan reads the design registry (include_registry=True);
    # scoped_registry guarantees this section leaves it exactly as
    # found even if a future candidate generator registers points.
    with coaxial.scoped_registry():
        us, plan = time_call(
            lambda: capacity.plan_capacity(
                (ARCH,), trace, slo_p99_ms=SLO_MS, peak_util=0.65,
                steps=steps, engine=engine),
            warmup=0, iters=1)
    emit("serving.plan_capacity", us, len(plan.verdicts))
    best = plan.best or plan.closest
    baseline = next(v for v in plan.verdicts if v.design == "ddr-baseline")
    emit_derived("serving.arch", ARCH)
    emit_derived("serving.best.design", best.name)
    emit_derived("serving.best.rel_area", f"{best.rel_area:.3f}")
    emit_derived("serving.best.token_p99_ms", f"{best.token_p99_ms:.1f}")
    emit_derived("serving.ddr-baseline.token_p99_ms",
                 f"{baseline.token_p99_ms:.1f}")
    emit_derived("serving.p99_speedup_vs_ddr",
                 f"{baseline.token_p99_ms / best.token_p99_ms:.2f}")
    emit_derived("serving.meets_slo", int(plan.best is not None))


if __name__ == "__main__":
    main()
