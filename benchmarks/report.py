"""Render the EXPERIMENTS.md §Dry-run / §Roofline / §Coaxial tables.

    PYTHONPATH=src python -m benchmarks.report [--mesh 16x16]

Markdown to stdout; EXPERIMENTS.md embeds the output.  The §Coaxial table
is sliced from the one shared design-space sweep (a single XLA compile for
every design x latency x core-count cell).
"""

import argparse
import json

from benchmarks.roofline import analyze, load_cells, model_flops
from repro.configs import ARCHS, SHAPES


def dryrun_table(mesh: str) -> str:
    cells = {(c["arch"], c["shape"]): c for c in load_cells(mesh)}
    lines = [
        f"| arch | shape | status | compile s | HLO GFLOP/chip | "
        f"HBM GB/chip | coll GB/chip | temp GiB/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            c = cells.get((arch, shape.name))
            if c is None:
                lines.append(f"| {arch} | {shape.name} | missing | | | | | |")
                continue
            if c["status"] != "ok":
                reason = c["status"].replace("skip: ", "")
                lines.append(
                    f"| {arch} | {shape.name} | SKIP ({reason[:42]}) "
                    f"| | | | | |")
                continue
            temp = c["memory"].get("temp_bytes", 0) / 2**30
            lines.append(
                f"| {arch} | {shape.name} | ok | {c['seconds']:.0f} | "
                f"{c['flops_per_chip']/1e9:.0f} | "
                f"{c['bytes_per_chip']/1e9:.1f} | "
                f"{c['collectives']['total']/1e9:.2f} | {temp:.1f} |")
    return "\n".join(lines)


def roofline_table(mesh: str) -> str:
    cells = {(c["arch"], c["shape"]): c for c in load_cells(mesh)}
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | "
        "dominant | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            c = cells.get((arch, shape.name))
            if c is None or c["status"] != "ok":
                continue
            t = analyze(c)
            lines.append(
                f"| {arch} | {shape.name} | {t['compute_s']*1e3:.2f} | "
                f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
                f"{t['dominant'].replace('_s','')} | "
                f"{t['useful_ratio']:.2f} | {t['mfu_bound']:.3f} |")
    return "\n".join(lines)


def variant_table(arch: str, shape: str, mesh: str = "16x16") -> str:
    """All recorded variants of one cell (the §Perf iteration log)."""
    rows = []
    import glob
    import os
    from benchmarks.roofline import RESULTS_DIR
    for path in sorted(glob.glob(os.path.join(
            RESULTS_DIR, f"{arch}__{shape}__{mesh}__*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    lines = [
        "| variant | GFLOP/chip | HBM GB/chip | coll GB/chip | "
        "temp GiB | dominant | bound ms |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in rows:
        if c["status"] != "ok":
            lines.append(f"| {c['variant']} | error: {c['error'][:60]} "
                         f"| | | | | |")
            continue
        t = analyze(c)
        lines.append(
            f"| {c['variant']} | {c['flops_per_chip']/1e9:.0f} | "
            f"{c['bytes_per_chip']/1e9:.1f} | "
            f"{c['collectives']['total']/1e9:.2f} | "
            f"{c['memory'].get('temp_bytes',0)/2**30:.1f} | "
            f"{t['dominant'].replace('_s','')} | {t['bound_s']*1e3:.2f} |")
    return "\n".join(lines)


def coaxial_table() -> str:
    """Geomean speedup vs baseline for every registered design, at both
    §6.4 latency points and every §6.5 core count -- one sweep, one table."""
    from repro.core import coaxial
    sw = coaxial.default_sweep()
    gm = sw.geomean_grid()          # (D, L, C)
    lat_labels = ["default" if l is None else f"{l:.0f}ns"
                  for l in sw.iface_lats]
    header = ["design"] + [f"{lab} @{c}c" for lab in lat_labels
                           for c in sw.cores]
    lines = ["| " + " | ".join(header) + " |",
             "|" + "---|" * len(header)]
    for i, d in enumerate(sw.designs):
        if d.name == sw.baseline_name:
            continue
        cells = [f"{gm[i, j, k]:.3f}" for j in range(len(sw.iface_lats))
                 for k in range(len(sw.cores))]
        lines.append("| " + " | ".join([d.name] + cells) + " |")
    return "\n".join(lines)


def drift_table() -> str:
    """Closed-form vs memsim-backed headline numbers, one row per
    headline -- the "mechanism replaces closed form" drift experiment."""
    from benchmarks.drift_headline import drift_rows, drift_sweep
    lines = ["| headline | closed form | memsim-backed | drift |",
             "|---|---|---|---|"]
    for r in drift_rows(drift_sweep()):
        lines.append(f"| {r['metric']} | {r['closed']:.3f} | "
                     f"{r['memsim']:.3f} | {r['drift_pct']:+.1f}% |")
    return "\n".join(lines)


def harvest_table() -> str:
    """The idle-I/O harvesting frontier (duty x channels) and the
    backend drift on a harvesting design -- the 2511.12349 rows."""
    from benchmarks.harvest_headline import drift_row, frontier_rows, \
        frontier_sim, headline
    rows = frontier_rows(frontier_sim())
    lines = ["| channels | duty | queuing mean ns | mean reduction | "
             "p99 reduction |",
             "|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['channels']} | {r['duty']:g} | "
            f"{r['q_mean0_ns']:.0f} -> {r['q_mean_ns']:.0f} | "
            f"x{r['mean_reduction']:.2f} | x{r['p99_reduction']:.2f} |")
    h = headline(rows)
    d = drift_row()
    lines += ["",
              f"Headline: geomean x{h['reduction_gm']:.2f}, max "
              f"x{h['reduction_max']:.2f} queuing-delay reduction "
              f"(paper: 1.52x mean / ~3x max).",
              f"Backend drift on coaxial-4x+harvest: closed form "
              f"{d['closed']:.3f} vs memsim {d['memsim']:.3f} geomean "
              f"speedup ({d['drift_pct']:+.1f}%).",
              f"Harvest gain (memsim backend): coaxial-4x "
              f"{d['memsim_plain']:.3f} -> {d['memsim']:.3f} "
              f"({d['gain_pct']:+.1f}% -- the headline the closed form "
              f"cannot see)."]
    return "\n".join(lines)


def pareto_table() -> str:
    """The channels x LLC area-vs-speedup frontier (named-axis sweep),
    knee point flagged -- the design the frontier says to buy."""
    from benchmarks.pareto_frontier import frontier_sweep, knee_point
    sw = frontier_sweep()
    front = sw.pareto(cost="rel_area")
    knee = knee_point(front)
    lines = ["| design | llc MB/core | rel area | rel pins | geomean "
             "speedup | |",
             "|---|---|---|---|---|---|"]
    for p in front:
        mark = "knee" if p is knee else ""
        lines.append(
            f"| {p['design']} | {p['llc_mb_per_core']:g} | "
            f"{p['rel_area']:.3f} | {p['rel_pins']:.3f} | "
            f"{p['geomean_speedup']:.3f} | {mark} |")
    return "\n".join(lines)


def serving_table(arch: str = "stablelm-1.6b",
                  slo_p99_ms: float = 500.0) -> str:
    """Every capacity-planner verdict for one serving scenario, the
    SLO-meeting minimum-area pick flagged -- the repro.serving answer."""
    from repro.serving import capacity, traffic
    trace = traffic.synthetic_diurnal(n_epochs=4)
    plan = capacity.plan_capacity((arch,), trace, slo_p99_ms=slo_p99_ms,
                                  peak_util=0.65)
    lines = [f"Scenario: {arch} @ batch {plan.batch} / context "
             f"{plan.context}, trace `{plan.trace}`, SLO p99 <= "
             f"{plan.slo_p99_ms:g} ms ({plan.engine} engine, "
             f"{plan.steps} ns/cell)", "",
             "| design | tier split | rel area | rel pins | peak rho | "
             "access p99 ns | token p99 ms | SLO | |",
             "|---|---|---|---|---|---|---|---|---|"]
    best = plan.best
    for v in plan.verdicts:
        mark = "pick" if best is not None and v.name == best.name else ""
        lines.append(
            f"| {v.name} | {v.tier_split:g} | {v.rel_area:.3f} | "
            f"{v.rel_pins:.3f} | {v.peak_rho:.2f} | "
            f"{v.access_p99_ns:.0f} | {v.token_p99_ms:.1f} | "
            f"{'ok' if v.meets_slo else 'no'} | {mark} |")
    return "\n".join(lines)


def lut_table() -> str:
    """LUT store economics + grid convergence: cold/warm build rows, the
    grid ladder (interpolation error + fixed-point drift vs grid size),
    and the adaptive refinement trajectory with its <1% final-step
    convergence line -- the LUT-resolution endgame rendered."""
    from benchmarks.lut_convergence import bench_budget, cold_warm, \
        ladder_rows, refine_history
    steps, engine = bench_budget()
    cw = cold_warm()
    lines = [f"Store: cold build {cw['cold_s']:.2f}s vs warm resolution "
             f"{cw['warm_s']:.3f}s ({engine} engine, {steps} ns/cell); "
             f"warm DES traces {cw['warm_traces']}, bit-identical: "
             f"{'yes' if cw['bitident'] else 'NO'}.", "",
             "| grid | cells | interp err (max) | gm drift | "
             "token-p99 drift |",
             "|---|---|---|---|---|"]
    for r in ladder_rows(cw["lut"], steps, engine):
        label = "full" if r["stride"] == 1 else f"stride {r['stride']}"
        lines.append(
            f"| {label} | {r['cells']} | {r['interp_err_max']:.4f} | "
            f"{r['gm_drift_pct']:+.2f}% | {r['tok99_drift_pct']:+.2f}% |")
    hist = refine_history(steps, engine)
    lines += ["", "| refine round | cells | geomean speedup | "
              "token p99 ms | worst probe err | step delta |",
              "|---|---|---|---|---|---|"]
    for r in hist:
        delta = ("" if "d_geomean" not in r else
                 f"gm {100 * r['d_geomean']:.2f}% / "
                 f"p99 {100 * r['d_token_p99']:.2f}%")
        lines.append(
            f"| {r['round']} | {r['cells']} | "
            f"{r['geomean_speedup']:.4f} | {r['token_p99_ms']:.1f} | "
            f"{r['worst_err']:.3f} | {delta} |")
    final = hist[-1]
    if final["converged"]:
        lines += ["", f"Converged: final refinement step moved the "
                  f"geomean speedup {100 * final.get('d_geomean', 0.0):.2f}% "
                  f"and token p99 {100 * final.get('d_token_p99', 0.0):.2f}% "
                  f"(each < 1%)."]
    else:
        lines += ["", "NOT converged within the round budget "
                  f"(last step: gm {100 * final.get('d_geomean', 0.0):.2f}%, "
                  f"p99 {100 * final.get('d_token_p99', 0.0):.2f}%)."]
    return "\n".join(lines)


def _dirty_index(name: str) -> int:
    """``BENCH_<rev>-dirty<n>.json`` -> n; the clean base point -> 0."""
    import re
    m = re.search(r"-dirty(\d+)\.json$", name)
    return int(m.group(1)) if m else 0


def _load_bench_points(bench_dir=None) -> list:
    """All ``BENCH_*.json`` trajectory points, oldest first.

    Ordered by each point's own recorded ``unix_time`` (falling back to
    file mtime for pre-field points), tie-broken so a clean base rev
    sorts before its ``-dirty<n>`` descendants and dirty points stay in
    suffix order.  mtime alone is NOT trustworthy: a git checkout, an
    artifact download, or a ``cp`` rewrites it, which used to shuffle
    the trajectory and hide dirty points behind their base rev.
    """
    import glob
    import os
    from benchmarks.run import BENCH_DIR
    d = bench_dir or BENCH_DIR
    pts = []
    for p in glob.glob(os.path.join(d, "BENCH_*.json")):
        with open(p) as f:
            point = json.load(f)
        name = os.path.basename(p)
        t = point.get("unix_time", os.path.getmtime(p))
        pts.append(((t, _dirty_index(name), name), name, point))
    pts.sort(key=lambda x: x[0])
    return [(name, point) for _, name, point in pts]


#: Environment knobs two trajectory points must share to be comparable:
#: wall-clock gating a 6k-step local run against a 40k-step CI run (or a
#: different device count / module subset) would only measure the knobs.
_BENCH_ENV_KEYS = ("devices", "REPRO_DES_STEPS", "REPRO_DES_ENGINE",
                   "REPRO_DES_DEVICES", "only")


def _comparable(a_env: dict, b_env: dict) -> bool:
    return all(a_env.get(k) == b_env.get(k) for k in _BENCH_ENV_KEYS)


def bench_regressions(points, threshold: float = 0.30) -> dict:
    """Per-section wall-clock regressions: newest point vs the latest
    COMPARABLE prior (same :data:`_BENCH_ENV_KEYS`).

    Returns ``dict(prior=<name or None>, regressions=[...])``; the list
    stays empty until at least two comparable points exist, so a fresh
    trajectory (or an env-knob change) never gates.  A section regresses
    when both runs completed ok and its wall-clock grew by more than
    ``threshold`` (fractional, 0.30 = +30%).
    """
    if len(points) < 2:
        return dict(prior=None, regressions=[])
    _, cur = points[-1]
    prior = next(((n, p) for n, p in reversed(points[:-1])
                  if _comparable(p.get("env", {}), cur.get("env", {}))),
                 None)
    if prior is None:
        return dict(prior=None, regressions=[])
    name_prev, prev = prior
    regs = []
    for sec, s in cur.get("sections", {}).items():
        p = prev.get("sections", {}).get(sec)
        if (p is None or s.get("status") != "ok"
                or p.get("status") != "ok" or not p.get("seconds")):
            continue
        rel = s["seconds"] / p["seconds"] - 1.0
        if rel > threshold:
            regs.append(dict(section=sec, prev_s=p["seconds"],
                             cur_s=s["seconds"], pct=100.0 * rel))
    return dict(prior=name_prev, regressions=regs)


def bench_diff_table(bench_dir=None) -> str:
    """Diff the newest ``BENCH_<rev>.json`` against the prior point.

    Two tables: per-section wall-clock / row / trace-count drift, and
    the emitted rows whose numeric ``derived`` moved by more than 5%
    (speedups sliding, gates loosening).  With a single point the tables
    degrade to a plain snapshot -- the first run of the flywheel.
    """
    pts = _load_bench_points(bench_dir)
    if not pts:
        return "(no BENCH_*.json points yet -- run `python -m " \
               "benchmarks.run` to start the trajectory)"
    name_cur, cur = pts[-1]
    name_prev, prev = pts[-2] if len(pts) > 1 else (None, None)
    lines = []
    if prev is None:
        lines.append("(only one BENCH_*.json point -- nothing to diff "
                     "against yet; showing the snapshot. A second "
                     "`python -m benchmarks.run` starts the trajectory.)")
        lines.append("")
    lines += [f"Current: `{name_cur}` (rev {cur['rev']}, "
             f"{cur['env']['devices']} device(s), "
             f"{cur['totals']['seconds']:.1f}s, "
             f"{cur['totals']['failures']} failure(s))"]
    if prev is not None:
        lines.append(f"Prior:   `{name_prev}` (rev {prev['rev']}, "
                     f"{prev['env']['devices']} device(s), "
                     f"{prev['totals']['seconds']:.1f}s)")
    lines += ["", "| section | prev s | cur s | dt% | rows | traces |",
              "|---|---|---|---|---|---|"]
    prev_secs = (prev or {}).get("sections", {})
    for name, s in cur["sections"].items():
        p = prev_secs.get(name)
        tr = "+".join(str(v) for v in s.get("traces", {}).values())
        if p is None or not p["seconds"]:
            lines.append(f"| {name} | | {s['seconds']:.2f} | | "
                         f"{s['rows']} | {tr} |")
        else:
            d = 100.0 * (s["seconds"] / p["seconds"] - 1.0)
            lines.append(f"| {name} | {p['seconds']:.2f} | "
                         f"{s['seconds']:.2f} | {d:+.0f}% | "
                         f"{s['rows']} | {tr} |")
    if prev is None:
        return "\n".join(lines)

    def numeric_rows(pt):
        out = {}
        for name, _us, derived in pt["rows"]:
            try:
                out[name] = float(derived)
            except ValueError:
                pass
        return out

    cu, pr = numeric_rows(cur), numeric_rows(prev)
    moved = []
    for name in sorted(set(cu) & set(pr)):
        a, b = pr[name], cu[name]
        if a == b:
            continue
        rel = abs(b - a) / max(abs(a), 1e-12)
        if rel > 0.05:
            moved.append((rel, name, a, b))
    if moved:
        lines += ["", "| row (moved >5%) | prev | cur |", "|---|---|---|"]
        for rel, name, a, b in sorted(moved, reverse=True)[:20]:
            lines.append(f"| {name} | {a:g} | {b:g} |")
    else:
        lines += ["", "(no numeric row moved by more than 5%)"]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "coaxial",
                             "pareto", "drift", "harvest", "serving",
                             "lut", "bench"])
    ap.add_argument("--variants", nargs=2, metavar=("ARCH", "SHAPE"),
                    default=None)
    ap.add_argument("--max-regress", type=float, default=None,
                    metavar="FRAC",
                    help="with the bench section: exit 1 when any "
                         "section's wall-clock grew by more than FRAC "
                         "(e.g. 0.30) vs the latest comparable point")
    args = ap.parse_args()
    if args.variants:
        print(variant_table(args.variants[0], args.variants[1], args.mesh))
        return
    if args.section in ("all", "dryrun"):
        print(f"### Dry-run ({args.mesh})\n")
        print(dryrun_table(args.mesh))
        print()
    if args.section in ("all", "roofline"):
        print(f"### Roofline ({args.mesh})\n")
        print(roofline_table(args.mesh))
        print()
    if args.section in ("all", "coaxial"):
        print("### Coaxial design-space sweep\n")
        print(coaxial_table())
        print()
    if args.section in ("all", "pareto"):
        print("### Channels x LLC Pareto frontier\n")
        print(pareto_table())
        print()
    if args.section in ("all", "drift"):
        print("### Closed form vs mechanism (headline drift)\n")
        print(drift_table())
        print()
    if args.section in ("all", "harvest"):
        print("### Idle-I/O harvesting frontier\n")
        print(harvest_table())
        print()
    if args.section in ("all", "serving"):
        print("### Serving capacity plan\n")
        print(serving_table())
        print()
    if args.section in ("all", "lut"):
        print("### QueueLUT store & grid convergence\n")
        print(lut_table())
        print()
    if args.section in ("all", "bench"):
        print("### Benchmark trajectory (BENCH_<rev>.json diff)\n")
        print(bench_diff_table())
        if args.max_regress is not None:
            gate = bench_regressions(_load_bench_points(),
                                     threshold=args.max_regress)
            for r in gate["regressions"]:
                print(f"REGRESSION {r['section']}: {r['prev_s']:.2f}s "
                      f"-> {r['cur_s']:.2f}s ({r['pct']:+.0f}% > "
                      f"+{100 * args.max_regress:.0f}% vs "
                      f"`{gate['prior']}`)")
            if gate["regressions"]:
                raise SystemExit(1)


if __name__ == "__main__":
    main()
