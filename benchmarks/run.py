"""Run every benchmark; print ``name,us_per_call,derived`` CSV.

One module per paper figure/table (fig2a..fig9, table2, table5), the STREAM
Pallas kernels, the beyond-paper channelized-decode planner study, and the
roofline table derived from the dry-run artifacts.
"""

import importlib
import sys
import traceback

MODULES = [
    "benchmarks.fig2a_load_latency",
    "benchmarks.fig2b_breakdown",
    "benchmarks.fig3_variance",
    "benchmarks.fig5_speedup",
    "benchmarks.fig6_distribution",
    "benchmarks.fig7_designs",
    "benchmarks.fig8_latency_sens",
    "benchmarks.fig9_utilization",
    "benchmarks.table2_designs",
    "benchmarks.table5_edp",
    "benchmarks.sweep_grid",
    "benchmarks.pareto_frontier",
    "benchmarks.drift_headline",
    "benchmarks.memsim_speed",
    "benchmarks.stream_kernels",
    "benchmarks.channelized_decode",
    "benchmarks.roofline",
]


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes (e.g. "
                         "'fig2a_load_latency,table2_designs') -- the CI "
                         "smoke subset")
    args = ap.parse_args(argv)
    modules = MODULES
    if args.only:
        wanted = {m.strip() for m in args.only.split(",")}
        modules = [m for m in MODULES if m.split(".")[-1] in wanted]
        missing = wanted - {m.split(".")[-1] for m in modules}
        if missing:
            raise SystemExit(f"unknown benchmark modules: {sorted(missing)}")
    print("name,us_per_call,derived")
    failures = 0
    for mod_name in modules:
        try:
            mod = importlib.import_module(mod_name)
            mod.main()
        except Exception:       # noqa: BLE001 -- report all benches
            failures += 1
            print(f"{mod_name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
