"""Run every benchmark; print ``name,us_per_call,derived`` CSV.

One module per paper figure/table (fig2a..fig9, table2, table5), the STREAM
Pallas kernels, the beyond-paper channelized-decode planner study, and the
roofline table derived from the dry-run artifacts.

Every run also writes a versioned ``BENCH_<rev>.json`` trajectory point
at the repo root (override with ``--bench-json``, disable with
``--no-bench-json``); dirty working trees get ``BENCH_<rev>-dirty<n>``
suffixes so iterating locally accumulates points instead of clobbering
one: per-section wall-clock, emitted-row and
DES jit-trace counts, every CSV row, and the environment knobs that shaped
the run (device count, ``REPRO_DES_STEPS``/``_ENGINE``/``_DEVICES``,
compile-cache dir).  ``report.py --section bench`` diffs the newest two
points, so benchmark trajectory -- speedups drifting, sections slowing,
trace counts creeping -- is a reviewable artifact, not a memory.
"""

import importlib
import json
import os
import subprocess
import sys
import time
import traceback

MODULES = [
    "benchmarks.fig2a_load_latency",
    "benchmarks.fig2b_breakdown",
    "benchmarks.fig3_variance",
    "benchmarks.fig5_speedup",
    "benchmarks.fig6_distribution",
    "benchmarks.fig7_designs",
    "benchmarks.fig8_latency_sens",
    "benchmarks.fig9_utilization",
    "benchmarks.table2_designs",
    "benchmarks.table5_edp",
    "benchmarks.sweep_grid",
    "benchmarks.pareto_frontier",
    # lut_convergence resolves the shared default QueueLUT surface first,
    # so the LUT-backed sections after it (drift, harvest, serving,
    # designer) hit the bounded in-process layer instead of rebuilding.
    "benchmarks.lut_convergence",
    "benchmarks.drift_headline",
    "benchmarks.harvest_headline",
    "benchmarks.serving_capacity",
    "benchmarks.designer_opt",
    "benchmarks.memsim_speed",
    "benchmarks.stream_kernels",
    "benchmarks.channelized_decode",
    "benchmarks.roofline",
]

#: Default home of the ``BENCH_<rev>.json`` history: the repo root, so
#: trajectory points are committed alongside the code they measure
#: (``benchmarks/results/`` was never checked in, so the history always
#: started empty there).
BENCH_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_rev() -> str:
    """Short HEAD revision, or ``nogit`` outside a checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, check=True,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "nogit"
    except Exception:       # noqa: BLE001 -- any git failure means nogit
        return "nogit"


def git_dirty() -> bool:
    """True when the working tree differs from HEAD."""
    try:
        out = subprocess.run(["git", "status", "--porcelain"],
                             capture_output=True, text=True, check=True,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        return bool(out.stdout.strip())
    except Exception:       # noqa: BLE001 -- any git failure means clean
        return False


def bench_path(where: str, rev: str, dirty: bool = False) -> str:
    """Resolve ``--bench-json`` (a dir or a ``.json`` path) to a file.

    A clean rev maps to ``BENCH_<rev>.json`` (re-running the same
    commit legitimately refreshes its point); a dirty tree gets the
    first free ``BENCH_<rev>-dirty<n>.json`` so successive local edits
    accumulate trajectory points instead of overwriting one.
    """
    if where.endswith(".json"):
        return where
    if not dirty:
        return os.path.join(where, f"BENCH_{rev}.json")
    n = 1
    while os.path.exists(os.path.join(where,
                                      f"BENCH_{rev}-dirty{n}.json")):
        n += 1
    return os.path.join(where, f"BENCH_{rev}-dirty{n}.json")


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes (e.g. "
                         "'fig2a_load_latency,table2_designs') -- the CI "
                         "smoke subset")
    ap.add_argument("--bench-json", default=BENCH_DIR,
                    help="directory (or explicit .json path) for the "
                         "BENCH_<rev>.json trajectory point")
    ap.add_argument("--no-bench-json", action="store_true",
                    help="skip writing the trajectory point")
    args = ap.parse_args(argv)
    modules = MODULES
    if args.only:
        wanted = {m.strip() for m in args.only.split(",")}
        modules = [m for m in MODULES if m.split(".")[-1] in wanted]
        missing = wanted - {m.split(".")[-1] for m in modules}
        if missing:
            raise SystemExit(f"unknown benchmark modules: {sorted(missing)}")

    from benchmarks import common
    cache_dir = common.enable_compile_cache()
    lut_cache = common.enable_lut_cache()

    import jax
    from repro.core import memsim

    print("name,us_per_call,derived")
    sections, all_rows = {}, []
    t_start = time.perf_counter()
    failures = 0
    for mod_name in modules:
        name = mod_name.split(".")[-1]
        common.ROWS = rows = []
        tr0 = {e: memsim.sim_trace_count(e) for e in memsim.ENGINES}
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(mod_name)
            mod.main()
            status = "ok"
        except Exception:       # noqa: BLE001 -- report all benches
            failures += 1
            status = "error"
            print(f"{mod_name},0.0,ERROR", file=sys.stderr)
            traceback.print_exc()
        finally:
            common.ROWS = None
        sections[name] = dict(
            status=status,
            seconds=round(time.perf_counter() - t0, 3),
            rows=len(rows),
            traces={e: memsim.sim_trace_count(e) - tr0[e]
                    for e in memsim.ENGINES})
        all_rows.extend(list(r) for r in rows)

    if not args.no_bench_json:
        rev = git_rev()
        path = bench_path(args.bench_json, rev, dirty=git_dirty())
        base = os.path.basename(path)
        if base.startswith("BENCH_") and base.endswith(".json"):
            rev = base[len("BENCH_"):-len(".json")]
        point = dict(
            rev=rev,
            unix_time=int(time.time()),
            env=dict(
                devices=len(jax.devices()),
                REPRO_DES_STEPS=os.environ.get("REPRO_DES_STEPS"),
                REPRO_DES_ENGINE=os.environ.get("REPRO_DES_ENGINE"),
                REPRO_DES_DEVICES=os.environ.get("REPRO_DES_DEVICES"),
                compile_cache=cache_dir,
                lut_cache=lut_cache,
                only=args.only),
            totals=dict(seconds=round(time.perf_counter() - t_start, 3),
                        rows=len(all_rows), failures=failures,
                        traces={e: memsim.sim_trace_count(e)
                                for e in memsim.ENGINES}),
            sections=sections,
            rows=all_rows)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(point, f, indent=1)
        print(f"bench json: {path}", file=sys.stderr)

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
