"""Closed form vs mechanism: drift of every headline number.

The "mechanism replaces closed form" experiment: the same design grid is
solved twice -- once with the calibrated closed-form queue model
(``queueing.effective_queue_wait_ns`` + the sigma heuristic) and once
with the DES-derived :class:`repro.core.queuelut.QueueLUT` inside the
fixed point -- via ONE ``queue_model`` sweep axis (one jitted pass per
backend).  Every headline the paper reports is then compared backend
against backend: the Fig 5/7 geomean speedups per design, the Fig 5
extremes (lbm, stream-copy), the §6.4 pessimistic-latency point, and the
Table 5 EDP ratio.

The drift is the finding, not a bug: the closed form caps queue waits at
the *mean* level (occupancy-scaled architectural cap) while the DES
bounds every sample path through its finite in-flight population, so the
two part ways exactly at the high-rho operating points that decide the
CoaXiaL headline.  ``REPRO_DES_STEPS`` caps the LUT build for CI smoke;
the build runs on the DES's default engine (the per-request event
engine) unless ``REPRO_DES_ENGINE`` overrides it.
"""

import numpy as np

from benchmarks.common import des_budget, des_engine, emit, emit_derived, \
    time_call
from repro.core import coaxial, cpu_model, devices, hw, queuelut, workloads


def drift_sweep() -> "coaxial.SweepResult":
    """Designs x (default, pessimistic) latency x both queue backends.

    Solves whatever is REGISTERED -- ``main`` registers the measured
    2303.15375 device points and the derived LLM serving workload first,
    so each gets its own drift row beside the idealized Table-2 set."""
    lut = queuelut.default_queue_lut(
        steps=des_budget(queuelut.DEFAULT_STEPS),
        engine=des_engine(queuelut.DEFAULT_ENGINE))
    spec = coaxial.sweep_spec(
        design=coaxial.all_designs(),
        iface_lat_ns=(None, hw.CXL_LAT_PESSIMISTIC_NS),
        queue_model=cpu_model.QUEUE_MODELS)
    return coaxial.solve_spec(spec, workloads=workloads.all_workloads(),
                              lut=lut)


def drift_rows(sw) -> list[dict]:
    """One row per headline: closed-form value, memsim value, drift %."""

    def cmp(design, iface=None):
        return {qm: sw.comparison(design, iface_lat=iface, queue_model=qm)
                for qm in cpu_model.QUEUE_MODELS}

    rows = []

    def add(metric, closed, memsim):
        closed, memsim = float(closed), float(memsim)
        rows.append(dict(metric=metric, closed=closed, memsim=memsim,
                         drift_pct=100.0 * (memsim / closed - 1.0)))

    # Fig 7 / Table 2: geomean speedup of every registered design.
    for d in sw.designs:
        if d.name == sw.baseline_name:
            continue
        c = cmp(d)
        add(f"fig7.{d.name}.gm_speedup",
            c["closed_form"].geomean_speedup,
            c["memsim"].geomean_speedup)
    # §6.4 / Fig 8: the pessimistic 50ns CXL premium on the 4x design.
    c50 = cmp(coaxial.COAXIAL_4X, iface=hw.CXL_LAT_PESSIMISTIC_NS)
    add("fig8.coaxial-4x.gm_speedup_50ns",
        c50["closed_form"].geomean_speedup, c50["memsim"].geomean_speedup)
    # Fig 5 extremes: the best-case streaming kernel and the regression
    # canary.
    c4 = cmp(coaxial.COAXIAL_4X)
    extremes = ("lbm", "stream-copy")
    # ... plus any registered LLM serving workload (repro.serving).
    llm = tuple(n for n in sw.names if n.startswith("llm-"))
    for wname in extremes + llm:
        i = sw.names.index(wname)
        prefix = "serving" if wname in llm else "fig5"
        add(f"{prefix}.{wname}.speedup",
            c4["closed_form"].speedup[i], c4["memsim"].speedup[i])
    # Table 5: EDP ratio, re-derived per backend from its own comparison.
    add("table5.edp_ratio",
        coaxial.edp_report(coaxial.COAXIAL_4X,
                           cmp=c4["closed_form"])["edp_ratio"],
        coaxial.edp_report(coaxial.COAXIAL_4X,
                           cmp=c4["memsim"])["edp_ratio"])
    return rows


def main():
    from repro.serving.demand import register_llm_workloads

    # scoped_registry snapshots BOTH registries and restores on exit
    # (invalidating the default_sweep cache), so repeated invocations --
    # and whatever runs after this section -- solve the same grid.
    with coaxial.scoped_registry():
        devices.register_measured_devices()
        register_llm_workloads(("mistral-large-123b",))
        us, sw = time_call(drift_sweep, warmup=0, iters=1)
        emit("drift.cells", us, int(np.prod(sw.shape)))
        for r in drift_rows(sw):
            emit_derived(
                f"drift.{r['metric']}",
                f"{r['closed']:.3f}|{r['memsim']:.3f}|"
                f"{r['drift_pct']:+.1f}%")


if __name__ == "__main__":
    main()
