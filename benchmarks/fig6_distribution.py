"""Fig 6: latency distributions -- group means/stdevs + streamcluster CDF
(baseline vs COAXIAL channel at matched per-channel load)."""

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import coaxial, memsim
from repro.core.workloads import WORKLOADS


def main():
    cmp = coaxial.evaluate(coaxial.COAXIAL_4X)
    suites = sorted({w.suite for w in WORKLOADS})
    for suite in suites:
        idx = [i for i, w in enumerate(WORKLOADS) if w.suite == suite]
        emit(f"fig6a.{suite}.base_mean_ns", 0.0,
             f"{np.mean(cmp.base.latency_ns[idx]):.1f}")
        emit(f"fig6a.{suite}.base_stdev_ns", 0.0,
             f"{np.mean(cmp.base.sigma_ns[idx]):.1f}")
        emit(f"fig6a.{suite}.coax_mean_ns", 0.0,
             f"{np.mean(cmp.res.latency_ns[idx]):.1f}")
        emit(f"fig6a.{suite}.coax_stdev_ns", 0.0,
             f"{np.mean(cmp.res.sigma_ns[idx]):.1f}")

    # Streamcluster CDF: DDR channel at its baseline rho vs a COAXIAL
    # channel at rho/4 with the 30ns premium.
    i = [w.name for w in WORKLOADS].index("streamcluster")
    rho_b = float(cmp.base.rho[i])
    us, stats = time_call(lambda: memsim.simulate(
        [memsim.ChannelConfig(rho=rho_b),
         memsim.ChannelConfig(rho=rho_b / 4, cxl_lat_ns=30.0)],
        steps=150_000), iters=1)
    for j, tag in enumerate(["ddr", "coaxial"]):
        emit(f"fig6b.streamcluster.{tag}.p50_ns", us / 2,
             f"{stats.p50_ns[j]:.0f}")
        emit(f"fig6b.streamcluster.{tag}.p90_ns", us / 2,
             f"{stats.p90_ns[j]:.0f}")
        emit(f"fig6b.streamcluster.{tag}.stdev_ns", us / 2,
             f"{stats.stdev_ns[j]:.0f}")


if __name__ == "__main__":
    main()
