"""Fig 6: latency distributions -- group means/stdevs + streamcluster CDF
(baseline vs COAXIAL channel at matched per-channel load).

The 6b comparison slices ONE shared distribution sweep (a rho x
cxl_lat_ns grid, single compile) by named coordinate instead of an
ad-hoc config list.
"""

import numpy as np

from benchmarks.common import des_steps, emit, time_call
from repro.core import coaxial
from repro.core.workloads import WORKLOADS


def main():
    cmp = coaxial.evaluate(coaxial.COAXIAL_4X)
    suites = sorted({w.suite for w in WORKLOADS})
    for suite in suites:
        idx = [i for i, w in enumerate(WORKLOADS) if w.suite == suite]
        emit(f"fig6a.{suite}.base_mean_ns", 0.0,
             f"{np.mean(cmp.base.latency_ns[idx]):.1f}")
        emit(f"fig6a.{suite}.base_stdev_ns", 0.0,
             f"{np.mean(cmp.base.sigma_ns[idx]):.1f}")
        emit(f"fig6a.{suite}.coax_mean_ns", 0.0,
             f"{np.mean(cmp.res.latency_ns[idx]):.1f}")
        emit(f"fig6a.{suite}.coax_stdev_ns", 0.0,
             f"{np.mean(cmp.res.sigma_ns[idx]):.1f}")

    # Streamcluster CDF: DDR channel at its baseline rho vs a COAXIAL
    # channel at rho/4 with the 30ns premium -- two named cells of one
    # batched rho x cxl_lat_ns distribution sweep.
    i = [w.name for w in WORKLOADS].index("streamcluster")
    rho_b = float(cmp.base.rho[i])
    steps = des_steps(150_000)
    us, sw = time_call(lambda: coaxial.distribution_sweep(
        rho=(rho_b, rho_b / 4), cxl_lat_ns=(0.0, 30.0),
        steps=steps, reps=max(1, 600_000 // steps)), iters=1)
    cells = dict(ddr=sw.sel(rho=rho_b, cxl_lat_ns=0.0),
                 coaxial=sw.sel(rho=rho_b / 4, cxl_lat_ns=30.0))
    for tag, stats in cells.items():
        emit(f"fig6b.streamcluster.{tag}.p50_ns", us / 2,
             f"{float(stats.p50_ns):.0f}")
        emit(f"fig6b.streamcluster.{tag}.p90_ns", us / 2,
             f"{float(stats.p90_ns):.0f}")
        emit(f"fig6b.streamcluster.{tag}.stdev_ns", us / 2,
             f"{float(stats.stdev_ns):.0f}")


if __name__ == "__main__":
    main()
