#!/usr/bin/env python3
"""Fail on broken relative links in markdown files (stdlib only).

    python tools/check_links.py [PATH ...]

Each PATH is a markdown file or a directory to scan recursively for
``*.md`` (default: ``README.md`` and ``docs/``).  Inline links
``[text](target)`` are checked; targets that are external
(``http(s)://``, ``mailto:``) or pure in-page anchors (``#...``) are
skipped, fenced code blocks are stripped first, and ``target#anchor``
checks only the file part.  Exit code 1 if any relative target does not
exist on disk -- the CI docs job runs exactly this.
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```.*?```", re.S)
EXTERNAL = ("http://", "https://", "mailto:")


def broken_links(path: Path) -> list[str]:
    text = FENCE_RE.sub("", path.read_text())
    bad = []
    for target in LINK_RE.findall(text):
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            bad.append(target)
    return bad


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] or [Path("README.md"), Path("docs")]
    files: list[Path] = []
    for root in roots:
        if root.is_dir():
            files.extend(sorted(root.rglob("*.md")))
        elif root.exists():
            files.append(root)
        else:
            print(f"{root}: no such file or directory")
            return 1
    failed = False
    for f in files:
        for target in broken_links(f):
            print(f"{f}: broken relative link -> {target}")
            failed = True
    if failed:
        return 1
    print(f"checked {len(files)} markdown file(s): all relative links "
          f"resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
