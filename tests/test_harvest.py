"""Idle-I/O bandwidth harvesting (arXiv 2511.12349) across both engines.

The property layer the mechanism ships inside:

* ``harvest_duty=0`` is BIT-identical to the pre-harvest simulator --
  pinned against sha256 fingerprints captured from the commit before the
  mechanism existed (both engines, mixed open/closed-loop configs).
* The two engines agree on the harvested law at every calibration
  anchor (``coaxial.crosscheck_engines`` with a harvesting base).
* Sharded vs unsharded runs stay bit-equal with harvest active.
* Seeds reproduce; a harvest grid still costs one trace per engine.
* Hypothesis-guarded monotonicity: in the open loop the per-request
  wait is EXACTLY pathwise non-increasing in ``harvest_bw_gbps``, and
  any lent-time fraction can only shorten waits vs its duty=0 twin
  (the harvest streams are salted, so the base draws never move).
"""

import hashlib

import jax
import numpy as np
import pytest

from repro.core import coaxial, cpu_model, memsim
from repro.core.memsim import ChannelConfig

NDEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 (forced) host devices")

#: The pre-harvest fingerprint batch: open loop, bursty CXL, and a
#: tight closed loop with queue-exposure eta -- every engine code path.
CONFIGS = [
    ChannelConfig(rho=0.35),
    ChannelConfig(rho=0.75, kappa=2.0, cxl_lat_ns=30.0),
    ChannelConfig(rho=0.8, outstanding=8.0, eta=1.4),
]
STEPS, SEED = 60_000, 3

#: sha256 of the (3, N_BINS) float64 histogram block, captured on the
#: commit BEFORE the harvest mechanism existed.  If one of these moves,
#: harvest_duty=0 is no longer a no-op -- that is a bug, not a rebase.
PRE_HARVEST_SHA = {
    "timestep":
        "62970ce041c2b2d723951f4defc238163c93d5f01d9bffd4f12c8a4f7580310e",
    "event":
        "7d6ea2c7c8fd2e08d616966ca5f0d218b415414263a84a889d73005ef0eafba9",
}

HARVEST_BW = 38.4        # one lendable x8 link ~ one DDR5 channel


def _sha(stats) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(stats.hist, np.float64).tobytes()).hexdigest()


class TestDutyZeroBitIdentity:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_pre_harvest_fingerprint(self, engine):
        st = memsim.simulate(CONFIGS, steps=STEPS, seed=SEED,
                             engine=engine)
        assert _sha(st) == PRE_HARVEST_SHA[engine]

    @pytest.mark.parametrize("engine", memsim.ENGINES)
    @pytest.mark.parametrize("duty,bw", [(0.0, HARVEST_BW), (0.5, 0.0)])
    def test_degenerate_harvest_is_exact_noop(self, engine, duty, bw):
        # duty=0 with bandwidth attached, and duty>0 with nothing to
        # lend: both must keep the no-harvest streams bit-for-bit.
        import dataclasses
        cfgs = [dataclasses.replace(c, harvest_duty=duty,
                                    harvest_bw_gbps=bw) for c in CONFIGS]
        st = memsim.simulate(cfgs, steps=STEPS, seed=SEED, engine=engine)
        assert _sha(st) == PRE_HARVEST_SHA[engine]


class TestEngineAgreementHarvested:
    """Event vs timestep on the HARVESTED law at every anchor."""

    @pytest.fixture(scope="class")
    def cc(self):
        return coaxial.crosscheck_engines(
            steps=120_000, seed=0, reps=32,
            base=ChannelConfig(rho=0.5, harvest_duty=0.5,
                               harvest_bw_gbps=HARVEST_BW))

    def test_ok_at_every_anchor(self, cc):
        assert cc["ok"], (cc["max_abs_mean_err"], cc["max_abs_p90_err"])
        for a in cc["anchors"]:
            assert (abs(a["mean_err"]) <= cc["mean_tol"]
                    or abs(a["mean_z"]) <= cc["se_k"]), a

    def test_harvest_actually_acted(self, cc):
        # The harvested anchors must sit BELOW the unharvested law --
        # otherwise the cross-check just re-proved the duty=0 case.
        plain = coaxial.crosscheck_engines(steps=120_000, seed=0, reps=8)
        for eng in memsim.ENGINES:
            assert (cc["anchors"][-1][f"{eng}_mean_ns"]
                    < plain["anchors"][-1][f"{eng}_mean_ns"])


class TestShardedHarvest:
    @needs4
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_sharded_vs_unsharded_bit_equal(self, engine):
        cfgs = [ChannelConfig(rho=r, harvest_duty=d,
                              harvest_bw_gbps=HARVEST_BW)
                for r, d in ((0.5, 0.3), (0.7, 0.6), (0.85, 0.45),
                             (0.6, 0.0), (0.8, 0.75))]
        a = memsim.simulate(cfgs, steps=30_000, seed=7, engine=engine,
                            devices=1)
        b = memsim.simulate(cfgs, steps=30_000, seed=7, engine=engine,
                            devices=4)
        np.testing.assert_array_equal(a.hist, b.hist)
        np.testing.assert_array_equal(a.mean_ns, b.mean_ns)


class TestSeedAndTraces:
    def test_seed_reproducibility_with_harvest(self):
        cfg = [ChannelConfig(rho=0.7, harvest_duty=0.5,
                             harvest_bw_gbps=HARVEST_BW)]
        a = memsim.simulate(cfg, steps=30_000, seed=9, engine="event")
        b = memsim.simulate(cfg, steps=30_000, seed=9, engine="event")
        np.testing.assert_array_equal(a.hist, b.hist)
        c = memsim.simulate(cfg, steps=30_000, seed=10, engine="event")
        assert not np.array_equal(a.hist, c.hist)

    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_one_trace_per_harvest_grid(self, engine):
        # A harvest_duty axis is a channel_field axis like any other:
        # the whole grid costs ONE trace of its engine, none of the
        # other's.  Width 14 is unique to this test.
        spec = coaxial.distribution_spec(
            rho=(0.55, 0.8),
            harvest_duty=(0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6),
            harvest_bw_gbps=(HARVEST_BW,))
        other = [e for e in memsim.ENGINES if e != engine][0]
        before = {e: memsim.sim_trace_count(e) for e in memsim.ENGINES}
        sw = coaxial.distribution_sweep(spec, steps=21_000, engine=engine,
                                        reps=4)
        assert sw.shape == (2, 7, 1)
        assert memsim.sim_trace_count(engine) == before[engine] + 1
        assert memsim.sim_trace_count(other) == before[other]
        # Harvested cells below their duty=0 twin at the hot anchor
        # (statistical; 4 merged replicas separate 0 vs 0.6 widely).
        hot0 = float(sw.cell(rho=0.8, harvest_duty=0.0).mean_ns)
        hot6 = float(sw.cell(rho=0.8, harvest_duty=0.6).mean_ns)
        assert hot6 < hot0


class TestMonotonicity:
    """Exact pathwise laws of the open loop, hypothesis-driven."""

    def _stat(self, cfg, engine, steps=15_000):
        # Width-1 batches on purpose: streams are LANE-keyed, so two
        # configs in one batch draw different randomness and a pathwise
        # comparison is meaningless.  Two width-1 runs share lane 0's
        # streams exactly (one cached trace per engine covers all
        # examples).
        st = memsim.simulate([cfg], steps=steps, seed=11, engine=engine)
        return float(st.mean_ns[0]), float(st.p90_ns[0])

    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_wait_nonincreasing_in_harvest_bw(self, engine):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=8, deadline=None)
        @given(rho=st.floats(0.3, 0.9),
               bw_lo=st.floats(1.0, 30.0), bw_hi=st.floats(30.0, 120.0))
        def prop(rho, bw_lo, bw_hi):
            # Same salted lent-boundary stream at both bandwidths; more
            # lent bandwidth can only shrink each request's work, so
            # every sample path's wait is <= -- mean and p90 follow.
            lo = self._stat(ChannelConfig(rho=rho, harvest_duty=0.5,
                                          harvest_bw_gbps=bw_lo), engine)
            hi = self._stat(ChannelConfig(rho=rho, harvest_duty=0.5,
                                          harvest_bw_gbps=bw_hi), engine)
            assert hi[0] <= lo[0] + 1e-9
            assert hi[1] <= lo[1] + 1e-9

        prop()

    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_lent_time_never_hurts(self, engine):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=8, deadline=None)
        @given(rho=st.floats(0.3, 0.9), duty=st.floats(0.01, 0.9))
        def prop(rho, duty):
            # vs the duty=0 twin the base draws are untouched (salted
            # harvest streams), so lending any fraction of the time is
            # pathwise <= the unharvested run.
            base = self._stat(ChannelConfig(rho=rho), engine)
            harv = self._stat(ChannelConfig(rho=rho, harvest_duty=duty,
                                            harvest_bw_gbps=HARVEST_BW),
                              engine)
            assert harv[0] <= base[0] + 1e-9
            assert harv[1] <= base[1] + 1e-9

        prop()


class TestModelExposure:
    def test_explicit_4d_lut_rejects_harvesting_design(self):
        from repro.core import queuelut
        lut = queuelut.build_queue_lut(
            rho=(0.2, 0.6), kappa=(1.0, 2.0), outstanding=(8.0, 64.0),
            eta=(1.0, 1.4), steps=4_000)
        assert lut.harvest_grid is None
        with pytest.raises(ValueError, match="no harvest axis"):
            cpu_model.resolve_queue_lut("memsim", lut, harvest=True)

    def test_harvest_lut_has_fifth_axis(self):
        from repro.core import queuelut
        lut = queuelut.build_queue_lut(
            rho=(0.2, 0.6), kappa=(1.0, 2.0), outstanding=(8.0, 64.0),
            eta=(1.0, 1.4), harvest=(0.0, 0.5), steps=4_000)
        assert lut.wait_ns.shape == (2, 2, 2, 2, 2)
        assert tuple(np.asarray(lut.harvest_grid)) == (0.0, 0.5)
        # harvest=0 lands exactly on the duty-0 grid plane.
        w0 = lut.lookup(0.6, 1.0, 64.0, harvest=0.0)[0]
        np.testing.assert_allclose(
            np.asarray(w0), np.asarray(lut.wait_ns)[1, 0, 1, 0, 0])

    def test_any_harvest_peek(self):
        sysa = cpu_model.COAXIAL_4X.as_arrays()
        assert not cpu_model._any_harvest(sysa)
        import dataclasses
        h = dataclasses.replace(cpu_model.COAXIAL_4X, harvest_duty=0.5,
                                harvest_bw_gbps=HARVEST_BW)
        assert cpu_model._any_harvest(h.as_arrays())
        # NaN-masked overrides participate: an override can switch
        # harvesting on for a design whose own fields are zero.
        import jax.numpy as jnp
        ov = {"harvest_duty": jnp.asarray(0.5),
              "harvest_bw_gbps": jnp.asarray(HARVEST_BW)}
        assert cpu_model._any_harvest(sysa, ov)
