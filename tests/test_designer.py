"""The projected-gradient designer and the tail-aware frontier.

Pins the optimizer's contracts:

  * the generic ``projected_ascent`` driver converges to the known
    optimum of an unconstrained toy problem (pure Python, no DES);
  * the projection NEVER lets an iterate leave the box or the
    area-budget feasible set (bisection back to the last feasible
    point), and an infeasible start is refused loudly;
  * end-to-end ``optimize_design`` returns a design inside the budget
    whose p99, re-verified by a direct ``engine="event"`` run at the
    returned point, meets the SLO within the calibration tolerance;
  * the whole ascent costs at most ONE jit trace of the objective
    (``designer_trace_count``), and a second run re-uses the cache;
  * ``SweepResult.pareto(tail=True)`` ranks by (area, mean speedup,
    p99) and refuses the closed form (whose tail is NaN);
  * the ``python -m repro.designer`` CLI exits 0 on a meeting design.
"""

import numpy as np
import pytest

from repro.core import coaxial, designer, queuelut
from repro.core.cpu_model import COAXIAL_2X, COAXIAL_4X, DDR_BASELINE
from repro.core.designer import make_projector, projected_ascent

#: Reduced DES budget for the shared LUT (structure identical to the
#: benchmark build).  Built through ``default_queue_lut`` with the SAME
#: keyword layout the designer uses, so the CLI smoke test below hits
#: the lru cache instead of building a second surface.
LUT_STEPS = 8_000


@pytest.fixture(scope="module")
def lut():
    return queuelut.default_queue_lut(steps=LUT_STEPS, engine="event")


class TestProjectedAscent:
    BOX = {"a": (0.0, 6.0), "b": (-2.0, 2.0)}
    WIDTHS = {"a": 1.0, "b": 1.0}

    @staticmethod
    def _toy_vg(x):
        # Concave quadratic with its unconstrained optimum at (3, 1),
        # strictly inside the box: the known knee of the toy problem.
        val = -((x["a"] - 3.0) ** 2) - (x["b"] - 1.0) ** 2
        g = {"a": -2.0 * (x["a"] - 3.0), "b": -2.0 * (x["b"] - 1.0)}
        return (val, {}), g

    def test_converges_to_known_optimum(self):
        clip = lambda x, prev: {k: float(np.clip(v, *self.BOX[k]))
                                for k, v in x.items()}
        x, traj, converged = projected_ascent(
            {"a": 0.5, "b": -1.5}, self._toy_vg, clip,
            widths=self.WIDTHS, lr=0.3, iters=100, tol=1e-5)
        assert converged
        assert x["a"] == pytest.approx(3.0, abs=1e-2)
        assert x["b"] == pytest.approx(1.0, abs=1e-2)
        # One objective evaluation per recorded iterate, start included.
        assert len(traj) >= 2
        assert traj[-1]["objective"] >= traj[0]["objective"]

    def test_projection_keeps_iterates_inside_budget_box(self):
        box = {"dram_channels": (1.0, 8.0), "llc_mb_per_core": (0.5, 4.0)}
        budget = 1.1
        project = make_projector(box, budget, float("inf"), tie=1.0,
                                 links0=0.0)
        # A gradient that always pushes toward the expensive corner.
        vg = lambda x: ((x["dram_channels"] + x["llc_mb_per_core"], {}),
                        {"dram_channels": 1.0, "llc_mb_per_core": 1.0})
        x, traj, _ = projected_ascent(
            {"dram_channels": 2.0, "llc_mb_per_core": 1.0}, vg, project,
            widths={k: hi - lo for k, (lo, hi) in box.items()},
            lr=0.5, iters=15, tol=1e-6)
        for t in traj:
            for k, (lo, hi) in box.items():
                assert lo - 1e-9 <= t[k] <= hi + 1e-9
            cost = coaxial.design_cost(t["dram_channels"],
                                       t["dram_channels"],
                                       t["llc_mb_per_core"])
            assert float(cost["rel_area"]) <= budget + 1e-6
        # The ascent actually reached the budget surface (it binds).
        final_cost = coaxial.design_cost(x["dram_channels"],
                                         x["dram_channels"],
                                         x["llc_mb_per_core"])
        assert float(final_cost["rel_area"]) == pytest.approx(budget,
                                                              abs=1e-3)

    def test_infeasible_start_refused(self):
        box = {"dram_channels": (1.0, 8.0), "llc_mb_per_core": (0.5, 4.0)}
        project = make_projector(box, 1.05, float("inf"), tie=1.0,
                                 links0=0.0)
        with pytest.raises(ValueError, match="infeasible start"):
            project({"dram_channels": 8.0, "llc_mb_per_core": 4.0}, None)


class TestOptimizeDesign:
    def test_end_to_end_budget_slo_verify_one_trace(self, lut):
        before = designer.designer_trace_count()
        res = designer.optimize_design(
            area_budget=1.2, slo_ms=500.0, iters=8, lut=lut,
            steps=LUT_STEPS, verify_steps=LUT_STEPS)
        # ONE compiled value-and-grad serves every iteration.
        assert designer.designer_trace_count() - before <= 1
        assert res.meets_budget and res.rel_area <= 1.2 + 1e-6
        assert res.meets_slo and res.token_p99_ms <= 500.0
        # The DES re-verification at the optimum agrees with the
        # in-loop model p99 within the calibration-style gate.
        assert res.verify["ok"]
        assert res.verify["engine"] == "event"
        # Returned fields stay inside the frontier box.
        assert 1.0 <= float(res.design.dram_channels) <= 8.0
        assert 0.5 <= float(res.design.llc_mb_per_core) <= 4.0
        assert res.gm_speedup > 1.0
        # Ascent is monotone-or-better end to end vs the knee start.
        assert (res.trajectory[-1]["objective"]
                >= res.trajectory[0]["objective"] - 1e-9)

        # A second run with the same shapes re-uses the compiled
        # objective: no new trace at all.
        before2 = designer.designer_trace_count()
        res2 = designer.optimize_design(
            area_budget=1.15, slo_ms=500.0, iters=2, lut=lut,
            steps=LUT_STEPS, verify_steps=LUT_STEPS)
        assert designer.designer_trace_count() == before2
        assert res2.rel_area <= 1.15 + 1e-6

    def test_slo_without_arch_refused(self, lut):
        with pytest.raises(ValueError, match="arch"):
            designer.optimize_design(slo_ms=10.0, arch=None, lut=lut)

    def test_impossible_budget_refused(self, lut):
        with pytest.raises(ValueError, match="no frontier point"):
            designer.optimize_design(area_budget=0.5, slo_ms=None,
                                     arch=None, lut=lut)


class TestParetoTail:
    @pytest.fixture(scope="class")
    def sw(self, lut):
        spec = coaxial.sweep_spec(
            design=(DDR_BASELINE, COAXIAL_2X, COAXIAL_4X))
        return coaxial.solve_spec(spec, queue_model="memsim", lut=lut)

    def test_points_carry_p99_and_sort_by_cost(self, sw):
        front = sw.pareto(tail=True)
        assert front, "tail frontier must not be empty"
        for p in front:
            assert np.isfinite(p["latency_p99_ns"])
            assert p["latency_p99_ns"] > 0
        costs = [p["rel_area"] for p in front]
        assert costs == sorted(costs)

    def test_tail_frontier_extends_the_2d_frontier(self, sw):
        # A third objective can only shrink the dominance relation, so
        # every 2-D-nondominated point survives and the frontier can
        # only grow.
        assert len(sw.pareto(tail=True)) >= len(sw.pareto())

    def test_closed_form_refused(self):
        sw = coaxial.solve_spec(
            coaxial.sweep_spec(design=(DDR_BASELINE, COAXIAL_4X)))
        with pytest.raises(ValueError, match="memsim"):
            sw.pareto(tail=True)


class TestCLI:
    def test_cli_smoke_exit_zero(self, lut, monkeypatch, capsys):
        # ``lut`` warms the default_queue_lut cache at LUT_STEPS, so the
        # CLI (capped by REPRO_DES_STEPS) reuses the surface.
        monkeypatch.setenv("REPRO_DES_STEPS", str(LUT_STEPS))
        import repro.designer as cli
        rc = cli.main(["--iters", "6"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "DESIGN OK" in out
        assert "verify" in out
