"""The persistent QueueLUT store and its canonical stream contract.

Three layers, each pinned BITWISE (float32 tables under the default jax
config, so equality is exact, not approximate):

* **Canonical streams** -- with caller-owned ``stream_ids`` and the
  width-pinned ``canonical_chunk``, a cell's DES histogram is a pure
  function of (its channel values, its stream id, seed, budget, engine):
  a subset batch reproduces the superset's cells exactly.  This is the
  empirical-but-pinned contract everything else stands on (like the
  sharding bit-identity gate in ``test_shardsim.py``).
* **Incremental builds** -- ``build_queue_lut(base_lut=...)`` simulating
  only the missing cells equals the from-scratch build of the union
  grid, both engines, with and without the harvest axis.
* **The store** -- warm reads are bit-identical and run zero DES
  (``build_queue_lut`` is monkeypatched to explode, and the jit trace
  count is pinned flat); a fingerprint change misses (never serves a
  stale surface); a truncated artifact is quarantined and rebuilt, not
  crashed on.
"""

import numpy as np
import pytest

from repro.core import lutstore, memsim, queuelut
from repro.core.memsim import ChannelConfig

#: Tiny build parameters -- the contract is bitwise, not statistical, so
#: the budget only needs to exercise the code paths.
STEPS, SEED, REPS = 3_000, 0, 1
GRID = dict(rho=(0.2, 0.5, 0.8), kappa=(1.0, 2.0),
            outstanding=(8.0, 64.0), eta=(0.3, 1.0))
SUBGRID = dict(rho=(0.2, 0.8), kappa=(1.0, 2.0),
               outstanding=(8.0, 64.0), eta=(0.3, 1.0))


def lut_equal(a: queuelut.QueueLUT, b: queuelut.QueueLUT) -> bool:
    return all((x is None) == (y is None)
               and (x is None or np.array_equal(np.asarray(x),
                                                np.asarray(y)))
               for x, y in zip(a, b))


@pytest.fixture()
def store(tmp_path, monkeypatch):
    """Fresh on-disk store + empty in-process layer for every test."""
    monkeypatch.setenv(lutstore.ENV_VAR, str(tmp_path / "lut"))
    lutstore.clear_lut_cache()
    yield tmp_path / "lut"
    lutstore.clear_lut_cache()


class TestCanonicalStreams:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_subset_batch_reproduces_superset_cells(self, engine):
        cfgs = [ChannelConfig(rho=r, kappa=k)
                for r in (0.3, 0.6, 0.85) for k in (1.0, 2.2)]
        names = ("rho", "kappa")
        coords = np.asarray([[c.rho, c.kappa] for c in cfgs])
        sids = queuelut.cell_stream_ids(names, coords)
        chunk = memsim.canonical_chunk(engine)
        kw = dict(steps=STEPS, seed=SEED, reps=2, engine=engine,
                  chunk=chunk)
        full = memsim.simulate_cells(memsim.stack_channels(cfgs),
                                     stream_ids=sids, **kw)
        pick = np.asarray([1, 4, 5])
        sub = memsim.simulate_cells(
            memsim.stack_channels([cfgs[i] for i in pick]),
            stream_ids=sids[pick], **kw)
        assert np.array_equal(np.asarray(sub.hist),
                              np.asarray(full.hist)[pick])

    def test_stream_ids_shape_checked(self):
        cfgs = [ChannelConfig(rho=0.3), ChannelConfig(rho=0.6)]
        with pytest.raises(ValueError, match="stream_ids"):
            memsim.simulate_cells(memsim.stack_channels(cfgs),
                                  steps=STEPS,
                                  stream_ids=np.zeros(3, np.uint32))

    def test_cell_ids_keyed_by_coordinates_not_order(self):
        names = ("rho", "kappa")
        a = queuelut.cell_stream_ids(names, [[0.2, 1.0], [0.5, 2.0]])
        b = queuelut.cell_stream_ids(names, [[0.5, 2.0], [0.2, 1.0]])
        assert a[0] == b[1] and a[1] == b[0]
        assert a[0] != a[1]


class TestIncrementalBuild:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    @pytest.mark.parametrize("harvest", [None, (0.0, 0.5)])
    def test_merge_equals_scratch_union(self, engine, harvest):
        kw = dict(steps=STEPS, seed=SEED, reps=REPS, engine=engine,
                  harvest=harvest)
        scratch = queuelut.build_queue_lut(**GRID, **kw)
        base = queuelut.build_queue_lut(**SUBGRID, **kw)
        grown = queuelut.build_queue_lut(**GRID, **kw, base_lut=base)
        assert lut_equal(scratch, grown)

    def test_axis_count_mismatch_rejected(self):
        base = queuelut.build_queue_lut(**SUBGRID, steps=STEPS, reps=REPS,
                                        engine="event")
        with pytest.raises(ValueError, match="harvest"):
            queuelut.build_queue_lut(**GRID, harvest=(0.0, 0.5),
                                     steps=STEPS, reps=REPS,
                                     engine="event", base_lut=base)


class TestStoreRoundTrip:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    @pytest.mark.parametrize("harvest", [None, (0.0, 0.5)])
    def test_warm_read_bit_identical_zero_des(self, store, monkeypatch,
                                              engine, harvest):
        kw = dict(steps=STEPS, seed=SEED, reps=REPS, engine=engine,
                  harvest=harvest)
        cold = queuelut.resolve_lut(**GRID, **kw)
        lutstore.clear_lut_cache()
        # A warm read may neither build nor trace the simulator.
        monkeypatch.setattr(
            queuelut, "build_queue_lut",
            lambda *a, **k: pytest.fail("warm read ran the DES"))
        n0 = memsim.sim_trace_count()
        warm = queuelut.resolve_lut(**GRID, **kw)
        assert memsim.sim_trace_count() == n0
        assert lut_equal(cold, warm)
        assert (warm.harvest_grid is None) == (harvest is None)

    def test_mem_layer_serves_without_disk(self, store):
        kw = dict(steps=STEPS, seed=SEED, reps=REPS, engine="event")
        lut = queuelut.resolve_lut(**GRID, **kw)
        for p in store.glob("qlut-*.npz"):
            p.unlink()
        assert queuelut.resolve_lut(**GRID, **kw) is lut

    def test_fingerprint_mismatch_forces_rebuild(self, store,
                                                 monkeypatch):
        kw = dict(steps=STEPS, seed=SEED, reps=REPS, engine="event")
        lut = queuelut.resolve_lut(**GRID, **kw)
        lutstore.clear_lut_cache()
        monkeypatch.setattr(lutstore, "_fingerprint_memo",
                            "f" * 64)
        builds = []
        real = queuelut.build_queue_lut

        def counting(*a, **k):
            builds.append(1)
            return real(*a, **k)

        monkeypatch.setattr(queuelut, "build_queue_lut", counting)
        rebuilt = queuelut.resolve_lut(**GRID, **kw)
        assert builds, "stale-fingerprint surface was served"
        assert lut_equal(lut, rebuilt)   # the DES itself is unchanged

    def test_corrupt_artifact_quarantined_not_crashed(self, store):
        kw = dict(steps=STEPS, seed=SEED, reps=REPS, engine="event")
        lut = queuelut.resolve_lut(**GRID, **kw)
        lutstore.clear_lut_cache()
        (path,) = store.glob("qlut-*.npz")
        path.write_bytes(path.read_bytes()[:100])     # truncate
        rebuilt = queuelut.resolve_lut(**GRID, **kw)
        assert lut_equal(lut, rebuilt)
        assert list(store.glob("*.corrupt"))
        assert lutstore.gc()["removed"] >= 1          # quarantine swept

    def test_gc_drops_stale_and_aged(self, store, monkeypatch):
        kw = dict(steps=STEPS, seed=SEED, reps=REPS, engine="event")
        queuelut.resolve_lut(**GRID, **kw)
        assert lutstore.gc()["removed"] == 0          # fresh entry kept
        assert lutstore.gc(max_age_days=-1.0)["removed"] == 1
        queuelut.resolve_lut(**SUBGRID, **kw)
        monkeypatch.setattr(lutstore, "_fingerprint_memo", "e" * 64)
        assert lutstore.gc()["removed"] == 1          # stale fingerprint

    def test_store_disabled_still_builds(self, monkeypatch):
        monkeypatch.delenv(lutstore.ENV_VAR, raising=False)
        lutstore.clear_lut_cache()
        lut = queuelut.resolve_lut(**SUBGRID, steps=STEPS, reps=REPS,
                                   engine="event")
        assert lut.wait_ns.shape == (2, 2, 2, 2)


class TestBoundedMemCache:
    def test_bounded_and_clearable(self):
        lutstore.clear_lut_cache()
        for i in range(lutstore.MEM_CACHE_MAX + 3):
            lutstore.cache_put(f"k{i}", object())
        assert len(lutstore._mem_cache) == lutstore.MEM_CACHE_MAX
        assert lutstore.cache_get("k0") is None       # LRU-evicted
        newest = f"k{lutstore.MEM_CACHE_MAX + 2}"
        assert lutstore.cache_get(newest) is not None
        lutstore.clear_lut_cache()
        assert lutstore.cache_get(newest) is None

    def test_default_queue_lut_no_lru_cache(self):
        # The historical unbounded functools.lru_cache is gone.
        assert not hasattr(queuelut.default_queue_lut, "cache_clear")
