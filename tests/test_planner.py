"""Planner tests: the COAXIAL trade on TPU numbers behaves like the paper's
queueing argument -- loaded systems want channels, unloaded want locality."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import memsim, planner
from repro.core.hw import TPU_V5E


class TestContention:
    def test_factor_grows_with_load(self):
        f = [planner.contention_factor(r) for r in (0.0, 0.3, 0.6, 0.9)]
        assert f[0] == 1.0
        assert all(a < b for a, b in zip(f, f[1:]))


class TestDecodePlan:
    def test_big_kv_wants_channels(self):
        """32k-context 123B-class decode: memory-bound -> shard the KV."""
        plan = planner.plan_decode_kv(
            kv_bytes=50e9, qkv_flops=1e11, combine_bytes=1e6)
        assert plan.n_channels > 1
        assert plan.speedup > 2.0

    def test_tiny_state_stays_local(self):
        """RWKV-like tiny state: the premium outweighs queuing -> 1 channel.

        Same math as the paper's single-core case (Fig 9): an unloaded
        memory system does not want the latency premium."""
        plan = planner.plan_decode_kv(
            kv_bytes=5e5, qkv_flops=1e6, combine_bytes=1e6)
        assert plan.n_channels == 1

    def test_more_load_more_channels(self):
        small = planner.plan_decode_kv(kv_bytes=1e8, qkv_flops=1e9,
                                       combine_bytes=1e5)
        big = planner.plan_decode_kv(kv_bytes=1e11, qkv_flops=1e12,
                                     combine_bytes=1e5)
        assert big.n_channels >= small.n_channels

    @settings(max_examples=20, deadline=None)
    @given(kv_gb=st.floats(0.001, 100.0))
    def test_property_chosen_plan_is_optimal(self, kv_gb):
        kv = kv_gb * 1e9
        plan = planner.plan_decode_kv(kv_bytes=kv, qkv_flops=kv / 2,
                                      combine_bytes=1e6)
        for n in (1, 2, 4, 8, 16):
            alt = planner.decode_step_cost(
                kv_bytes=kv, qkv_flops=kv / 2, combine_bytes=1e6, n=n)
            assert plan.cost.total_s <= alt.total_s + 1e-12


class TestParamPlan:
    def test_replication_wins_on_time_when_it_fits(self):
        """ICI < HBM bandwidth: broadcast-consumed params prefer locality.

        This is the planner correctly applying the paper's math in the
        *other* direction: channelizing only pays when sharded state stays
        local (KV/experts), not when every chip re-reads everything."""
        plan = planner.plan_param_channels(
            param_bytes=1e9, step_flops_per_chip=1e12, layers=32)
        assert plan.shards == 1

    def test_capacity_forces_fsdp(self):
        """Params + optimizer state over the HBM budget -> must shard."""
        plan = planner.plan_param_channels(
            param_bytes=10e9, step_flops_per_chip=1e12, layers=32)
        assert plan.shards >= 8   # 80GB resident / 12.8GB budget

    def test_compute_bound_model_indifferent(self):
        plan = planner.plan_param_channels(
            param_bytes=1e6, step_flops_per_chip=1e15, layers=8)
        # compute term dominates everywhere; any plan ~equal, speedup ~1
        assert plan.speedup == pytest.approx(1.0, abs=0.05)


class TestAsymSchedule:
    def test_rw_ratio_drives_split(self):
        s = planner.asym_schedule(read_bytes=2e9, write_bytes=1e9)
        assert s.read_fraction == pytest.approx(2 / 3)
        assert s.rw_ratio == pytest.approx(2.0)

    def test_degenerate(self):
        s = planner.asym_schedule(0.0, 0.0)
        assert s.read_fraction == 0.5


class TestRooflineTerms:
    def test_dominant_selection(self):
        t = planner.roofline_terms(hlo_flops=1e15, hlo_bytes=1e9,
                                   collective_bytes=1e6, chips=256)
        assert t["dominant"] == "compute_s"
        t = planner.roofline_terms(hlo_flops=1e9, hlo_bytes=1e13,
                                   collective_bytes=1e6, chips=256)
        assert t["dominant"] == "memory_s"


class TestMemsimCrossValidation:
    """The DES agrees with the planner's qualitative claims."""

    def test_channelizing_cuts_latency_under_load(self):
        stats = memsim.simulate(
            [memsim.ChannelConfig(rho=0.8),
             memsim.ChannelConfig(rho=0.2, cxl_lat_ns=30.0)],
            steps=100_000)
        # 4x channels (rho/4) + 30ns premium beats the loaded baseline...
        assert stats.mean_ns[1] < stats.mean_ns[0]

    def test_channelizing_loses_when_unloaded(self):
        stats = memsim.simulate(
            [memsim.ChannelConfig(rho=0.05),
             memsim.ChannelConfig(rho=0.0125, cxl_lat_ns=30.0)],
            steps=100_000)
        # ...and loses when the baseline was never queued (Fig 9, 1 core).
        assert stats.mean_ns[1] > stats.mean_ns[0]
