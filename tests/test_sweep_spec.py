"""Named-axis sweep spec: legacy equivalence, multi-axis compile count,
override correctness, tolerant coordinate lookup, pareto and gradients.

The redesign's contract: any legacy ``sweep(designs, iface_lat_grid,
n_active_grid)`` call equals the spec-built sweep slice for slice, a grid
of ANY number of axes costs one XLA trace, and the two new consumers
(``SweepResult.pareto`` / ``design_gradient``) are numerically sane.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import coaxial, cpu_model, hw, workloads
from repro.core.cpu_model import (COAXIAL_4X, DDR_BASELINE, DESIGNS,
                                  design_gradient, geomean, solve,
                                  solve_batch, solve_trace_count)
from repro.core.sweepspec import Axis, sweep_spec


def _spec_equals_batch(designs, lat_grid, core_grid):
    """Legacy positional grid == spec-built sweep, slice for slice."""
    spec = sweep_spec(design=designs, iface_lat_ns=lat_grid,
                      n_active=core_grid)
    sw = coaxial.solve_spec(spec)
    ref = solve_batch(sw.designs, n_active_grid=core_grid,
                      iface_lat_grid=lat_grid)
    assert sw.shape == ref.ipc.shape[:-1]
    for field in ("ipc", "latency_ns", "queue_ns", "rho", "iface_ns"):
        np.testing.assert_allclose(getattr(sw.results, field),
                                   getattr(ref, field), rtol=1e-6,
                                   atol=1e-9, err_msg=field)


class TestLegacyEquivalence:
    def test_deterministic_grid(self):
        _spec_equals_batch(DESIGNS, (None, 50.0), (1, 8, hw.SIM_CORES))

    def test_property_based_equivalence(self):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        lat = st.one_of(st.none(),
                        st.floats(5.0, 200.0, allow_nan=False))
        grids = st.tuples(
            st.lists(st.sampled_from(DESIGNS), min_size=1, max_size=3,
                     unique_by=lambda d: d.name),
            st.lists(lat, min_size=1, max_size=3, unique_by=str),
            st.lists(st.integers(1, hw.SIM_CORES), min_size=1, max_size=2,
                     unique=True))

        @settings(max_examples=10, deadline=None)
        @given(grids)
        def run(g):
            designs, lats, cores = g
            _spec_equals_batch(tuple(designs), tuple(lats), tuple(cores))

        run()


class TestMultiAxis:
    @pytest.fixture(scope="class")
    def sw4(self):
        spec = sweep_spec(design=DESIGNS, iface_lat_ns=(None, 50.0),
                          llc_mb_per_core=(0.5, 2.0, 4.0),
                          kappa=(1.0, 1.6))
        return coaxial.solve_spec(spec)

    def test_four_axis_grid_is_one_trace(self):
        # A flattened cell count no other test uses forces a fresh trace.
        spec = sweep_spec(design=DESIGNS[1:3], iface_lat_ns=(None, 41.0),
                          llc_mb_per_core=(0.5, 1.0, 2.0),
                          kappa=(1.0, 1.3, 1.9))  # baseline prepended: N=54
        before = solve_trace_count()
        sw = coaxial.solve_spec(spec)
        assert sw.shape == (3, 2, 3, 3)
        assert solve_trace_count() == before + 1
        # Same flattened size, different axis values: cache hit.
        coaxial.solve_spec(sweep_spec(
            design=DESIGNS[1:3], iface_lat_ns=(10.0, 90.0),
            llc_mb_per_core=(1.0, 2.0, 8.0), kappa=(1.1, 2.0, 3.0)))
        assert solve_trace_count() == before + 1

    def test_design_field_axis_matches_replaced_design(self, sw4):
        for llc in (0.5, 4.0):
            got = sw4.sel(design="coaxial-4x", iface_lat_ns=None,
                          llc_mb_per_core=llc, kappa=1.6)
            mod = dataclasses.replace(COAXIAL_4X, llc_mb_per_core=llc)
            wl = [dataclasses.replace(w, kappa=1.6)
                  for w in workloads.WORKLOADS]
            ref = solve(mod, workloads=wl)
            np.testing.assert_allclose(got.results.ipc, ref.ipc,
                                       rtol=1e-6, atol=1e-9)

    def test_workload_axis_matches_modified_workloads(self, sw4):
        got = sw4.sel(design=DDR_BASELINE, iface_lat_ns=None,
                      llc_mb_per_core=2.0, kappa=1.0)
        wl = [dataclasses.replace(w, kappa=1.0) for w in workloads.WORKLOADS]
        ref = solve(DDR_BASELINE, workloads=wl)
        np.testing.assert_allclose(got.results.ipc, ref.ipc,
                                   rtol=1e-6, atol=1e-9)

    def test_links_axis_crosses_ddr_cxl_boundary(self):
        # links=0 must flip the is_cxl mask off: the cell equals the plain
        # DDR design with the same channel count.
        sw = sweep_spec(design=(COAXIAL_4X,), links=(0.0,)).solve()
        got = sw.sel(design="coaxial-4x", links=0.0)
        # link bandwidths are zeroed by the mask, not the fields; the cell
        # must equal the equivalently-replaced design solved directly
        # (including its iface_lat_ns field, which non-CXL designs apply
        # unconditionally).
        ref = solve(dataclasses.replace(COAXIAL_4X, links=0))
        np.testing.assert_allclose(got.results.ipc, ref.ipc, rtol=1e-6)
        np.testing.assert_allclose(got.results.iface_ns, ref.iface_ns,
                                   rtol=1e-6)

    def test_sel_partial_keeps_axes(self, sw4):
        sub = sw4.sel(design="coaxial-4x", kappa=1.6)
        assert sub.axis_names == ("iface_lat_ns", "llc_mb_per_core")
        assert sub.shape == (2, 3)
        full = sub.sel(iface_lat_ns=50.0, llc_mb_per_core=2.0)
        assert full.results.ipc.shape == (35,)


class TestCoordinateLookup:
    @pytest.fixture(scope="class")
    def sw(self):
        return coaxial.sweep((DDR_BASELINE, COAXIAL_4X),
                             iface_lat_grid=(None, 50.0))

    def test_int_and_float_resolve_identically(self, sw):
        a = sw.sel(design="coaxial-4x", iface_lat_ns=50)
        b = sw.sel(design="coaxial-4x", iface_lat_ns=50.0)
        np.testing.assert_array_equal(a.results.ipc, b.results.ipc)

    def test_near_miss_from_linspace_resolves(self):
        lats = tuple(np.linspace(10.0, 100.0, 7))  # e.g. 55.00000000000001
        sw = coaxial.sweep((COAXIAL_4X,), iface_lat_grid=lats)
        sw.sel(design="coaxial-4x", iface_lat_ns=55.0)

    def test_unknown_coordinate_lists_valid_ones(self, sw):
        with pytest.raises(KeyError, match=r"valid coordinates.*50\.0"):
            sw.sel(design="coaxial-4x", iface_lat_ns=77.0)

    def test_unconvertible_coordinate_still_keyerror(self, sw):
        # A tuple or string must get the same clear KeyError, not a
        # TypeError out of float().
        with pytest.raises(KeyError, match="valid coordinates"):
            sw.sel(design="coaxial-4x", iface_lat_ns=(50.0,))
        with pytest.raises(KeyError, match="valid coordinates"):
            sw.sel(design="coaxial-4x", iface_lat_ns="fast")

    def test_unknown_axis_lists_axes(self, sw):
        with pytest.raises(KeyError, match="iface_lat_ns"):
            sw.sel(bogus_axis=1.0)

    def test_unpinned_long_axis_is_an_error(self, sw):
        with pytest.raises(KeyError, match="iface_lat_ns"):
            sw.indices(design="coaxial-4x")

    def test_spec_rejects_unknown_field(self):
        with pytest.raises(ValueError, match="kappa"):
            sweep_spec(design=DESIGNS, not_a_field=(1.0,))

    def test_spec_rejects_none_off_iface_axis(self):
        with pytest.raises(ValueError, match="iface_lat_ns"):
            sweep_spec(design=DESIGNS, kappa=(None,))


class TestPareto:
    @pytest.fixture(scope="class")
    def sw(self):
        from benchmarks.pareto_frontier import frontier_sweep
        return frontier_sweep()

    def test_frontier_is_nondominated_and_sorted(self, sw):
        front = sw.pareto(cost="rel_area")
        assert len(front) >= 3
        areas = [p["rel_area"] for p in front]
        gms = [p["geomean_speedup"] for p in front]
        assert areas == sorted(areas)
        assert gms == sorted(gms)  # strictly better or it would be dominated

    def test_frontier_contains_global_best(self, sw):
        front = sw.pareto(cost="rel_area")
        assert front[-1]["geomean_speedup"] == pytest.approx(
            float(np.max(sw.speedup_grid())))

    def test_knee_point_on_frontier(self, sw):
        from benchmarks.pareto_frontier import knee_point
        front = sw.pareto(cost="rel_area")
        assert knee_point(front) in front

    def test_speedup_grid_matches_geomean_grid_without_overrides(self):
        sw = coaxial.sweep((DDR_BASELINE, COAXIAL_4X),
                           n_active_grid=(8, hw.SIM_CORES))
        np.testing.assert_allclose(sw.speedup_grid(), sw.geomean_grid(),
                                   rtol=1e-6)

    def test_bad_cost_key(self, sw):
        with pytest.raises(ValueError, match="rel_area"):
            sw.pareto(cost="dollars")

    def test_sel_pins_coords_for_baseline_reference(self):
        # After sel(n_active=4) the reference must still be solved at 4
        # active cores: the baseline design's own speedup is exactly 1.
        sw = coaxial.sweep((DDR_BASELINE, COAXIAL_4X),
                           n_active_grid=(4, hw.SIM_CORES))
        sub = sw.sel(n_active=4)
        b = sub.design_index(DDR_BASELINE.name)
        np.testing.assert_allclose(sub.speedup_grid()[b], 1.0, rtol=1e-6)
        # And the reduced grid equals the matching slice of the full one.
        k = sw.axis("n_active").index(4)
        np.testing.assert_allclose(sub.speedup_grid(),
                                   sw.speedup_grid()[:, :, k], rtol=1e-6)

    def test_sel_pins_workload_axis_for_reference(self):
        spec = sweep_spec(design=(DDR_BASELINE, COAXIAL_4X),
                          kappa=(1.0, 3.2))
        sw = coaxial.solve_spec(spec)
        sub = sw.sel(kappa=3.2)
        b = sub.design_index(DDR_BASELINE.name)
        np.testing.assert_allclose(sub.speedup_grid()[b], 1.0, rtol=1e-6)
        k = sw.axis("kappa").index(3.2)
        np.testing.assert_allclose(sub.speedup_grid(),
                                   sw.speedup_grid()[:, k], rtol=1e-6)

    def test_sel_pins_design_field_axis_for_costs(self, sw):
        # A pinned LLC override must keep shaping the area accounting.
        sub = sw.sel(llc_mb_per_core=4.0)
        j = sw.axis("llc_mb_per_core").index(4.0)
        full = sw.design_cost_grid()["rel_area"]
        np.testing.assert_allclose(sub.design_cost_grid()["rel_area"],
                                   full[:, j], rtol=1e-12)

    def test_geomean_grid_after_design_sel_delegates(self):
        # The docstring's showcase: sel(design=..., kappa=...) then
        # geomean_grid() -- must equal the full grid's slice, not raise.
        spec = sweep_spec(design=(DDR_BASELINE, COAXIAL_4X),
                          kappa=(1.0, 1.6))
        sw = coaxial.solve_spec(spec)
        got = sw.sel(design="coaxial-4x", kappa=1.6).geomean_grid()
        full = sw.geomean_grid()
        i = sw.design_index("coaxial-4x")
        k = sw.axis("kappa").index(1.6)
        np.testing.assert_allclose(got, full[i, k], rtol=1e-6)

    def test_custom_baseline_reference(self):
        # speedup_grid must reference the sweep's OWN baseline, not the
        # default DDR point: the custom baseline's row is exactly 1.
        sw = coaxial.sweep((DDR_BASELINE, COAXIAL_4X),
                           baseline=cpu_model.COAXIAL_2X)
        gm = sw.speedup_grid()
        b = sw.design_index("coaxial-2x")
        np.testing.assert_allclose(gm[b], 1.0, rtol=1e-6)
        assert gm[sw.design_index("ddr-baseline"), 0] < 1.0

    def test_pareto_after_sel_matches_full_grid_slice(self, sw):
        sub = sw.sel(llc_mb_per_core=1.0)
        front = sub.pareto(cost="rel_area")
        assert all(p["llc_mb_per_core"] == 1.0 for p in front)
        gm = sub.speedup_grid()
        assert front[-1]["geomean_speedup"] == pytest.approx(float(gm.max()))


class TestDesignGradient:
    def test_channels_gradient_positive_at_baseline(self):
        g = design_gradient(DDR_BASELINE, ("dram_channels",))
        assert g["dram_channels"] > 0.0

    def test_coaxial_gradients_signs(self):
        g = design_gradient(COAXIAL_4X,
                            ("dram_channels", "llc_mb_per_core",
                             "iface_lat_ns"))
        assert g["dram_channels"] > 0.0
        assert g["llc_mb_per_core"] > 0.0
        assert g["iface_lat_ns"] < 0.0   # a slower link can't help

    def test_rejects_unknown_field(self):
        with pytest.raises(ValueError, match="is_cxl"):
            design_gradient(COAXIAL_4X, ("is_cxl",))


class TestSatelliteGuards:
    def test_geomean_rejects_nonpositive_with_names(self):
        with pytest.raises(ValueError, match="lbm=0"):
            geomean([1.0, 0.0, 2.0], ("gcc", "lbm", "mcf"))

    def test_geomean_rejects_nan(self):
        with pytest.raises(ValueError, match=r"\[1\]"):
            geomean([1.0, float("nan")])

    def test_geomean_positive_path_unchanged(self):
        assert geomean([2.0, 8.0]) == pytest.approx(4.0)

    def test_by_name_dict_lookup(self):
        assert workloads.by_name("lbm").name == "lbm"
        with pytest.raises(KeyError, match="unknown workload"):
            workloads.by_name("no-such-workload")

    def test_axis_repr_roundtrip(self):
        ax = Axis("kappa", (1.0, 1.6), "workload_field")
        assert ax.index(1.6) == 1
        assert len(ax) == 2
