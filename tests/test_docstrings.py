"""Doctest collection pass over the core public API.

Every public entry point named here must carry a RUNNABLE example in its
docstring (a real ``>>>`` doctest, executed by this module -- not prose
pretending to be an example).  Examples are written with doctest-sized
DES/solve budgets so the whole pass stays cheap.
"""

import doctest

import pytest

from repro.core import coaxial, cpu_model, queuelut, sweepspec

PUBLIC_API = [
    ("coaxial.distribution_sweep", coaxial.distribution_sweep),
    ("coaxial.validate_calibration", coaxial.validate_calibration),
    ("sweepspec.sweep_spec", sweepspec.sweep_spec),
    ("SweepResult.sel", coaxial.SweepResult.sel),
    ("SweepResult.pareto", coaxial.SweepResult.pareto),
    ("cpu_model.design_gradient", cpu_model.design_gradient),
    ("queuelut.QueueLUT", queuelut.QueueLUT),
    ("queuelut.build_queue_lut", queuelut.build_queue_lut),
]


@pytest.mark.parametrize("name,obj", PUBLIC_API,
                         ids=[n for n, _ in PUBLIC_API])
def test_public_api_example_runs(name, obj):
    finder = doctest.DocTestFinder(recurse=False)
    tests = [t for t in finder.find(obj, name) if t.examples]
    assert tests, f"{name} has no runnable docstring example"
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
    for t in tests:
        result = runner.run(t)
        assert result.failed == 0, (
            f"{name}: {result.failed}/{result.attempted} doctest "
            f"example(s) failed (see captured stdout)")
