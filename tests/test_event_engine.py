"""The two-engine contract of memsim.

  * the timestep engine (lane-keyed streams + scan-emitted
    ``(latency, mask)`` + one post-scan histogram) is BIT-IDENTICAL to
    an in-scan-scatter reference that re-derives the stream contract
    (chunk keys split from the seed, one threefry stream per lane via
    ``fold_in``) and every law from scratch -- pinned by comparing
    histograms exactly;
  * the event engine reproduces exactly per seed, costs one kernel
    trace per flattened cell count (its own counter, independent of the
    timestep engine's), honours the closed-loop ``outstanding`` bound,
    and shifts with the CXL premium;
  * the engines agree statistically: event vs timestep mean within 10%
    and p90 within 15% at every ``validate_calibration`` rho anchor
    (``coaxial.crosscheck_engines``), and the event engine passes the
    SAME closed-form mean/p90/stdev gates as the timestep engine;
  * the shared ns-budget knob is engine-neutral and validated
    (``benchmarks.common.des_budget`` / ``des_engine``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coaxial, memsim
from repro.core.memsim import ChannelConfig


class TestTimestepMicroOpt:
    """Satellite: the emission-based timestep engine vs the old scatter."""

    @staticmethod
    def _old_scatter_sim(configs, steps, seed, warmup):
        """The in-scan-scatter reference core: per-step histogram scatter
        carried through one scan per chunk (the historical accumulation
        scheme), re-deriving the production stream contract and every
        law from scratch."""
        c = memsim.stack_channels(configs)
        n = int(c.rho.shape[0])
        # Derived terms spelled out verbatim (NOT via memsim helpers), so
        # a drift anywhere in the production laws fails this pin.
        rate_avg = c.rho / c.t_xfer_ns
        rate_hi = jnp.minimum(c.kappa * rate_avg, 0.98)
        rate_lo = jnp.maximum(
            (rate_avg - c.burst_duty * rate_hi) / (1.0 - c.burst_duty), 0.0)
        p_leave = 1.0 / c.burst_sojourn_ns
        p_enter = p_leave * c.burst_duty / (1.0 - c.burst_duty)
        sn, xb = c.stall_ns, c.stall_break_ns
        a1, a2, cap = c.stall_alpha, c.stall_alpha2, c.stall_max_ns
        q_b = (sn / xb) ** a1
        p_stall = jnp.clip(c.stall_prob * c.eta, 0.0, 0.999)

        def pareto_seg(ratio, a):
            d = a - 1.0
            near_one = jnp.abs(d) < 1e-4
            safe = jnp.where(near_one, 1.0, d)
            return jnp.where(near_one, -jnp.log(ratio),
                             (1.0 - ratio ** safe) / safe)

        stall_mean = (sn + sn * pareto_seg(sn / xb, a1) +
                      q_b * xb * pareto_seg(xb / cap, a2))
        s_small = ((c.t_xfer_ns - p_stall * stall_mean) /
                   (1.0 - p_stall))
        s_small = jnp.maximum(s_small, memsim.MIN_SERVICE_NS)
        bound = c.outstanding * c.t_xfer_ns
        lat0 = c.service_ns + 2.0 + c.cxl_lat_ns

        # The stream contract, re-derived: one chunk key per emission
        # chunk (split from the seed), ONE threefry stream per lane
        # (fold_in of the lane index), five uniforms per step per lane.
        chunk = memsim._ts_chunk_len(n)
        n_chunks = -(-steps // chunk)
        ckeys = jax.random.split(jax.random.PRNGKey(seed), n_chunks)
        record = np.zeros(n_chunks * chunk, np.float32)
        record[warmup:steps] = 1.0

        @jax.jit
        def run_chunk(state, key, rec):
            lanes = jnp.arange(n, dtype=jnp.int32)
            lane_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(lanes)
            u5 = jax.vmap(lambda k: jax.random.uniform(k, (chunk, 5))
                          )(lane_keys)
            switch_u, arrive_u, jitter_u, svc_u, size_u = \
                jnp.moveaxis(u5, -1, 0)                 # each (n, chunk)
            jitter = (jitter_u * 2.0 - 1.0) * c.service_jitter_ns[:, None]
            u = jnp.maximum(size_u, 1e-7)
            stall = jnp.where(u > q_b[:, None],
                              sn[:, None] * u ** (-1.0 / a1[:, None]),
                              xb[:, None] * (q_b[:, None] / u)
                              ** (1.0 / a2[:, None]))
            stall = jnp.minimum(stall, cap[:, None])
            svc = jnp.where(svc_u < p_stall[:, None], stall,
                            s_small[:, None])

            def step(carry, xs):
                sw, au, jit_ns, s, rec1 = xs
                backlog, in_burst, hist = carry
                in_burst = jnp.where(
                    in_burst > 0.5,
                    jnp.where(sw < p_leave, 0.0, 1.0),
                    jnp.where(sw < p_enter, 1.0, 0.0))
                rate = jnp.where(in_burst > 0.5, rate_hi, rate_lo)
                arrive = (au < rate).astype(jnp.float32)
                arrive = arrive * (backlog <= bound).astype(jnp.float32)
                latency = backlog + lat0 + jit_ns
                bin_idx = jnp.clip(
                    (latency / memsim.BIN_NS).astype(jnp.int32),
                    0, memsim.N_BINS - 1)
                hist = hist.at[jnp.arange(n), bin_idx].add(arrive * rec1)
                backlog = jnp.maximum(backlog + arrive * s - 1.0, 0.0)
                return (backlog, in_burst, hist), None

            return jax.lax.scan(
                step, state,
                (switch_u.T, arrive_u.T, jitter.T, svc.T, rec))[0]

        state = (jnp.zeros(n), jnp.ones(n), jnp.zeros((n, memsim.N_BINS)))
        for k in range(n_chunks):
            state = run_chunk(state, ckeys[k],
                              jnp.asarray(record[k * chunk:(k + 1) * chunk]))
        return np.asarray(state[2], np.float64)

    def test_before_after_histograms_bit_identical(self):
        configs = [ChannelConfig(rho=0.35),
                   ChannelConfig(rho=0.75, kappa=2.0, cxl_lat_ns=30.0),
                   ChannelConfig(rho=0.8, outstanding=8.0)]
        for steps, seed in ((20_000, 5), (30_000, 11)):
            old = self._old_scatter_sim(configs, steps, seed, steps // 10)
            new = memsim.simulate(configs, steps=steps, seed=seed)
            np.testing.assert_array_equal(old, new.hist)

    def test_nonchunk_aligned_steps(self):
        # steps that are not a multiple of the emission chunk exercise
        # the padded tail (dummy keys, zero record): still bit-identical.
        configs = [ChannelConfig(rho=0.6)]
        steps = 10_000  # < one chunk
        old = self._old_scatter_sim(configs, steps, 3, steps // 10)
        new = memsim.simulate(configs, steps=steps, seed=3)
        np.testing.assert_array_equal(old, new.hist)


class TestEventEngine:
    def test_exact_seed_reproducibility(self):
        a = memsim.simulate([ChannelConfig(rho=0.6)], steps=30_000, seed=9,
                            engine="event")
        b = memsim.simulate([ChannelConfig(rho=0.6)], steps=30_000, seed=9,
                            engine="event")
        np.testing.assert_array_equal(a.hist, b.hist)
        c = memsim.simulate([ChannelConfig(rho=0.6)], steps=30_000, seed=10,
                            engine="event")
        assert not np.array_equal(a.hist, c.hist)

    def test_one_trace_per_grid_per_engine(self):
        # A fresh flattened cell count forces one trace of the EVENT
        # kernel; the timestep counter must not move.
        spec = coaxial.distribution_spec(rho=(0.25, 0.45, 0.65),
                                         kappa=(1.0, 1.9),
                                         cxl_lat_ns=(0.0, 25.0),
                                         stall_ns=(37.0,))
        before_ev = memsim.sim_trace_count("event")
        before_ts = memsim.sim_trace_count("timestep")
        sw = coaxial.distribution_sweep(spec, steps=25_000, engine="event")
        assert sw.shape == (3, 2, 2, 1)
        assert sw.engine == "event"
        assert memsim.sim_trace_count("event") == before_ev + 1
        assert memsim.sim_trace_count("timestep") == before_ts
        # Same flattened size + budget, different axis values: cache hit.
        coaxial.distribution_sweep(
            coaxial.distribution_spec(rho=(0.15, 0.3, 0.7),
                                      kappa=(1.2, 2.4),
                                      stall_prob=(0.01, 0.02),
                                      outstanding=(64.0,)),
            steps=25_000, engine="event")
        assert memsim.sim_trace_count("event") == before_ev + 1

    def test_outstanding_monotone_closed_loop(self):
        sw = coaxial.distribution_sweep(
            rho=(0.8,), outstanding=(4.0, 1e9), steps=120_000, reps=4,
            engine="event")
        tight = float(sw.cell(rho=0.8, outstanding=4.0).mean_ns)
        open_ = float(sw.cell(rho=0.8, outstanding=1e9).mean_ns)
        assert tight < open_
        # The tight bound caps the admitted backlog at ~outstanding
        # requests' worth of work (plus service terms).
        assert tight < 4.0 * 1.67 + 40.0 + 3 * memsim.BIN_NS

    def test_cxl_premium_shifts_distribution(self):
        s = memsim.simulate(
            [ChannelConfig(rho=0.3), ChannelConfig(rho=0.3, cxl_lat_ns=30.0)],
            steps=150_000, seed=1, reps=8, engine="event")
        assert (s.mean_ns[1] - s.mean_ns[0]
                == pytest.approx(30.0, abs=2.5 * memsim.BIN_NS))

    def test_extreme_jitter_width_clamps_into_edge_bins(self):
        # A jitter wider than the histogram span must clamp (like the
        # timestep engine's bin clip), not crash the convolution.
        s = memsim.simulate(
            [ChannelConfig(rho=0.2, service_jitter_ns=5000.0)],
            steps=20_000, seed=0, engine="event")
        assert np.isfinite(s.hist).all()
        assert s.hist.sum() > 0

    def test_engine_and_budget_validation(self):
        with pytest.raises(ValueError, match="unknown engine"):
            memsim.simulate([ChannelConfig(rho=0.5)], steps=1_000,
                            engine="warp")
        with pytest.raises(ValueError, match="event-engine budget"):
            memsim.simulate_cells(
                memsim.stack_channels([ChannelConfig(rho=0.5)]),
                steps=1_000, events=500, engine="timestep")
        with pytest.raises(ValueError, match="unknown engine"):
            memsim.sim_trace_count("warp")

    def test_jitter_convolution_mass_and_spread(self):
        # The event engine convolves the uniform jitter into the
        # histogram; mass is conserved and the spread matches a sampled
        # jitter within binning.
        narrow = memsim.simulate(
            [ChannelConfig(rho=0.1, service_jitter_ns=0.0)],
            steps=80_000, seed=2, engine="event")
        wide = memsim.simulate(
            [ChannelConfig(rho=0.1, service_jitter_ns=13.5)],
            steps=80_000, seed=2, engine="event")
        assert wide.hist.sum() == pytest.approx(narrow.hist.sum())
        assert float(wide.stdev_ns[0]) > float(narrow.stdev_ns[0])
        assert float(wide.mean_ns[0]) == pytest.approx(
            float(narrow.mean_ns[0]), abs=memsim.BIN_NS)


class TestEngineAgreement:
    """Event vs timestep at the closed-form anchors (the statistical
    counterpart of the timestep engine's bit-identity pin)."""

    @pytest.fixture(scope="class")
    def cc(self):
        return coaxial.crosscheck_engines(steps=200_000, seed=0, reps=64)

    def test_mean_within_10pct_at_every_anchor(self, cc):
        for a in cc["anchors"]:
            assert abs(a["mean_err"]) <= 0.10, (
                f"rho={a['rho']}: event mean {a['event_mean_ns']:.1f} vs "
                f"timestep {a['timestep_mean_ns']:.1f} "
                f"({a['mean_err']:+.1%})")

    def test_p90_within_15pct_at_every_anchor(self, cc):
        for a in cc["anchors"]:
            assert abs(a["p90_err"]) <= 0.15, (
                f"rho={a['rho']}: event p90 {a['event_p90_ns']:.1f} vs "
                f"timestep {a['timestep_p90_ns']:.1f} "
                f"({a['p90_err']:+.1%})")

    def test_ok_flag(self, cc):
        assert cc["ok"]
        assert cc["max_abs_mean_err"] <= cc["mean_tol"]
        assert cc["max_abs_p90_err"] <= cc["p90_tol"]
        assert cc["sweeps"]["event"].engine == "event"

    def test_event_passes_closed_form_gates(self):
        # Same gates as the timestep engine's cross-validation
        # (tests/test_distribution_sweep.py): mean 15%, p90 20%,
        # stdev 125% against queueing.closed_form_stats per anchor.
        val = coaxial.validate_calibration(engine="event", steps=200_000,
                                           seed=3, reps=48)
        assert val["engine"] == "event"
        assert val["ok"], (val["max_abs_mean_err"], val["max_abs_p90_err"],
                           val["max_abs_stdev_err"])


class TestBudgetHelpers:
    def test_des_budget_caps_both_engines(self, monkeypatch):
        from benchmarks import common
        monkeypatch.delenv("REPRO_DES_STEPS", raising=False)
        assert common.des_budget(120_000) == 120_000
        monkeypatch.setenv("REPRO_DES_STEPS", "40000")
        assert common.des_budget(120_000, engine="timestep") == 40_000
        assert common.des_budget(120_000, engine="event") == 40_000
        assert common.des_steps(120_000) == 40_000   # legacy alias
        with pytest.raises(ValueError, match="unknown engine"):
            common.des_budget(120_000, engine="warp")

    def test_des_engine_env_override(self, monkeypatch):
        from benchmarks import common
        monkeypatch.delenv("REPRO_DES_ENGINE", raising=False)
        assert common.des_engine() == "timestep"
        assert common.des_engine("event") == "event"
        monkeypatch.setenv("REPRO_DES_ENGINE", "event")
        assert common.des_engine() == "event"
        monkeypatch.setenv("REPRO_DES_ENGINE", "warp")
        with pytest.raises(ValueError, match="not an engine"):
            common.des_engine()

    def test_events_for_steps_reference_rate(self):
        assert memsim.events_for_steps(200_000) == pytest.approx(
            200_000 * memsim.EVENTS_PER_NS, rel=0.01)
