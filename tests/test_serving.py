"""repro.serving: demand derivation, traffic traces, capacity planning.

The contracts under test:

* every config in ``repro.configs`` yields a finite, positive demand
  vector (encoder-only and attention-free families included);
* derived MPKI is monotone (non-decreasing) in context length;
* registered LLM workloads are first-class: they round-trip through
  ``sweep_spec``/``solve_spec`` with ONE jit trace per grid, and the
  workload registry restores cleanly;
* traffic generators and the CSV loader round-trip;
* the capacity planner runs end-to-end on the event engine and returns
  a concrete, area-sorted verdict list with the DES feeding the p99.
"""

import math

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import coaxial, memsim, workloads
from repro.core.cpu_model import solve_trace_count
from repro.core.devices import (MEASURED_NAMES, register_measured_devices,
                                unregister_measured_devices)
from repro.serving import capacity, demand, traffic


class TestDemand:
    def test_every_config_finite_positive(self):
        for arch in ARCHS:
            d = demand.decode_demand(arch)
            vec = dict(read=d.read_bytes, weight=d.weight_bytes,
                       flops=d.flops_per_token, inst=d.inst_per_token,
                       mpki=d.mpki, ipc=d.ipc, exec_frac=d.exec_frac,
                       ws_mb=d.ws_mb, compute_s=d.compute_s,
                       memory_s=d.memory_s)
            for k, v in vec.items():
                assert math.isfinite(v) and v > 0, (arch, k, v)
            assert math.isfinite(d.wb) and d.wb >= 0, arch

    def test_mpki_monotone_in_context(self):
        for arch in ARCHS:
            ms = [demand.decode_demand(arch, context=c).mpki
                  for c in (1024, 4096, 16384, 65536)]
            assert all(b >= a - 1e-12 for a, b in zip(ms, ms[1:])), \
                (arch, ms)

    def test_attention_archs_strictly_monotone(self):
        ms = [demand.decode_demand("mistral-large-123b", context=c).mpki
              for c in (1024, 4096, 16384)]
        assert ms[0] < ms[1] < ms[2]

    def test_encoder_only_has_no_kv(self):
        d = demand.decode_demand("hubert-xlarge")
        assert d.state_read_bytes == 0.0
        assert d.read_bytes == d.weight_bytes > 0

    def test_recurrent_state_context_free(self):
        a = demand.decode_demand("rwkv6-1.6b", context=1024)
        b = demand.decode_demand("rwkv6-1.6b", context=65536)
        assert a.state_read_bytes == b.state_read_bytes > 0

    def test_batch_amortizes_weights_only(self):
        small = demand.decode_demand("stablelm-1.6b", batch=8)
        big = demand.decode_demand("stablelm-1.6b", batch=256)
        assert big.weight_bytes < small.weight_bytes
        assert big.state_read_bytes == small.state_read_bytes

    def test_streaming_anchor(self):
        # A KV-dominated small model must land in the STREAM-like corner
        # of Table 4's (ipc, exec_frac) plane -- the fit's anchor.
        d = demand.decode_demand("stablelm-1.6b", context=32768)
        assert d.ipc < 0.4
        assert d.exec_frac < 0.15

    def test_rejects_bad_operating_point(self):
        with pytest.raises(ValueError):
            demand.decode_demand("stablelm-1.6b", batch=0)


class TestWorkloadRegistry:
    def test_round_trip_through_solve_spec(self):
        with coaxial.scoped_registry():
            wls = demand.register_llm_workloads(("stablelm-1.6b",))
            w = workloads.by_name("llm-stablelm-1.6b")
            assert w is wls[0] and w.suite == demand.LLM_SUITE
            assert w in workloads.all_workloads()
            spec = coaxial.sweep_spec(design=coaxial.all_designs())
            before = solve_trace_count()
            sw = coaxial.solve_spec(spec,
                                    workloads=workloads.all_workloads())
            assert solve_trace_count() == before + 1    # one trace/grid
            assert "llm-stablelm-1.6b" in sw.names
            i = sw.names.index("llm-stablelm-1.6b")
            cmpn = sw.comparison(coaxial.COAXIAL_4X)
            assert math.isfinite(float(cmpn.speedup[i]))
            assert float(cmpn.speedup[i]) > 0
        assert all(not n.startswith("llm-")
                   for n in (w.name for w in workloads.all_workloads()))

    def test_register_is_idempotent_and_restores(self):
        with coaxial.scoped_registry():
            n0 = len(workloads.all_workloads())
            a = demand.register_llm_workloads(("rwkv6-1.6b",))
            b = demand.register_llm_workloads(("rwkv6-1.6b",))
            assert a == b and len(workloads.all_workloads()) == n0 + 1
            demand.unregister_llm_workloads(("rwkv6-1.6b",))
            assert len(workloads.all_workloads()) == n0

    def test_measured_devices_round_trip(self):
        base = {d.name for d in coaxial.all_designs()}
        assert not (base & set(MEASURED_NAMES))    # opt-in, not default
        with coaxial.scoped_registry():
            register_measured_devices()
            now = {d.name for d in coaxial.all_designs()}
            assert set(MEASURED_NAMES) <= now
        assert {d.name for d in coaxial.all_designs()} == base


class TestTraffic:
    def test_synthetic_diurnal_shape(self):
        t = traffic.synthetic_diurnal(n_epochs=6, peak_rps=2.0,
                                      trough_frac=0.25)
        assert len(t.epochs) == 6
        assert t.peak_rps <= 2.0
        assert min(e.rps for e in t.epochs) >= 0.25 * 2.0 * 0.99
        assert all(e.kappa >= 1.0 for e in t.epochs)

    def test_poisson_burst_seeded(self):
        a = traffic.poisson_burst(seed=7)
        b = traffic.poisson_burst(seed=7)
        c = traffic.poisson_burst(seed=8)
        assert a == b
        assert a != c

    def test_csv_round_trip(self, tmp_path):
        t = traffic.synthetic_diurnal(n_epochs=4)
        path = str(tmp_path / "diurnal.csv")
        t.to_csv(path)
        back = traffic.load_csv(path)
        assert len(back.epochs) == 4
        for e0, e1 in zip(t.epochs, back.epochs):
            assert e1.rps == pytest.approx(e0.rps, rel=1e-5)
            assert e1.kappa == pytest.approx(e0.kappa, rel=1e-5)
        assert traffic.get_trace(path).epochs == back.epochs

    def test_get_trace_names(self):
        assert traffic.get_trace("synthetic-diurnal").name == \
            "synthetic-diurnal"
        with pytest.raises(KeyError):
            traffic.get_trace("no-such-trace")

    @staticmethod
    def _write(tmp_path, body):
        p = tmp_path / "trace.csv"
        p.write_text(body)
        return str(p)

    def test_csv_rejects_nonmonotone_t(self, tmp_path):
        path = self._write(tmp_path,
                           "t_s,rps\n0,1.0\n120,1.5\n60,2.0\n")
        with pytest.raises(ValueError, match=r"trace\.csv:4.*precedes"):
            traffic.load_csv(path)

    def test_csv_rejects_duplicate_t(self, tmp_path):
        path = self._write(tmp_path, "t_s,rps\n0,1.0\n60,1.5\n60,2.0\n")
        with pytest.raises(ValueError,
                           match=r"trace\.csv:4.*duplicates"):
            traffic.load_csv(path)

    def test_csv_rejects_negative_rps(self, tmp_path):
        path = self._write(tmp_path, "t_s,rps\n0,1.0\n60,-0.5\n")
        with pytest.raises(ValueError,
                           match=r"trace\.csv:3.*negative rps"):
            traffic.load_csv(path)

    def test_csv_rejects_sub_floor_kappa(self, tmp_path):
        path = self._write(tmp_path, "0,1.0,1.2\n60,1.0,0.5\n")
        with pytest.raises(ValueError, match=r"trace\.csv:2.*floor"):
            traffic.load_csv(path)

    def test_csv_rejects_garbage_mid_file(self, tmp_path):
        # Only the FIRST row may be a non-numeric header; a later
        # unparseable row is an error with its line number, not a row
        # silently skipped.
        path = self._write(tmp_path, "t_s,rps\n0,1.0\nsixty,2.0\n")
        with pytest.raises(ValueError,
                           match=r"trace\.csv:3.*non-numeric t_s"):
            traffic.load_csv(path)
        path = self._write(tmp_path, "t_s,rps\n0,1.0\n60,fast\n")
        with pytest.raises(ValueError, match=r"trace\.csv:3"):
            traffic.load_csv(path)

    def test_csv_rejects_short_row(self, tmp_path):
        path = self._write(tmp_path, "t_s,rps\n0,1.0\n60\n")
        with pytest.raises(ValueError,
                           match=r"trace\.csv:3.*expected t_s"):
            traffic.load_csv(path)

    def test_csv_accepts_comments_and_header(self, tmp_path):
        path = self._write(tmp_path,
                           "# measured trace\nt_s,rps,kappa\n"
                           "0,1.0,1.3\n\n# gap comment\n60,2.0,1.8\n")
        t = traffic.load_csv(path)
        assert len(t.epochs) == 2
        assert t.epochs[1].rps == 2.0 and t.epochs[1].kappa == 1.8

    def test_scaled(self):
        t = traffic.synthetic_diurnal(peak_rps=1.0)
        assert t.scaled(3.0).peak_rps == pytest.approx(3.0 * t.peak_rps)


class TestCapacity:
    def test_plan_end_to_end(self):
        trace = traffic.synthetic_diurnal(n_epochs=2)
        before = memsim.sim_trace_count("event")
        plan = capacity.plan_capacity(
            ("stablelm-1.6b",), trace, slo_p99_ms=10_000.0,
            batch=32, context=2048, channels=(2, 4), premium_ns=(30.0,),
            tier_splits=(0.0, 0.5), include_registry=False,
            include_measured=True, peak_util=0.6, steps=8_000,
            engine="event")
        # ONE batched DES run fed every (variant, epoch, lane) cell (0
        # new traces if this flat cell count was already compiled).
        assert memsim.sim_trace_count("event") - before <= 1
        assert plan.best is not None          # generous SLO -> a pick
        areas = [v.rel_area for v in plan.verdicts]
        assert areas == sorted(areas)         # cheapest-first contract
        names = {v.name for v in plan.verdicts}
        assert "ddr-baseline" in names
        assert any(n.startswith("cxl-dev-") for n in names)
        assert any("+tier" in n for n in names)
        for v in plan.verdicts:
            assert v.token_p99_ms > 0 and math.isfinite(v.token_p99_ms)
            assert v.access_p99_ns > 0        # DES actually fed the p99
            assert 0.0 < v.peak_rho <= 0.95
        assert plan.best.rel_area == min(
            v.rel_area for v in plan.verdicts if v.meets_slo)

    def test_impossible_slo_has_closest(self):
        trace = traffic.synthetic_diurnal(n_epochs=1)
        plan = capacity.plan_capacity(
            ("stablelm-1.6b",), trace, slo_p99_ms=1e-6, batch=32,
            context=2048, channels=(2,), premium_ns=(30.0,),
            tier_splits=(0.0,), include_registry=False,
            include_measured=False, peak_util=0.5, steps=8_000,
            engine="event")
        assert plan.best is None
        assert plan.closest.token_p99_ms == min(
            v.token_p99_ms for v in plan.verdicts)

    def test_tiered_area_between_pure_points(self):
        # A 50/50 DDR+CXL tier pays more pins than pure-CXL but its area
        # sits near the pure points (cores+LLC dominate Table 1).
        designs = capacity.candidate_designs(
            channels=(4,), premium_ns=(30.0,), include_registry=False,
            include_measured=False)
        cxl4 = next(d for d in designs if d.name.startswith("cxl-4ch"))
        variants = capacity._variants([cxl4], (0.0, 0.5))
        pure = next(v for v in variants if v.tier_split == 0.0)
        tier = next(v for v in variants if v.tier_split == 0.5)
        assert tier.rel_pins > pure.rel_pins
        assert tier.n_hot == 2 and tier.n_cold == 2
        assert abs(tier.rel_area - pure.rel_area) < 0.25

    def test_capacity_scales_with_channels(self):
        designs = {d.name: d for d in capacity.candidate_designs(
            channels=(2, 8), premium_ns=(30.0,), include_registry=False,
            include_measured=False)}
        c2 = capacity.capacity_gbps(designs["cxl-2ch-llc1-30ns"])
        c8 = capacity.capacity_gbps(designs["cxl-8ch-llc1-30ns"])
        assert c8 == pytest.approx(4.0 * c2)


class TestCLI:
    def test_plan_cli_smoke(self, capsys, monkeypatch):
        monkeypatch.setenv("REPRO_DES_STEPS", "8000")
        from repro.serving import plan as plan_cli
        rc = plan_cli.main([
            "--arch", "stablelm-1.6b", "--slo-p99-ms", "10000",
            "--trace", "synthetic-diurnal", "--batch", "32",
            "--context", "2048", "--channels", "2", "4",
            "--premium-ns", "30", "--tier-splits", "0",
            "--no-measured"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "PICK " in out and "channels=" in out
