"""Pin every assigned architecture config to its assignment-sheet numbers.

The dry-run exercises these configs at full size; this test makes sure no
refactor silently drifts a dimension.
"""

import pytest

from repro.configs import ARCHS, SHAPES, get_config

# (arch, layers, d_model, heads, kv, d_ff, vocab) straight from the sheet.
ASSIGNED = {
    "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_assigned_dimensions(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab == v


def test_moe_expert_counts():
    olmoe = get_config("olmoe-1b-7b")
    assert (olmoe.n_experts, olmoe.top_k) == (64, 8)
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert (phi.n_experts, phi.top_k) == (16, 2)


def test_zamba2_ssm_state():
    z = get_config("zamba2-2.7b")
    assert z.ssm_state == 64
    assert z.family == "hybrid"
    assert z.n_layers % z.attn_every == 0


def test_qwen2_vl_mrope():
    q = get_config("qwen2-vl-72b")
    assert q.mrope_sections is not None
    assert sum(q.mrope_sections) == q.resolved_head_dim // 2


def test_hubert_encoder_only():
    h = get_config("hubert-xlarge")
    assert h.encoder_only and h.embed_inputs
    assert not h.has_decode


def test_assigned_shapes():
    grid = {s.name: (s.seq_len, s.global_batch) for s in SHAPES}
    assert grid == {
        "train_4k": (4096, 256),
        "prefill_32k": (32768, 32),
        "decode_32k": (32768, 128),
        "long_500k": (524288, 1),
    }


def test_param_counts_in_expected_band():
    """Model names encode sizes: verify the spec trees land in-band."""
    bands = {
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "starcoder2-3b": (2.8e9, 3.5e9),
        "mistral-large-123b": (1.05e11, 1.4e11),
        "olmoe-1b-7b": (5.5e9, 8.5e9),
        "phi3.5-moe-42b-a6.6b": (3.4e10, 4.8e10),
        "qwen2-vl-72b": (6.0e10, 8.2e10),
        "rwkv6-1.6b": (1.1e9, 2.2e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    active = phi.active_param_count()
    total = phi.param_count()
    assert active < 0.3 * total          # 2 of 16 experts active
    assert 5e9 <= active <= 9e9          # "a6.6b"
