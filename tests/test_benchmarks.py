"""Smoke the benchmark harness itself: CSV contract + roofline loader."""

import io
import json
import sys

import pytest


def _capture(fn):
    buf = io.StringIO()
    old = sys.stdout
    sys.stdout = buf
    try:
        fn()
    finally:
        sys.stdout = old
    return buf.getvalue()


def test_fig3_csv_contract():
    from benchmarks import fig3_variance
    out = _capture(fig3_variance.main)
    rows = [l for l in out.strip().splitlines() if l]
    assert len(rows) == 3
    for row in rows:
        name, us, derived = row.split(",")
        assert name.startswith("fig3.")
        float(us)
        assert 0.5 < float(derived) < 1.0


def test_table2_csv_contract():
    from benchmarks import table2_designs
    out = _capture(table2_designs.main)
    assert "table2.coaxial-5x.rel_area,0.0,1.166" in out


def test_roofline_loader_tolerates_foreign_json(tmp_path, monkeypatch):
    """int8_proof.json & co. in results/dryrun must not break the loader."""
    from benchmarks import roofline
    monkeypatch.setattr(roofline, "RESULTS_DIR", str(tmp_path))
    with open(tmp_path / "int8_proof.json", "w") as f:
        json.dump({"f32": {}, "int8": {}}, f)
    with open(tmp_path / "cell.json", "w") as f:
        json.dump({"mesh": "16x16", "status": "ok", "chips": 256,
                   "arch": "stablelm-1.6b", "shape": "train_4k",
                   "flops_per_chip": 1e12, "bytes_per_chip": 1e12,
                   "hbm_bytes_per_chip": 5e11,
                   "collectives": {"total": 1e10}, "memory": {},
                   "variant": "baseline"}, f)
    cells = roofline.load_cells("16x16")
    assert len(cells) == 1
    terms = roofline.analyze(cells[0])
    assert terms["dominant"] in ("compute_s", "memory_s", "collective_s")


def test_model_flops_shapes():
    from benchmarks.roofline import model_flops
    train = model_flops("stablelm-1.6b", "train_4k")
    prefill = model_flops("stablelm-1.6b", "prefill_32k")
    decode = model_flops("stablelm-1.6b", "decode_32k")
    assert train > prefill > decode          # 6ND*1M > 2ND*1M > 2ND*128
    # MoE counts active params only
    moe_train = model_flops("olmoe-1b-7b", "train_4k")
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b")
    assert moe_train == pytest.approx(
        6 * cfg.active_param_count() * 4096 * 256, rel=1e-6)
