"""Smoke the benchmark harness itself: CSV contract + roofline loader."""

import io
import json
import sys

import pytest


def _capture(fn):
    buf = io.StringIO()
    old = sys.stdout
    sys.stdout = buf
    try:
        fn()
    finally:
        sys.stdout = old
    return buf.getvalue()


def test_fig3_csv_contract():
    from benchmarks import fig3_variance
    out = _capture(fig3_variance.main)
    rows = [l for l in out.strip().splitlines() if l]
    assert len(rows) == 3
    for row in rows:
        name, us, derived = row.split(",")
        assert name.startswith("fig3.")
        float(us)
        assert 0.5 < float(derived) < 1.0


def test_table2_csv_contract():
    from benchmarks import table2_designs
    out = _capture(table2_designs.main)
    assert "table2.coaxial-5x.rel_area,0.0,1.166" in out


def test_roofline_loader_tolerates_foreign_json(tmp_path, monkeypatch):
    """int8_proof.json & co. in results/dryrun must not break the loader."""
    from benchmarks import roofline
    monkeypatch.setattr(roofline, "RESULTS_DIR", str(tmp_path))
    with open(tmp_path / "int8_proof.json", "w") as f:
        json.dump({"f32": {}, "int8": {}}, f)
    with open(tmp_path / "cell.json", "w") as f:
        json.dump({"mesh": "16x16", "status": "ok", "chips": 256,
                   "arch": "stablelm-1.6b", "shape": "train_4k",
                   "flops_per_chip": 1e12, "bytes_per_chip": 1e12,
                   "hbm_bytes_per_chip": 5e11,
                   "collectives": {"total": 1e10}, "memory": {},
                   "variant": "baseline"}, f)
    cells = roofline.load_cells("16x16")
    assert len(cells) == 1
    terms = roofline.analyze(cells[0])
    assert terms["dominant"] in ("compute_s", "memory_s", "collective_s")


def _bench_point(rev, unix_time, sections, env=None, failures=0):
    """A minimal synthetic BENCH_<rev>.json trajectory point."""
    return dict(
        rev=rev, unix_time=unix_time,
        env={**dict(devices=1, REPRO_DES_STEPS="40000",
                    REPRO_DES_ENGINE="event", REPRO_DES_DEVICES=None,
                    compile_cache=None, only=None), **(env or {})},
        totals=dict(seconds=sum(s["seconds"] for s in sections.values()),
                    rows=0, failures=failures,
                    traces={"timestep": 0, "event": 0}),
        sections={name: dict(status="ok", rows=0,
                             traces={"timestep": 0, "event": 0}, **s)
                  for name, s in sections.items()},
        rows=[])


def _write_points(tmp_path, points):
    import os
    for i, (fname, pt) in enumerate(points):
        with open(tmp_path / fname, "w") as f:
            json.dump(pt, f)
        # Adversarial mtimes (REVERSE of the true order): a checkout or
        # artifact download rewrites them, so ordering must not use them.
        os.utime(tmp_path / fname, (1e9 - i, 1e9 - i))


def test_bench_points_dirty_after_base(tmp_path):
    """Trajectory order follows recorded unix_time, dirty points after
    their base rev -- regardless of file mtimes."""
    from benchmarks.report import _load_bench_points, bench_diff_table
    sec = {"drift_headline": dict(seconds=1.0)}
    _write_points(tmp_path, [
        ("BENCH_aaa.json", _bench_point("aaa", 100, sec)),
        ("BENCH_aaa-dirty1.json", _bench_point("aaa-dirty1", 100, sec)),
        ("BENCH_aaa-dirty2.json", _bench_point("aaa-dirty2", 100, sec)),
        ("BENCH_bbb.json", _bench_point("bbb", 200, sec)),
    ])
    names = [n for n, _ in _load_bench_points(str(tmp_path))]
    assert names == ["BENCH_aaa.json", "BENCH_aaa-dirty1.json",
                     "BENCH_aaa-dirty2.json", "BENCH_bbb.json"]
    out = bench_diff_table(str(tmp_path))
    assert "Current: `BENCH_bbb.json`" in out
    assert "Prior:   `BENCH_aaa-dirty2.json`" in out


def test_bench_regression_gate(tmp_path):
    """>threshold wall-clock growth vs the latest COMPARABLE prior."""
    from benchmarks.report import bench_regressions

    def pts(*entries):
        return [(f"BENCH_{p['rev']}.json", p) for p in entries]

    slow = _bench_point("new", 300, {"a": dict(seconds=1.4),
                                     "b": dict(seconds=0.9)})
    base = _bench_point("old", 100, {"a": dict(seconds=1.0),
                                     "b": dict(seconds=1.0)})
    # One point: nothing to compare.
    assert bench_regressions(pts(slow))["regressions"] == []
    # +40% on section a regresses; -10% on b does not.
    gate = bench_regressions(pts(base, slow), threshold=0.30)
    assert gate["prior"] == "BENCH_old.json"
    assert [r["section"] for r in gate["regressions"]] == ["a"]
    assert gate["regressions"][0]["pct"] == pytest.approx(40.0)
    # +20% stays under a 0.30 threshold.
    ok = _bench_point("new", 300, {"a": dict(seconds=1.2)})
    assert bench_regressions(pts(base, ok), 0.30)["regressions"] == []
    # A prior with different env knobs is not comparable -- the gate
    # skips it and stays silent when no comparable prior exists.
    other = _bench_point("smoke", 200, {"a": dict(seconds=0.1)},
                         env={"REPRO_DES_STEPS": "6000"})
    assert bench_regressions(pts(other, slow))["prior"] is None
    # ... and with both present, the LATEST comparable prior wins.
    gate = bench_regressions(pts(base, other, slow), 0.30)
    assert gate["prior"] == "BENCH_old.json"
    # Errored sections never gate.
    err = _bench_point("new", 300, {"a": dict(seconds=9.9)})
    err["sections"]["a"]["status"] = "error"
    assert bench_regressions(pts(base, err), 0.30)["regressions"] == []


def test_model_flops_shapes():
    from benchmarks.roofline import model_flops
    train = model_flops("stablelm-1.6b", "train_4k")
    prefill = model_flops("stablelm-1.6b", "prefill_32k")
    decode = model_flops("stablelm-1.6b", "decode_32k")
    assert train > prefill > decode          # 6ND*1M > 2ND*1M > 2ND*128
    # MoE counts active params only
    moe_train = model_flops("olmoe-1b-7b", "train_4k")
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b")
    assert moe_train == pytest.approx(
        6 * cfg.active_param_count() * 4096 * 256, rel=1e-6)
