"""Axis-kind contract: every registered sweep-axis kind drives one tiny
grid through the real solvers and honours the same selection API.

One parametrized case per kind -- design, iface_lat, n_active,
design_field (including the harvest pair), workload_field, queue_model
on the cpu target; channel_field (including the harvest pair) on the
memsim target under BOTH engines -- plus a completeness guard so a
future axis kind cannot ship without a contract case here.
"""

import numpy as np
import pytest

from repro.core import coaxial, memsim, queuelut, sweepspec
from repro.core.coaxial import COAXIAL_4X, DDR_BASELINE

#: (axis name, values, expected kind) -- one per CPU-target axis kind,
#: with the harvest design fields riding as extra design_field cases.
CPU_AXES = [
    ("iface_lat_ns", (None, 50.0), sweepspec.KIND_IFACE),
    ("n_active", (4, 12), sweepspec.KIND_N_ACTIVE),
    ("dram_channels", (8.0, 10.0), sweepspec.KIND_DESIGN_FIELD),
    ("harvest_duty", (0.0, 0.5), sweepspec.KIND_DESIGN_FIELD),
    ("harvest_bw_gbps", (0.0, 38.4), sweepspec.KIND_DESIGN_FIELD),
    ("mpki", (5.0, 20.0), sweepspec.KIND_WORKLOAD_FIELD),
]


class TestCpuAxisContract:
    @pytest.mark.parametrize("name,values,kind", CPU_AXES,
                             ids=[c[0] for c in CPU_AXES])
    def test_axis_solves_and_selects(self, name, values, kind):
        spec = sweepspec.sweep_spec(design=(DDR_BASELINE, COAXIAL_4X),
                                    **{name: values})
        ax = spec.axis(name)
        assert ax.kind == kind
        assert ax.coords == values
        sw = spec.solve(queue_model="closed_form")
        assert sw.axis_names == ("design", name)
        assert sw.results.ipc.shape == (2, len(values), len(sw.names))
        assert np.isfinite(sw.results.ipc).all()
        # sel() drops exactly the pinned axis and slices every leaf.
        sub = sw.sel(**{name: values[-1]})
        assert sub.axis_names == ("design",)
        np.testing.assert_array_equal(sub.results.ipc,
                                      sw.results.ipc[:, -1])
        # ... and the design axis selects by name, dropping to a
        # zero-axis result with only the workload dimension left.
        one = sub.sel(design="coaxial-4x")
        assert one.axis_names == ()
        assert one.results.ipc.shape == (len(sw.names),)
        np.testing.assert_array_equal(one.results.ipc,
                                      sw.results.ipc[1, -1])

    def test_design_axis_prepends_baseline(self):
        sw = sweepspec.sweep_spec(design=(COAXIAL_4X,)).solve()
        assert sw.axis("design").coords[0] == DDR_BASELINE.name

    def test_queue_model_axis_stacks_backends(self):
        lut = queuelut.build_queue_lut(
            rho=(0.2, 0.6), kappa=(1.0, 2.0), outstanding=(8.0, 64.0),
            eta=(1.0, 1.4), steps=4_000)
        spec = sweepspec.sweep_spec(
            design=(DDR_BASELINE, COAXIAL_4X),
            queue_model=("closed_form", "memsim"))
        assert spec.axis("queue_model").kind == sweepspec.KIND_QUEUE_MODEL
        sw = spec.solve(lut=lut)
        assert sw.axis_names == ("design", "queue_model")
        closed = sw.sel(queue_model="closed_form")
        mem = sw.sel(queue_model="memsim")
        assert closed.results.ipc.shape == mem.results.ipc.shape
        # Different backends, different queue law -- the stacked cells
        # must not be copies of one pass.
        assert not np.allclose(closed.results.queue_ns,
                               mem.results.queue_ns)
        assert sw.lut is lut


class TestChannelAxisContract:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_channel_axes_one_trace_per_engine(self, engine):
        # Width 22 (11 x 2 x 1) is unique to this test, so the
        # one-trace-per-grid pin is exact for BOTH counters.
        spec = coaxial.distribution_spec(
            rho=tuple(np.linspace(0.2, 0.8, 11).round(3)),
            harvest_duty=(0.0, 0.4),
            harvest_bw_gbps=(38.4,))
        assert spec.target == "memsim"
        for ax in spec.axes:
            assert ax.kind == sweepspec.KIND_CHANNEL_FIELD
        other = [e for e in memsim.ENGINES if e != engine][0]
        before = {e: memsim.sim_trace_count(e) for e in memsim.ENGINES}
        sw = spec.solve(steps=19_000, engine=engine)
        assert memsim.sim_trace_count(engine) == before[engine] + 1
        assert memsim.sim_trace_count(other) == before[other]
        assert sw.shape == (11, 2, 1)
        assert sw.engine == engine
        # sel() pins coordinates tolerantly and drops axes.
        sub = sw.sel(harvest_duty=0.4)
        assert sub.axis_names == ("rho", "harvest_bw_gbps")
        cell = sw.cell(rho=0.5, harvest_duty=0.0)
        assert np.isfinite(float(cell.mean_ns))
        # curve() keeps the one unpinned axis, in axis order.
        x, y = sw.curve("rho", harvest_duty=0.0, harvest_bw_gbps=38.4)
        assert x.shape == y.shape == (11,)


def test_every_axis_kind_has_a_contract_case():
    """A new KIND_* constant must gain a case in this file."""
    registered = {v for k, v in vars(sweepspec).items()
                  if k.startswith("KIND_")}
    covered = ({kind for _, _, kind in CPU_AXES}
               | {sweepspec.KIND_DESIGN, sweepspec.KIND_QUEUE_MODEL,
                  sweepspec.KIND_CHANNEL_FIELD})
    assert covered == registered


def test_harvest_axes_are_first_class():
    """The harvest pair sweeps on BOTH targets without special cases."""
    for f in ("harvest_duty", "harvest_bw_gbps"):
        assert f in sweepspec.DESIGN_FIELDS
        assert f in sweepspec.CHANNEL_FIELDS
        assert f in sweepspec.AXIS_NAMES
        assert sweepspec._kind_of(f) == sweepspec.KIND_DESIGN_FIELD
