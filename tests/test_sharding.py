"""Sharding-rule unit tests: divisibility fallbacks, mesh-axis folding,
cache layouts.  These run on the single real CPU device with tiny meshes --
the 256/512-device behavior is exercised by the dry-run artifacts."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models.config import smoke_variant
from repro.models.model import Model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestSpecFor:
    def test_divisible_dims_shard(self, mesh):
        spec = shd.spec_for((64, 32), ("embed", "mlp"),
                            shd.train_rules(mesh, get_config("stablelm-1.6b")),
                            mesh)
        # spec_for unwraps single-axis tuples, and PartitionSpec does not
        # normalize ('data',) == 'data' -- compare the unwrapped form.
        assert spec == P("data", "model")

    def test_undivisible_dim_replicates(self):
        m = jax.make_mesh((1,), ("model",))
        # vocab 504 on a 16-wide model axis would not divide; emulate with
        # a fake rule table demanding a 'model' axis of size 1 but dim 0.
        rules = {"vocab": ("model",)}
        spec = shd.spec_for((504,), ("vocab",), rules, m)
        assert spec == P("model")  # divides by 1 -> sharded

    def test_fsdp_axes_fold_pod(self):
        m2 = jax.make_mesh((1, 1), ("data", "model"))
        assert shd.fsdp_axes(m2) == ("data",)


class TestParamShardings:
    @pytest.mark.parametrize("arch", ["stablelm-1.6b", "olmoe-1b-7b",
                                      "rwkv6-1.6b", "hubert-xlarge"])
    def test_tree_matches_params(self, mesh, arch):
        cfg = smoke_variant(get_config(arch))
        model = Model(cfg)
        rules = shd.train_rules(mesh, cfg)
        sh = shd.param_shardings(model, mesh, rules)
        params = model.init(jax.random.PRNGKey(0))
        # same tree structure; device_put must succeed
        placed = jax.device_put(params, sh)
        jax.tree_util.tree_map(lambda a, b: None, params, placed)

    def test_moe_ep_rules(self, mesh):
        cfg = get_config("olmoe-1b-7b")
        rules = shd.train_rules(mesh, cfg)
        assert rules["experts"] == ("model",)
        assert rules["mlp"] is None     # EP owns the axis

    def test_decode_rules_replicate_embed(self, mesh):
        cfg = get_config("stablelm-1.6b")
        rules = shd.decode_rules(mesh, cfg)
        assert rules["embed"] is None


class TestCacheShardings:
    def test_kv_seq_sharded(self, mesh):
        cfg = smoke_variant(get_config("stablelm-1.6b"))
        model = Model(cfg)
        cache = jax.eval_shape(lambda: model.make_cache(4, 64))
        sh = shd.cache_shardings(cfg, mesh, cache, kv_channels=True)
        kspec = sh["k"].spec
        assert kspec[2] == "model"      # sequence axis channelized

    def test_kv_channels_off(self, mesh):
        cfg = smoke_variant(get_config("stablelm-1.6b"))
        model = Model(cfg)
        cache = jax.eval_shape(lambda: model.make_cache(4, 64))
        sh = shd.cache_shardings(cfg, mesh, cache, kv_channels=False)
        assert sh["k"].spec[2] is None

    def test_ssm_cache_batch_only(self, mesh):
        cfg = smoke_variant(get_config("rwkv6-1.6b"))
        model = Model(cfg)
        cache = jax.eval_shape(lambda: model.make_cache(4, 64))
        sh = shd.cache_shardings(cfg, mesh, cache)
        assert sh["wkv"].spec[1] is not None or sh["wkv"].spec[1] is None
        # no seq axis to shard; spec length matches rank
        assert len(sh["wkv"].spec) <= 5
