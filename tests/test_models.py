"""Model-zoo correctness: algebraic paths vs naive references, and
prefill/decode consistency against the training forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import SyntheticDataset
from repro.models import Model, smoke_variant
from repro.models import attention, ssm
from repro.models.config import ModelConfig

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Attention: flash (online-softmax scan) vs O(S^2) reference.
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("sq,sk,hq,hk,d", [
        (64, 64, 4, 4, 16),
        (128, 128, 8, 2, 32),   # GQA 4:1
        (32, 128, 4, 1, 16),    # cross: q shorter than kv (suffix-aligned)
    ])
    def test_matches_reference(self, sq, sk, hq, hk, d):
        q = _rand(0, 2, sq, hq, d)
        k = _rand(1, 2, sk, hk, d)
        v = _rand(2, 2, sk, hk, d)
        ref = attention.reference_attention(q, k, v, causal=True)
        out = attention.flash_attention(q, k, v, causal=True, chunk=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        q, k, v = _rand(0, 2, 64, 4, 16), _rand(1, 2, 64, 2, 16), \
            _rand(2, 2, 64, 2, 16)
        ref = attention.reference_attention(q, k, v, causal=False)
        out = attention.flash_attention(q, k, v, causal=False, chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_decode_matches_last_row(self):
        """decode_attention on a full cache == last row of full attention."""
        s, hq, hk, d = 96, 4, 2, 16
        q_all = _rand(0, 2, s, hq, d)
        k, v = _rand(1, 2, s, hk, d), _rand(2, 2, s, hk, d)
        full = attention.reference_attention(q_all, k, v, causal=True)
        lens = jnp.full((2,), s, jnp.int32)
        dec = attention.decode_attention(q_all[:, -1:], k, v, lens)
        np.testing.assert_allclose(np.asarray(dec[:, 0]),
                                   np.asarray(full[:, -1]), atol=2e-5,
                                   rtol=2e-5)

    def test_decode_masks_past_length(self):
        """Cache positions beyond cache_len must not affect the output."""
        s, hq, hk, d = 64, 2, 2, 8
        q = _rand(0, 1, 1, hq, d)
        k, v = _rand(1, 1, s, hk, d), _rand(2, 1, s, hk, d)
        lens = jnp.array([40], jnp.int32)
        out1 = attention.decode_attention(q, k, v, lens)
        k2 = k.at[:, 40:].set(99.0)
        v2 = v.at[:, 40:].set(-99.0)
        out2 = attention.decode_attention(q, k2, v2, lens)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# Mamba2 SSD: chunked algorithm vs naive recurrence.
# ---------------------------------------------------------------------------

def _naive_ssd(xh, dt, a, bmat, cmat):
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    state = np.zeros((b, h, n, p))
    ys = np.zeros_like(np.asarray(xh))
    xh, dt, bmat, cmat = map(np.asarray, (xh, dt, bmat, cmat))
    a = np.asarray(a)
    for t in range(s):
        da = np.exp(dt[:, t] * a[None, :])                  # (B,H)
        upd = np.einsum("bn,bh,bhp->bhnp", bmat[:, t], dt[:, t], xh[:, t])
        state = state * da[..., None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", cmat[:, t], state)
    return ys, state


class TestSSD:
    @pytest.mark.parametrize("s", [64, 128, 256])
    def test_chunked_matches_recurrence(self, s):
        b, h, p, n = 2, 3, 8, 4
        xh = _rand(0, b, s, h, p)
        dt = jax.nn.softplus(_rand(1, b, s, h))
        a = -jnp.exp(_rand(2, h) * 0.5)
        bmat = _rand(3, b, s, n)
        cmat = _rand(4, b, s, n)
        y, final = ssm._ssd_chunked(xh, dt, a, bmat, cmat)
        y_ref, final_ref = _naive_ssd(xh, dt, a, bmat, cmat)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4,
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(final), final_ref, atol=1e-4,
                                   rtol=1e-4)

    def test_decode_continues_prefill(self):
        """mamba_apply(decode) after mamba_apply(train) == longer train."""
        cfg = smoke_variant(get_config("zamba2-2.7b"))
        from repro.models.layers import init_params
        from repro.models.ssm import ssm_specs
        specs = ssm_specs(cfg, layered=False, n_layers=None)
        # strip the leading layer axis by using layered=False
        params = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
        x = _rand(5, 2, 65, cfg.d_model)
        full, _ = ssm.mamba_apply(cfg, params, x[:, :64])
        # run 64 then 1 more with carried state
        y1, (st, cv) = ssm.mamba_apply(cfg, params, x[:, :64])
        y2, _ = ssm.mamba_apply(cfg, params, x[:, 64:65], st, cv)
        full65, _ = ssm.mamba_apply(cfg, params, x)
        np.testing.assert_allclose(np.asarray(y2[:, 0]),
                                   np.asarray(full65[:, 64]), atol=1e-3,
                                   rtol=1e-3)


# ---------------------------------------------------------------------------
# Whole-model: smoke every arch, decode == teacher-forced forward.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = smoke_variant(get_config(arch))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = SyntheticDataset(cfg, batch=2, seq=32).batch_at(0)
        loss, metrics = jax.jit(m.loss)(params, batch)
        assert np.isfinite(float(loss))
        grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
        flat = jax.tree_util.tree_leaves(grads)
        assert all(np.isfinite(np.asarray(g)).all() for g in flat)

    def test_loss_near_uniform_at_init(self, arch):
        """With near-zero init output layers, loss ~ log(vocab)."""
        cfg = smoke_variant(get_config(arch))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = SyntheticDataset(cfg, batch=2, seq=32).batch_at(0)
        loss, _ = jax.jit(m.loss)(params, batch)
        assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


DECODE_ARCHS = [a for a in ARCHS if get_config(a).has_decode]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Prefill+decode logits == teacher-forced forward logits."""
    cfg = smoke_variant(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    s = 32
    batch = SyntheticDataset(cfg, batch=2, seq=s).batch_at(0)

    # Teacher-forced hidden states -> logits at every position.
    from repro.models import layers as L
    from repro.models.transformer import forward
    h, _ = forward(cfg, params, batch, training=False)
    w_head = L.unembed_matrix(cfg, params["embed"])
    logits_tf = np.asarray((h @ w_head).astype(jnp.float32))

    # Prefill the first s-1 tokens, then decode token s-1.
    pre = {k: (v[:, :s - 1] if hasattr(v, "ndim") and v.ndim >= 2 and
               v.shape[1] == s else v) for k, v in batch.items()}
    cache = m.make_cache(2, s + 8)
    logits_pre, cache = jax.jit(m.prefill)(params, pre, cache)
    np.testing.assert_allclose(logits_pre, logits_tf[:, s - 2], atol=2e-2,
                               rtol=2e-2)

    step = {"tokens": batch["tokens"][:, s - 1:s],
            "positions": batch["positions"][:, s - 1:s]}
    if cfg.family == "vlm":
        step["vision_embeds"] = batch["vision_embeds"][:, s - 1:s]
        step["vision_mask"] = batch["vision_mask"][:, s - 1:s]
    logits_dec, _ = jax.jit(m.decode_step)(params, step, cache)
    np.testing.assert_allclose(logits_dec, logits_tf[:, s - 1], atol=2e-2,
                               rtol=2e-2)


def test_param_count_analytic_close():
    """Analytic 6ND param count ~ matches the real spec tree (full size)."""
    for arch in ("stablelm-1.6b", "mistral-large-123b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        m = Model(cfg)
        analytic = cfg.param_count()
        real = m.param_count()
        assert abs(analytic - real) / real < 0.06, (arch, analytic, real)
