"""Lint: tests must not mutate the global design/workload registries.

Every ``register_design`` / ``register_workload`` call in ``tests/``
must sit lexically inside a ``with ... scoped_registry():`` block, so a
test failure can never leak a registered design into later tests.  The
walker is AST-based (a grep would miss multi-line calls and flag
comments); a call that is PROVABLY safe outside a scope -- an
idempotent re-register or one asserted to raise -- opts out with a
trailing ``# lint: outside-registry-ok`` comment on the call line.
"""

import ast
import pathlib

import pytest

REGISTRY_CALLS = {"register_design", "register_workload"}
OPT_OUT = "lint: outside-registry-ok"
TESTS_DIR = pathlib.Path(__file__).parent


def _callee(func) -> str | None:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _violations(path: pathlib.Path, src: str | None = None) -> list[str]:
    """``file:line`` for each registry mutation outside scoped_registry()."""
    src = path.read_text() if src is None else src
    lines = src.splitlines()
    found: list[str] = []

    class Walker(ast.NodeVisitor):
        def __init__(self):
            self.scoped_depth = 0

        def visit_With(self, node):
            scoped = any(
                isinstance(item.context_expr, ast.Call)
                and _callee(item.context_expr.func) == "scoped_registry"
                for item in node.items)
            self.scoped_depth += scoped
            self.generic_visit(node)
            self.scoped_depth -= scoped

        def visit_Call(self, node):
            span = lines[node.lineno - 1:(node.end_lineno or node.lineno)]
            if (_callee(node.func) in REGISTRY_CALLS
                    and self.scoped_depth == 0
                    and not any(OPT_OUT in l for l in span)):
                found.append(f"{path.name}:{node.lineno}")
            self.generic_visit(node)

    Walker().visit(ast.parse(src, str(path)))
    return found


def test_registry_mutations_are_scoped():
    bad = [v for p in sorted(TESTS_DIR.glob("*.py"))
           for v in _violations(p)]
    assert not bad, (
        "registry mutated outside scoped_registry() -- wrap in "
        "`with coaxial.scoped_registry():` or mark the line with "
        f"`# {OPT_OUT}`: " + ", ".join(bad))


class TestLinterItself:
    """The linter must actually catch violations, or the lint is a no-op."""

    def test_flags_unscoped_call(self):
        src = ("from repro.core import coaxial\n"
               "def test_x():\n"
               "    coaxial.register_design(D)\n")
        assert _violations(pathlib.Path("fake.py"), src) == ["fake.py:3"]

    def test_scoped_and_opted_out_pass(self):
        src = ("def test_x():\n"
               "    with coaxial.scoped_registry():\n"
               "        coaxial.register_design(D)\n"
               "        register_workload(W)\n"
               f"    register_design(D)  # {OPT_OUT}\n")
        assert _violations(pathlib.Path("fake.py"), src) == []

    def test_nested_with_still_scoped(self):
        src = ("def test_x():\n"
               "    with coaxial.scoped_registry():\n"
               "        with pytest.raises(ValueError):\n"
               "            coaxial.register_design(D)\n")
        assert _violations(pathlib.Path("fake.py"), src) == []

    def test_bare_name_and_multiline_call_flagged(self):
        src = ("def test_x():\n"
               "    register_workload(\n"
               "        W)\n")
        assert _violations(pathlib.Path("fake.py"), src) == ["fake.py:2"]
