"""Docs contract: the docs tree exists, README links it, links resolve.

Runs the same stdlib link checker the CI docs job runs, so broken
relative links fail tier-1 locally before they fail CI.
"""

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_docs_exist_and_are_linked_from_readme():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()
    assert (ROOT / "docs" / "FIGURES.md").is_file()
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/FIGURES.md" in readme


def test_relative_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_links.py"),
         str(ROOT / "README.md"), str(ROOT / "docs")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_checker_catches_broken_links(tmp_path):
    (tmp_path / "bad.md").write_text("see [missing](no/such/file.md)")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_links.py"),
         str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "no/such/file.md" in proc.stdout
