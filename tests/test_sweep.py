"""Design-space sweep engine: batch/single equivalence, registry, compile
count.  These pin the refactor's contract: the vmapped grid is numerically
the same model as the per-design path, and a whole grid costs ONE trace of
the jitted solver.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import coaxial, cpu_model, hw
from repro.core.cpu_model import (COAXIAL_4X, DDR_BASELINE, DESIGNS,
                                  MemSystem, solve, solve_batch,
                                  solve_trace_count, stack_designs)

LAT_GRID = (None, hw.CXL_LAT_PESSIMISTIC_NS)
CORE_GRID = (1, 8, hw.SIM_CORES)


class TestBatchMatchesSingle:
    @pytest.fixture(scope="class")
    def batch(self):
        return solve_batch(DESIGNS, n_active_grid=CORE_GRID,
                           iface_lat_grid=LAT_GRID)

    def test_shapes(self, batch):
        assert batch.ipc.shape == (len(DESIGNS), len(LAT_GRID),
                                   len(CORE_GRID), 35)

    @pytest.mark.parametrize("di", range(len(DESIGNS)))
    def test_elementwise_vs_solve(self, batch, di):
        sys = DESIGNS[di]
        for j, lat in enumerate(LAT_GRID):
            for k, n in enumerate(CORE_GRID):
                # None / non-CXL designs keep their own premium in the grid.
                override = lat if sys.is_cxl else None
                ref = solve(sys, n_active=n, iface_lat_ns=override)
                got = batch[di, j, k]
                for field in ("ipc", "latency_ns", "queue_ns", "sigma_ns",
                              "rho", "read_gbps", "write_gbps", "iface_ns"):
                    np.testing.assert_allclose(
                        getattr(got, field), getattr(ref, field),
                        rtol=1e-6, atol=1e-9,
                        err_msg=f"{sys.name} lat={lat} n={n} {field}")

    def test_baseline_column_ignores_latency_override(self, batch):
        b = [d.name for d in DESIGNS].index(DDR_BASELINE.name)
        np.testing.assert_array_equal(batch.ipc[b, 0], batch.ipc[b, 1])
        assert np.all(batch.iface_ns[b] == 0.0)

    def test_geomean_speedups_match_headline_path(self, batch):
        """4x / 2x / asym geomeans from the grid == evaluate()'s."""
        names = [d.name for d in DESIGNS]
        b = names.index(DDR_BASELINE.name)
        k = CORE_GRID.index(hw.SIM_CORES)
        for dname in ("coaxial-2x", "coaxial-4x", "coaxial-asym"):
            i = names.index(dname)
            gm_grid = cpu_model.geomean(batch.ipc[i, 0, k] /
                                        batch.ipc[b, 0, k])
            gm_eval = coaxial.evaluate(
                coaxial.get_design(dname)).geomean_speedup
            assert gm_grid == pytest.approx(gm_eval, rel=1e-6)


class TestCompileCount:
    def test_one_trace_per_grid_shape(self):
        # A shape not used anywhere else in the suite forces a fresh trace.
        grid = dict(n_active_grid=(2, 5, 7), iface_lat_grid=(11.0, 22.0))
        before = solve_trace_count()
        solve_batch(DESIGNS[:3], **grid)
        assert solve_trace_count() == before + 1
        # Same-shaped sweep: cache hit, zero new traces -- even with
        # different designs and grid values.
        solve_batch(DESIGNS[2:], **grid)
        solve_batch(DESIGNS[:3], n_active_grid=(1, 3, 12),
                    iface_lat_grid=(None, 40.0))
        assert solve_trace_count() == before + 1

    def test_single_solves_share_one_trace(self):
        solve(COAXIAL_4X)  # prime the (1,1,1) shape
        before = solve_trace_count()
        solve(DDR_BASELINE)
        solve(COAXIAL_4X, n_active=3, iface_lat_ns=42.0)
        solve(DESIGNS[3], n_active=9)
        assert solve_trace_count() == before


class TestSweepApi:
    @pytest.fixture(scope="class")
    def sw(self):
        return coaxial.sweep((DDR_BASELINE, COAXIAL_4X),
                             iface_lat_grid=LAT_GRID,
                             n_active_grid=CORE_GRID)

    def test_comparison_matches_evaluate(self, sw):
        for n in CORE_GRID:
            got = sw.comparison(COAXIAL_4X, n_active=n)
            ref = coaxial.evaluate(COAXIAL_4X, n_active=n)
            np.testing.assert_allclose(got.speedup, ref.speedup, rtol=1e-6)

    def test_latency_column_matches_evaluate(self, sw):
        got = sw.comparison(COAXIAL_4X, iface_lat=hw.CXL_LAT_PESSIMISTIC_NS)
        ref = coaxial.evaluate(COAXIAL_4X,
                               iface_lat_ns=hw.CXL_LAT_PESSIMISTIC_NS)
        np.testing.assert_allclose(got.speedup, ref.speedup, rtol=1e-6)

    def test_default_premium_aliases_explicit_30ns(self, sw):
        a = sw.comparison(COAXIAL_4X, iface_lat=None)
        b = sw.comparison(COAXIAL_4X, iface_lat=hw.CXL_LAT_NS)
        np.testing.assert_array_equal(a.speedup, b.speedup)

    def test_baseline_always_present(self):
        sw = coaxial.sweep((COAXIAL_4X,))
        assert sw.designs[0].name == DDR_BASELINE.name
        assert sw.comparison(COAXIAL_4X).geomean_speedup > 1.3

    def test_evaluate_applies_override_to_non_cxl(self):
        """Legacy contract: an explicit premium penalizes ANY design --
        the grid's is_cxl masking must not swallow it in evaluate()."""
        cmp = coaxial.evaluate(DDR_BASELINE, iface_lat_ns=50.0)
        assert cmp.sys.name == DDR_BASELINE.name
        ref = solve(DDR_BASELINE, iface_lat_ns=50.0)
        base = solve(DDR_BASELINE)
        np.testing.assert_allclose(cmp.speedup, ref.ipc / base.ipc,
                                   rtol=1e-6)
        assert cmp.geomean_speedup < 0.95

    def test_sensitivity_latency_non_cxl(self):
        out = coaxial.sensitivity_latency((30.0, 50.0), sys=DDR_BASELINE)
        assert out[50.0].geomean_speedup < out[30.0].geomean_speedup < 1.0

    def test_evaluate_modified_design_with_baseline_name(self):
        """A tweaked design still named 'ddr-baseline' must not shadow the
        comparator (legacy evaluate() solved it directly)."""
        import dataclasses
        ddr2 = dataclasses.replace(DDR_BASELINE, dram_channels=2)
        cmp = coaxial.evaluate(ddr2)
        ref = solve(ddr2)
        base = solve(DDR_BASELINE)
        np.testing.assert_allclose(cmp.speedup, ref.ipc / base.ipc,
                                   rtol=1e-6)
        assert cmp.geomean_speedup > 1.05
        sc = coaxial.sensitivity_cores((1, 12), sys=ddr2)
        assert sc[12].geomean_speedup == pytest.approx(
            cmp.geomean_speedup, rel=1e-6)

    def test_sweep_rejects_conflicting_same_name_designs(self):
        import dataclasses
        ddr2 = dataclasses.replace(DDR_BASELINE, dram_channels=2)
        with pytest.raises(ValueError, match="named"):
            coaxial.sweep((DDR_BASELINE, ddr2))

    def test_geomean_grid_baseline_row_is_one(self, sw):
        gm = sw.geomean_grid()
        b = sw.design_index(DDR_BASELINE.name)
        np.testing.assert_allclose(gm[b], 1.0, rtol=1e-6)


class TestRegistry:
    def test_seed_designs_registered(self):
        names = [d.name for d in coaxial.all_designs()]
        for d in DESIGNS:
            assert d.name in names

    def test_round_trip(self):
        custom = MemSystem(
            "test-cxl-3x", dram_channels=3, links=3,
            link_rd_gbps=hw.CXL_X8_RD_GBPS, link_wr_gbps=hw.CXL_X8_WR_GBPS,
            iface_lat_ns=hw.CXL_LAT_NS, llc_mb_per_core=1.5)
        with coaxial.scoped_registry():
            coaxial.register_design(custom)
            assert coaxial.get_design("test-cxl-3x") is custom
            assert custom in coaxial.all_designs()
            # Registered points flow into default sweeps and Table 2.
            sw = coaxial.sweep(n_active_grid=(hw.SIM_CORES,))
            gm = sw.comparison(custom).geomean_speedup
            assert 1.0 < gm < sw.comparison("coaxial-4x").geomean_speedup
            assert "test-cxl-3x" in coaxial.area_report()
        assert "test-cxl-3x" not in [d.name for d in coaxial.all_designs()]

    def test_duplicate_idempotent_or_rejected(self):
        # Re-registering the SAME design is an idempotent no-op...
        assert coaxial.register_design(
            COAXIAL_4X) is COAXIAL_4X  # lint: outside-registry-ok
        # ...but a DIFFERENT design under an existing name still raises
        # (silent shadowing) unless explicitly overwritten.
        impostor = dataclasses.replace(COAXIAL_4X, llc_mb_per_core=9.0)
        with pytest.raises(ValueError):
            coaxial.register_design(impostor)  # lint: outside-registry-ok
        with coaxial.scoped_registry():
            assert coaxial.register_design(
                impostor, overwrite=True) is impostor
            assert coaxial.get_design(COAXIAL_4X.name) is impostor
        assert coaxial.get_design(COAXIAL_4X.name) is COAXIAL_4X

    def test_scoped_registry_restores_on_exception(self):
        before = coaxial.all_designs()
        with pytest.raises(RuntimeError):
            with coaxial.scoped_registry():
                coaxial.register_design(
                    dataclasses.replace(COAXIAL_4X, name="test-doomed"))
                raise RuntimeError("boom")
        assert coaxial.all_designs() == before

    def test_unknown_design(self):
        with pytest.raises(KeyError):
            coaxial.get_design("no-such-design")


class TestPytree:
    def test_stack_designs_leading_axis(self):
        sysa = stack_designs(DESIGNS)
        assert sysa.dram_channels.shape == (len(DESIGNS),)
        np.testing.assert_array_equal(
            np.asarray(sysa.is_cxl),
            [0.0 if d.name == DDR_BASELINE.name else 1.0 for d in DESIGNS])

    def test_as_arrays_round_trip_values(self):
        a = COAXIAL_4X.as_arrays()
        assert float(a.dram_channels) == COAXIAL_4X.dram_channels
        assert float(a.iface_lat_ns) == COAXIAL_4X.iface_lat_ns
        assert float(a.is_cxl) == 1.0
