"""Integration tests: end-to-end training with checkpoint/crash/resume,
grad-compression training parity, serving consistency, and the dry-run
cell grid definition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.data.pipeline import SyntheticDataset
from repro.distributed.step import (TrainStepConfig, init_train_state,
                                    make_train_step, train_state_specs)
from repro.models.config import smoke_variant
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig

jax.config.update("jax_platform_name", "cpu")


def _setup(arch="stablelm-1.6b", compress=False, steps=16):
    cfg = smoke_variant(get_config(arch))
    model = Model(cfg)
    step_cfg = TrainStepConfig(
        opt=AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=steps),
        compress_grads=compress, param_dtype=cfg.dtype)
    state = init_train_state(model, jax.random.PRNGKey(0), step_cfg)
    step = jax.jit(make_train_step(model, step_cfg))
    ds = SyntheticDataset(cfg, 4, 32)
    return cfg, model, step_cfg, state, step, ds


class TestTrainLoop:
    def test_loss_decreases(self):
        _, _, _, state, step, ds = _setup(steps=60)
        losses = []
        for i in range(60):
            state, m = step(state, ds.batch_at(i))
            losses.append(float(m["loss"]))
        assert min(losses[-5:]) < losses[0] - 0.3

    def test_crash_resume_bitexact(self, tmp_path):
        """Train 10; checkpoint at 5; resume from 5 -> identical state."""
        _, model, step_cfg, state, step, ds = _setup()
        mid = None
        for i in range(10):
            if i == 5:
                ckpt.save(state, str(tmp_path), 5)
            state, _ = step(state, ds.batch_at(i))
        final_direct = jax.device_get(state["params"])

        specs = train_state_specs(model, step_cfg)
        restored, start = ckpt.restore(specs, str(tmp_path))
        assert start == 5
        for i in range(5, 10):
            restored, _ = step(restored, ds.batch_at(i))
        final_resumed = jax.device_get(restored["params"])
        for a, b in zip(jax.tree_util.tree_leaves(final_direct),
                        jax.tree_util.tree_leaves(final_resumed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_compressed_grads_still_learn(self):
        _, _, _, state, step, ds = _setup(compress=True, steps=60)
        losses = []
        for i in range(60):
            state, m = step(state, ds.batch_at(i))
            losses.append(float(m["loss"]))
        assert min(losses[-5:]) < losses[0] - 0.3

    def test_step_counter_advances(self):
        _, _, _, state, step, ds = _setup()
        assert int(state["step"]) == 0
        state, _ = step(state, ds.batch_at(0))
        assert int(state["step"]) == 1


class TestServingConsistency:
    def test_generate_deterministic(self):
        cfg = smoke_variant(get_config("starcoder2-3b"))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ds = SyntheticDataset(cfg, 2, 16)
        batch = ds.batch_at(0)
        prompt = {k: v for k, v in batch.items()
                  if k not in ("targets", "loss_mask")}
        t1, _ = model.greedy_generate(params, prompt,
                                      model.make_cache(2, 32), steps=8)
        t2, _ = model.greedy_generate(params, prompt,
                                      model.make_cache(2, 32), steps=8)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


class TestCellGrid:
    def test_grid_is_40_cells(self):
        all_cells = list(cells())
        assert len(all_cells) == 40

    def test_skip_reasons(self):
        status = {(a, s.name): st for a, _, s, st in cells()}
        # encoder-only: no decode shapes
        assert "encoder-only" in status[("hubert-xlarge", "decode_32k")]
        assert "encoder-only" in status[("hubert-xlarge", "long_500k")]
        # 500k decode only for sub-quadratic families
        assert status[("zamba2-2.7b", "long_500k")] == "ok"
        assert status[("rwkv6-1.6b", "long_500k")] == "ok"
        for a in ("stablelm-1.6b", "starcoder2-3b", "mistral-large-123b",
                  "stablelm-3b", "olmoe-1b-7b", "phi3.5-moe-42b-a6.6b",
                  "qwen2-vl-72b"):
            assert "sub-quadratic" in status[(a, "long_500k")]

    def test_runnable_cell_count(self):
        ok = [1 for *_, st in cells() if st == "ok"]
        # 10 train + 10 prefill + 9 decode_32k + 2 long_500k
        assert len(ok) == 31

    def test_every_arch_has_config(self):
        for arch in ARCHS:
            cfg = get_config(arch)
            assert cfg.n_layers > 0 and cfg.d_model > 0
