"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles in kernels/ref.py (interpret=True on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn
from repro.kernels.rwkv_wkv import wkv
from repro.kernels.stream import (stream_add, stream_copy, stream_scale,
                                  stream_triad)

jax.config.update("jax_platform_name", "cpu")


def _rand(seed, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# STREAM
# ---------------------------------------------------------------------------

STREAM_SHAPES = [(128, 128), (512, 256), (1024, 384), (2048, 128)]
STREAM_DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", STREAM_SHAPES)
@pytest.mark.parametrize("dtype", STREAM_DTYPES)
class TestStream:
    def test_copy(self, shape, dtype):
        a = _rand(0, shape, dtype)
        np.testing.assert_array_equal(
            np.asarray(stream_copy(a, interpret=True)), np.asarray(a))

    def test_scale(self, shape, dtype):
        a = _rand(1, shape, dtype)
        out = stream_scale(a, 2.5, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref.stream_scale_ref(a, jnp.asarray(2.5, dtype)),
                       np.float32), rtol=1e-2 if dtype == jnp.bfloat16
            else 1e-5, atol=1e-5)

    def test_add(self, shape, dtype):
        a, b = _rand(2, shape, dtype), _rand(3, shape, dtype)
        np.testing.assert_array_equal(
            np.asarray(stream_add(a, b, interpret=True)),
            np.asarray(ref.stream_add_ref(a, b)))

    def test_triad(self, shape, dtype):
        a, b = _rand(4, shape, dtype), _rand(5, shape, dtype)
        out = stream_triad(a, b, 2.5, interpret=True)
        want = ref.stream_triad_ref(a, b, jnp.asarray(2.5, dtype))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-2 if dtype == jnp.bfloat16
                                   else 1e-5, atol=1e-5)


def test_stream_non_divisible_rows():
    """Grid must cover shapes that do not divide the block size."""
    a = _rand(0, (300, 128))
    np.testing.assert_array_equal(
        np.asarray(stream_copy(a, interpret=True)), np.asarray(a))


# ---------------------------------------------------------------------------
# Flash-decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hk", [(8, 8), (8, 2), (12, 2), (4, 1)])
@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("s", [512, 1024, 1536])
def test_decode_attn_sweep(hq, hk, d, s):
    b = 2
    q = _rand(0, (b, hq, d))
    k = _rand(1, (b, s, hk, d))
    v = _rand(2, (b, s, hk, d))
    length = jnp.array(s - 100, jnp.int32)
    out = decode_attn(q, k, v, length, block_s=512, interpret=True)
    want = ref.decode_attn_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn_dtypes(dtype):
    b, hq, hk, d, s = 1, 4, 2, 64, 512
    q, k, v = (_rand(i, shp, dtype) for i, shp in
               enumerate([(b, hq, d), (b, s, hk, d), (b, s, hk, d)]))
    length = jnp.array(s, jnp.int32)
    out = decode_attn(q, k, v, length, interpret=True)
    want = ref.decode_attn_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(1, 16),
    frac=st.floats(0.1, 1.0),
    g=st.sampled_from([1, 2, 4]),
)
def test_decode_attn_property_length_invariance(s, frac, g):
    """Property: entries beyond `length` never influence the output."""
    b, hk, d = 1, 2, 64
    seq = 128 * s
    length = jnp.array(max(int(seq * frac), 1), jnp.int32)
    q = _rand(0, (b, hk * g, d))
    k = _rand(1, (b, seq, hk, d))
    v = _rand(2, (b, seq, hk, d))
    out1 = decode_attn(q, k, v, length, block_s=128, interpret=True)
    poison = jnp.where(jnp.arange(seq)[None, :, None, None] < length, k, 77.0)
    out2 = decode_attn(q, poison, v, length, block_s=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [64, 128, 256])
@pytest.mark.parametrize("h,d", [(2, 32), (4, 64)])
def test_wkv_sweep(t, h, d):
    b = 2
    r, k, v = (_rand(i, (b, t, h, d)) for i in range(3))
    w = jax.nn.sigmoid(_rand(3, (b, t, h, d))) * 0.5 + 0.5  # decays in (0.5,1)
    u = _rand(4, (h, d))
    s0 = _rand(5, (b, h, d, d))
    y, s = wkv(r, k, v, w, u, s0, block_t=64, interpret=True)
    y_ref, s_ref = ref.wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-4,
                               rtol=1e-4)


def test_wkv_state_chaining():
    """wkv(T) == wkv(T/2) chained twice (state carry is exact)."""
    b, t, h, d = 1, 128, 2, 32
    r, k, v = (_rand(i, (b, t, h, d)) for i in range(3))
    w = jax.nn.sigmoid(_rand(3, (b, t, h, d))) * 0.4 + 0.6
    u = _rand(4, (h, d))
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    y_full, s_full = wkv(r, k, v, w, u, s0, block_t=64, interpret=True)
    half = t // 2
    y1, s1 = wkv(r[:, :half], k[:, :half], v[:, :half], w[:, :half], u, s0,
                 block_t=64, interpret=True)
    y2, s2 = wkv(r[:, half:], k[:, half:], v[:, half:], w[:, half:], u, s1,
                 block_t=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.concatenate([y1, y2], axis=1), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(decay=st.floats(0.05, 0.99))
def test_wkv_property_uniform_decay(decay):
    """Property: with k=0 the state just decays: S_T = S_0 * decay^T."""
    b, t, h, d = 1, 64, 1, 32
    r = _rand(0, (b, t, h, d))
    k = jnp.zeros((b, t, h, d))
    v = _rand(1, (b, t, h, d))
    w = jnp.full((b, t, h, d), decay)
    u = jnp.zeros((h, d))
    s0 = _rand(2, (b, h, d, d))
    _, s = wkv(r, k, v, w, u, s0, block_t=64, interpret=True)
    want = np.asarray(s0) * decay ** t
    np.testing.assert_allclose(np.asarray(s), want, atol=1e-5, rtol=1e-3)
