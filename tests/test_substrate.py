"""Substrate tests: optimizer, compression, checkpointing, fault tolerance,
data pipeline, sharding helpers, HLO analysis."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import hloparse
from repro.data.pipeline import PrefetchIterator, SyntheticDataset
from repro.distributed import fault
from repro.models.config import smoke_variant
from repro.optim import adamw, compression
from repro.optim.adamw import AdamWConfig

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class TestAdamW:
    def _setup(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16),
                  "b": jnp.zeros((4,), jnp.bfloat16)}
        state = adamw.init(params)
        return params, state

    def test_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=100)
        params = {"w": jnp.full((8,), 5.0)}
        state = adamw.init(params)

        def loss(p):
            return jnp.sum(jnp.square(p["w"]))

        p = params
        losses = []
        for i in range(80):
            g = jax.grad(loss)(p)
            p, state, _ = adamw.update(cfg, g, state, jnp.int32(i),
                                       param_dtype=jnp.float32)
            losses.append(float(loss(p)))
        assert losses[-1] < 2.0
        assert losses[-1] < 0.05 * losses[0]

    def test_clip_norm(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        params, state = self._setup()
        grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.full((4,), 100.0)}
        _, _, metrics = adamw.update(cfg, grads, state, jnp.int32(0))
        assert float(metrics["grad_norm"]) > 100.0  # measured pre-clip

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(adamw.schedule(cfg, jnp.int32(s)))
               for s in (0, 5, 10, 50, 100)]
        assert lrs[0] == pytest.approx(1e-4)
        assert lrs[1] == pytest.approx(6e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(cfg.lr * cfg.min_lr_ratio, rel=1e-3)

    def test_master_weights_do_not_alias(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = adamw.init(params)
        assert state["master"]["w"] is not params["w"]


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
        ef = compression.init_error_feedback(g)
        comp, ef2 = compression.compress(g, ef)
        rec = compression.decompress(comp)
        err = np.abs(np.asarray(rec["w"]) - np.asarray(g["w"])).max()
        scale = float(np.abs(np.asarray(g["w"])).max()) / 127.0
        assert err <= scale + 1e-6

    def test_error_feedback_accumulates(self):
        """EF carries quantization residue: sum over steps converges."""
        g = {"w": jnp.full((16,), 0.001)}   # much smaller than scale step
        ef = compression.init_error_feedback(g)
        total = np.zeros(16)
        for _ in range(50):
            comp, ef = compression.compress(g, ef)
            total += np.asarray(compression.decompress(comp)["w"])
        # Without EF the tiny gradient would vanish; with EF the running sum
        # tracks 50 * g.
        np.testing.assert_allclose(total, 0.05, rtol=0.2)

    def test_compressed_is_int8(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (32,))}
        comp, _ = compression.compress(g, compression.init_error_feedback(g))
        q, scale = comp["w"]
        assert q.dtype == jnp.int8
        assert compression.compressed_bytes(comp) == 32

    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(1e-3, 1e3))
    def test_property_quantization_error_bound(self, scale):
        g = {"w": jax.random.normal(jax.random.PRNGKey(2), (128,)) * scale}
        ef = compression.init_error_feedback(g)
        comp, ef2 = compression.compress(g, ef)
        rec = compression.decompress(comp)
        # residual == what error-feedback remembers
        np.testing.assert_allclose(
            np.asarray(g["w"]) - np.asarray(rec["w"]), np.asarray(ef2["w"]),
            atol=1e-5 * scale)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self):
        return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                "step": jnp.int32(7)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(tree, str(tmp_path), 7)
        loaded, step = ckpt.restore(tree, str(tmp_path))
        assert step == 7
        np.testing.assert_array_equal(loaded["params"]["w"],
                                      tree["params"]["w"])

    def test_latest_and_retention(self, tmp_path):
        tree = self._tree()
        for s in (1, 2, 3, 4, 5):
            ckpt.save(tree, str(tmp_path), s)
        assert ckpt.latest_step(str(tmp_path)) == 5
        ckpt.retain(str(tmp_path), keep=2)
        steps = sorted(int(d.split("-")[1]) for d in os.listdir(tmp_path)
                       if d.startswith("step-"))
        assert steps == [4, 5]

    def test_async_checkpointer(self, tmp_path):
        tree = self._tree()
        acp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (10, 20):
            acp.save(tree, s)
        acp.close()
        assert ckpt.latest_step(str(tmp_path)) == 20

    def test_atomicity_no_partial_dirs(self, tmp_path):
        tree = self._tree()
        ckpt.save(tree, str(tmp_path), 1)
        names = os.listdir(tmp_path)
        assert all(not n.startswith(".tmp") for n in names)

    def test_elastic_reshard_restore(self, tmp_path):
        """Restore under a different sharding (1-device 'new mesh')."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        tree = self._tree()
        ckpt.save(tree, str(tmp_path), 3)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), tree)
        loaded, _ = ckpt.restore(tree, str(tmp_path), shardings=sh)
        np.testing.assert_array_equal(loaded["params"]["w"],
                                      tree["params"]["w"])


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

class TestFault:
    def test_retry_then_success(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise fault.StepFailure("transient")

        runner = fault.ResilientRunner(lambda x: x + 1, max_retries=3,
                                       failure_injector=flaky)
        assert runner.run_step(1) == 2
        assert runner.retries_total == 2

    def test_restore_after_exhausted_retries(self):
        calls = {"n": 0}

        def always_fail_twice():
            calls["n"] += 1
            if calls["n"] <= 4:
                raise fault.StepFailure("persistent")

        restored = {"n": 0}

        def on_restore(x):
            restored["n"] += 1
            return (x,), {}

        runner = fault.ResilientRunner(lambda x: x * 10, max_retries=1,
                                       on_restore=on_restore,
                                       failure_injector=always_fail_twice)
        assert runner.run_step(5) == 50
        assert restored["n"] >= 1

    def test_straggler_detection(self):
        mon = fault.StragglerMonitor(window=8, threshold=2.0)
        import time as _t
        for i in range(8):
            mon.start()
            _t.sleep(0.002)
            mon.stop()
        mon.start()
        _t.sleep(0.05)
        assert mon.stop() is True
        assert len(mon.straggler_steps) == 1

    def test_heartbeat(self, tmp_path):
        hb = fault.Heartbeat(str(tmp_path / "hb"), interval_s=0.0)
        hb.beat(3)
        assert fault.Heartbeat.is_alive(str(tmp_path / "hb"))
        assert not fault.Heartbeat.is_alive(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_determinism(self):
        cfg = smoke_variant(get_config("stablelm-1.6b"))
        ds = SyntheticDataset(cfg, 4, 32, seed=7)
        b1, b2 = ds.batch_at(5), ds.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = ds.batch_at(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_prefetch_order_and_restart(self):
        cfg = smoke_variant(get_config("stablelm-1.6b"))
        ds = SyntheticDataset(cfg, 2, 16)
        it = PrefetchIterator(ds, start_step=3)
        s0, b0 = next(it)
        s1, b1 = next(it)
        it.close()
        assert (s0, s1) == (3, 4)
        np.testing.assert_array_equal(b0["tokens"], ds.batch_at(3)["tokens"])

    def test_targets_shifted(self):
        cfg = smoke_variant(get_config("stablelm-1.6b"))
        b = SyntheticDataset(cfg, 2, 16).batch_at(0)
        assert b["tokens"].shape == b["targets"].shape
        assert (b["targets"] < cfg.vocab).all()


# ---------------------------------------------------------------------------
# HLO analysis (the loop-scaling parser behind the roofline).
# ---------------------------------------------------------------------------

class TestHloParse:
    def _compile(self, fn, *specs):
        return jax.jit(fn).lower(*specs).compile().as_text()

    def test_dot_flops_exact(self):
        w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
        txt = self._compile(lambda w, x: x @ w, w, x)
        cost = hloparse.analyze(txt)
        assert cost.flops == pytest.approx(2 * 32 * 128 * 64, rel=0.01)

    @pytest.mark.parametrize("n", [2, 8, 32])
    def test_while_trip_scaling(self, n):
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((16, 64), jnp.float32)

        def f(w, x):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out

        cost = hloparse.analyze(self._compile(f, w, x))
        assert cost.flops == pytest.approx(n * 2 * 16 * 64 * 64, rel=0.05)

    def test_nested_scan_scaling(self):
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((16, 64), jnp.float32)

        def f(w, x):
            def outer(c, _):
                def inner(c2, _):
                    return jnp.tanh(c2 @ w), None
                c, _ = jax.lax.scan(inner, c, None, length=3)
                return c, None
            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        cost = hloparse.analyze(self._compile(f, w, x))
        assert cost.flops == pytest.approx(15 * 2 * 16 * 64 * 64, rel=0.05)

    def test_bytes_grow_with_trip_count(self):
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((16, 64), jnp.float32)

        def mk(n):
            def f(w, x):
                def body(c, _):
                    return jnp.tanh(c @ w), None
                out, _ = jax.lax.scan(body, x, None, length=n)
                return out
            return f

        c2 = hloparse.analyze(self._compile(mk(2), w, x))
        c8 = hloparse.analyze(self._compile(mk(8), w, x))
        assert c8.bytes > 3 * c2.bytes


# ---------------------------------------------------------------------------
# H4': int8-on-the-wire all-reduce (numerics; the byte proof is
# repro.launch.dryrun --collective-proof).
# ---------------------------------------------------------------------------

class TestInt8Collectives:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_roundtrip_error_bounded(self):
        from repro.distributed import int8_collectives as i8
        red = i8.make_reducer(self._mesh(), int8=True)
        x = {"w": jax.random.normal(jax.random.PRNGKey(0), (37, 13))}
        out = jax.jit(red)(x)
        scale = float(jnp.abs(x["w"]).max()) / 127.0
        err = float(jnp.abs(out["w"] - x["w"]).max())
        assert err <= 2 * scale + 1e-6     # quantize + requantize steps

    def test_f32_reducer_exact(self):
        from repro.distributed import int8_collectives as i8
        red = i8.make_reducer(self._mesh(), int8=False)
        x = {"w": jnp.arange(12.0).reshape(3, 4)}
        out = jax.jit(red)(x)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(x["w"]), rtol=1e-6)

    def test_non_divisible_padding(self):
        from repro.distributed import int8_collectives as i8
        red = i8.make_reducer(self._mesh(), int8=True)
        x = {"w": jax.random.normal(jax.random.PRNGKey(1), (7,))}
        out = jax.jit(red)(x)
        assert out["w"].shape == (7,)
