"""Mechanism in the loop: the QueueLUT-backed cpu_model fixed point.

The contract of the pluggable queue backend:

  * ``queue_model="closed_form"`` is bit-identical to the historical
    solver (same jitted path, ``lut=None`` operand);
  * the LUT is honest -- interpolation at off-grid (rho, kappa) points
    matches a direct DES run within tolerance, and grid nodes are exact
    (the default build runs on the per-request EVENT engine over the
    one-notch-finer default grids; a timestep-built surface agrees);
  * ``queue_model="memsim"`` solves the full default grid with no
    per-cell Python loop (one jitted trace per flattened cell count,
    pinned by the trace counter) and the paper's qualitative story
    survives the mechanism (positive speedups, CoaXiaL still wins);
  * the backend is a sweep axis with per-backend baseline references;
  * gradients flow through the LUT, finite and sign-correct at the
    Pareto knee.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import coaxial, cpu_model, hw, memsim, queuelut
from repro.core.cpu_model import (COAXIAL_4X, DDR_BASELINE, solve,
                                  solve_trace_count)
from repro.core.queuelut import QueueLUT, build_queue_lut

#: Module-shared LUT: default grids, reduced DES budget (the full default
#: budget is for benchmarks; the structure is identical).
LUT_STEPS = 40_000


@pytest.fixture(scope="module")
def lut():
    return build_queue_lut(steps=LUT_STEPS, reps=2)


class TestQueueLUT:
    def test_tables_finite_and_shaped(self, lut):
        shape = (len(queuelut.DEFAULT_RHO_GRID),
                 len(queuelut.DEFAULT_KAPPA_GRID),
                 len(queuelut.DEFAULT_OUTSTANDING_GRID),
                 len(queuelut.DEFAULT_ETA_GRID))
        for t in (lut.wait_ns, lut.p90_wait_ns, lut.p99_wait_ns,
                  lut.sigma_ns):
            assert t.shape == shape
            assert np.isfinite(np.asarray(t)).all()
            assert (np.asarray(t) >= 0.0).all()
        # Percentiles are ordered by construction: p99 >= p90 >= mean
        # has no reason to hold cell-by-cell under DES noise at tiny
        # waits, but p99 >= p90 is a true per-sample-set invariant.
        assert (np.asarray(lut.p99_wait_ns)
                >= np.asarray(lut.p90_wait_ns) - 1e-9).all()

    def test_grid_nodes_are_exact(self, lut):
        i, j, k, m = 3, 1, 4, 2
        got = lut.lookup(float(lut.rho_grid[i]),
                         float(lut.kappa_grid[j]),
                         float(lut.outstanding_grid[k]),
                         float(lut.eta_grid[m]))
        tables = (lut.wait_ns, lut.p90_wait_ns, lut.p99_wait_ns,
                  lut.sigma_ns)
        assert len(got) == len(tables)
        for val, table in zip(got, tables):
            assert float(val) == pytest.approx(float(table[i, j, k, m]),
                                               rel=1e-6)

    def test_outstanding_interpolates_in_log_space(self, lut):
        # The outstanding axis is log-spaced: the geometric mean of two
        # adjacent grid nodes must read back as the arithmetic mean of
        # the two node values (fraction 1/2 in log space).
        k = 2
        lo = float(lut.outstanding_grid[k])
        hi = float(lut.outstanding_grid[k + 1])
        x = float(np.sqrt(lo * hi))
        got = float(lut.wait(float(lut.rho_grid[3]),
                             float(lut.kappa_grid[1]), x,
                             float(lut.eta_grid[-1])))
        tab = np.asarray(lut.wait_ns)
        want = 0.5 * (tab[3, 1, k, -1] + tab[3, 1, k + 1, -1])
        assert got == pytest.approx(float(want), rel=1e-6)

    def test_eta_axis_brackets_off_grid_reads(self, lut):
        # An off-grid eta read is a convex blend of its two neighbours.
        m = 1
        lo = float(lut.eta_grid[m])
        hi = float(lut.eta_grid[m + 1])
        mid = 0.5 * (lo + hi)
        tab = np.asarray(lut.wait_ns)
        a = float(tab[3, 1, 4, m])
        b = float(tab[3, 1, 4, m + 1])
        got = float(lut.wait(float(lut.rho_grid[3]),
                             float(lut.kappa_grid[1]),
                             float(lut.outstanding_grid[4]), mid))
        assert min(a, b) - 1e-9 <= got <= max(a, b) + 1e-9

    #: The off-grid probe point: (rho, kappa) strictly between grid
    #: nodes -- the LUT-resolution instrument shared by the mean and
    #: p99 cross-checks below.
    OFF_GRID = (0.41, 1.45, 192.0)

    @pytest.fixture(scope="class")
    def off_grid_cell(self):
        rho, kappa, out = self.OFF_GRID
        assert rho not in queuelut.DEFAULT_RHO_GRID
        assert kappa not in queuelut.DEFAULT_KAPPA_GRID
        sw = coaxial.distribution_sweep(
            rho=(rho,), kappa=(kappa,), outstanding=(out,),
            steps=LUT_STEPS, reps=8, engine=queuelut.DEFAULT_ENGINE)
        return sw.cell(rho=rho, kappa=kappa, outstanding=out)

    def test_interpolation_matches_direct_des_off_grid(
            self, lut, off_grid_cell):
        # The LUT's multilinear read must agree with a fresh DES run at
        # the exact point (same engine as the default build).  This is
        # the LUT-resolution instrument: the finer default grids must
        # keep it honest.
        rho, kappa, out = self.OFF_GRID
        des_wait = float(off_grid_cell.mean_ns) - hw.DRAM_SERVICE_NS
        lut_wait = float(lut.wait(rho, kappa, out))
        assert lut_wait == pytest.approx(des_wait, rel=0.35, abs=4.0)

    def test_p99_interpolation_matches_direct_des_off_grid(
            self, lut, off_grid_cell):
        # Same instrument for the tail: the p99 table's off-grid read
        # vs the event engine's exact per-request p99 at that point.
        # The p99 of a histogram is a noisier statistic than its mean,
        # so the absolute leg of the gate is a touch wider.
        rho, kappa, out = self.OFF_GRID
        des_p99 = float(off_grid_cell.p99_ns) - hw.DRAM_SERVICE_NS
        lut_p99 = float(lut.lookup(rho, kappa, out, 1.0)[2])
        assert des_p99 > 0.0            # the DES actually has a tail
        assert lut_p99 == pytest.approx(des_p99, rel=0.35, abs=6.0)

    def test_wait_monotone_in_rho_at_high_outstanding(self, lut):
        col = np.asarray(lut.wait_ns)[:, 0, -1, -1]
        assert col[-1] > col[0]
        # Not strictly per-segment (DES noise), but the top-of-grid wait
        # dominates the bottom by a wide margin.
        assert col[-1] > 3.0 * max(col[0], 1.0)

    def test_clamps_outside_hull(self, lut):
        inside = float(lut.wait(float(lut.rho_grid[-1]), 1.0, 192.0))
        beyond = float(lut.wait(1.5, 1.0, 192.0))
        assert beyond == pytest.approx(inside, rel=1e-6)

    def test_grid_validation(self):
        with pytest.raises(ValueError, match=">= 2 points"):
            build_queue_lut(rho=(0.5,), steps=1000)
        with pytest.raises(ValueError, match="ascending"):
            build_queue_lut(kappa=(2.0, 1.0), steps=1000)

    def test_outstanding_is_a_channel_field(self):
        # The closed-loop population is a real simulated mechanism: a
        # tight bound must reduce observed waits at high load.
        sw = coaxial.distribution_sweep(
            rho=(0.8,), outstanding=(4.0, 1e9), steps=30_000, reps=2)
        tight = float(sw.cell(rho=0.8, outstanding=4.0).mean_ns)
        open_ = float(sw.cell(rho=0.8, outstanding=1e9).mean_ns)
        assert tight < open_

    def test_engines_build_agreeing_tables(self):
        # The same default grid built by the timestep reference engine:
        # the two surfaces must agree where queueing is meaningful (the
        # residual is DES sampling noise, not a law mismatch -- reps=4
        # keeps the median comfortably inside the gate, ~0.18 measured).
        ts = build_queue_lut(steps=LUT_STEPS, reps=4, engine="timestep")
        ev = build_queue_lut(steps=LUT_STEPS, reps=4, engine="event")
        tw = np.asarray(ts.wait_ns)
        ew = np.asarray(ev.wait_ns)
        mask = tw > 15.0
        assert mask.sum() > 30           # the grid has real queueing cells
        rel = np.abs(ew - tw)[mask] / tw[mask]
        assert float(np.median(rel)) < 0.25

    def test_default_inf_is_bit_identical_to_pre_cap_sim(self):
        # The unbounded default must not perturb the threefry stream or
        # the Lindley chain: two paths, same seed, same histograms.
        a = memsim.simulate([memsim.ChannelConfig(rho=0.6)],
                            steps=20_000, seed=11)
        b = memsim.simulate(
            [memsim.ChannelConfig(rho=0.6, outstanding=float("inf"))],
            steps=20_000, seed=11)
        np.testing.assert_array_equal(a.hist, b.hist)


class TestBackends:
    def test_closed_form_bit_identical_to_default(self):
        a = solve(COAXIAL_4X)
        b = solve(COAXIAL_4X, queue_model="closed_form")
        np.testing.assert_array_equal(a.ipc, b.ipc)
        np.testing.assert_array_equal(a.sigma_ns, b.sigma_ns)

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="closed_form"):
            solve(COAXIAL_4X, queue_model="mmm1")

    def test_memsim_backend_story_survives(self, lut):
        res = solve(COAXIAL_4X, queue_model="memsim", lut=lut)
        base = solve(DDR_BASELINE, queue_model="memsim", lut=lut)
        assert np.isfinite(res.ipc).all() and (res.ipc > 0).all()
        gm = cpu_model.geomean(res.ipc / base.ipc)
        assert gm > 1.1           # CoaXiaL still wins under the mechanism
        # ... but the mechanism is not the closed form: drift is real.
        gm_cf = coaxial.evaluate(COAXIAL_4X).geomean_speedup
        assert abs(gm - gm_cf) > 0.01

    def test_memsim_sigma_is_the_des_table(self, lut):
        # The sigma heuristic sqrt(75^2 + W^2) is replaced: on the memsim
        # path the reported stdevs differ from the closed-form law.
        res = solve(DDR_BASELINE, queue_model="memsim", lut=lut)
        from repro.core import queueing
        heur = np.asarray(queueing.stdev_latency_ns(res.queue_ns))
        assert not np.allclose(res.sigma_ns, heur, rtol=0.05)

    def test_memsim_default_grid_is_one_trace(self, lut):
        # The full default-sweep-shaped grid (5 designs x 2 latencies x
        # 4 core counts = 40 cells) under the memsim backend: ONE new
        # trace, no per-cell Python loop.
        spec = coaxial.sweep_spec(
            design=coaxial.all_designs(),
            iface_lat_ns=(None, hw.CXL_LAT_PESSIMISTIC_NS),
            n_active=(1, 4, 8, hw.SIM_CORES))
        before = solve_trace_count()
        sw = coaxial.solve_spec(spec, queue_model="memsim", lut=lut)
        assert solve_trace_count() == before + 1
        assert sw.shape == (5, 2, 4)
        gm = sw.comparison(COAXIAL_4X, iface_lat=None,
                           n_active=hw.SIM_CORES).geomean_speedup
        assert np.isfinite(gm) and gm > 1.0

    def test_solve_batch_passthrough(self, lut):
        res = cpu_model.solve_batch((DDR_BASELINE, COAXIAL_4X),
                                    queue_model="memsim", lut=lut)
        one = solve(COAXIAL_4X, queue_model="memsim", lut=lut)
        np.testing.assert_allclose(res.ipc[1, 0, 0], one.ipc, rtol=1e-6)


class TestBackendAxis:
    @pytest.fixture(scope="class")
    def sw(self, lut):
        spec = coaxial.sweep_spec(design=(DDR_BASELINE, COAXIAL_4X),
                                  queue_model=("closed_form", "memsim"))
        return coaxial.solve_spec(spec, lut=lut)

    def test_axis_shape_and_string_sel(self, sw):
        assert sw.axis_names == ("design", "queue_model")
        assert sw.shape == (2, 2)
        cf = sw.sel(queue_model="closed_form")
        ref = coaxial.solve_spec(
            coaxial.sweep_spec(design=(DDR_BASELINE, COAXIAL_4X)))
        np.testing.assert_allclose(cf.results.ipc, ref.results.ipc,
                                   rtol=1e-6)

    def test_per_backend_baseline_reference(self, sw):
        # Each backend's baseline row is exactly 1 against its OWN
        # reference -- memsim cells never compare against the closed form.
        gm = sw.speedup_grid()
        b = sw.design_index(DDR_BASELINE.name)
        np.testing.assert_allclose(gm[b], 1.0, rtol=1e-6)
        # And a sel()-pinned backend keeps that reference.
        ms = sw.sel(queue_model="memsim")
        np.testing.assert_allclose(ms.speedup_grid()[b], 1.0, rtol=1e-6)

    def test_backends_disagree_quantitatively(self, sw):
        gm = sw.speedup_grid()
        i = sw.design_index(COAXIAL_4X.name)
        cf, ms = gm[i]
        assert cf > 1.0 and ms > 1.0
        assert abs(cf - ms) > 0.01    # the drift the report quantifies

    def test_comparison_accepts_backend_coordinate(self, sw):
        c = sw.comparison(COAXIAL_4X, queue_model="memsim")
        assert c.geomean_speedup > 1.0

    def test_bad_backend_coordinate_lists_valid(self, sw):
        with pytest.raises(KeyError, match="closed_form"):
            sw.sel(queue_model="fast")

    def test_spec_rejects_unknown_backend(self):
        with pytest.raises(ValueError, match="closed_form"):
            coaxial.sweep_spec(queue_model=("turbo",))

    def test_axis_plus_kwarg_rejected(self, lut):
        spec = coaxial.sweep_spec(design=(DDR_BASELINE,),
                                  queue_model=("closed_form", "memsim"))
        with pytest.raises(ValueError, match="not both"):
            coaxial.solve_spec(spec, queue_model="memsim", lut=lut)

    def test_build_flat_refuses_backend_axis(self):
        from repro.core import sweepspec
        spec = coaxial.sweep_spec(design=(DDR_BASELINE,),
                                  queue_model=("memsim",))
        with pytest.raises(ValueError, match="solve_spec"):
            sweepspec.build_flat(spec)


class TestGradientThroughLUT:
    def test_knee_gradient_finite_and_sign_correct(self, lut):
        from benchmarks.pareto_frontier import frontier_sweep, knee_point
        sw = frontier_sweep()
        knee = knee_point(sw.pareto(cost="rel_area"))
        base = next(d for d in sw.designs if d.name == knee["design"])
        knee_sys = dataclasses.replace(
            base, llc_mb_per_core=knee["llc_mb_per_core"])
        g = cpu_model.design_gradient(
            knee_sys, ("dram_channels", "llc_mb_per_core", "iface_lat_ns"),
            queue_model="memsim", lut=lut)
        assert all(np.isfinite(v) for v in g.values())
        assert g["dram_channels"] > 0.0   # more channels always help
        assert g["iface_lat_ns"] < 0.0    # a slower link never does


class TestSigmaGate:
    def test_validate_calibration_gates_stdev(self):
        # Full step budget (the gates are calibrated for it), two anchors
        # to keep the lane count small.
        val = coaxial.validate_calibration(rhos=(0.3, 0.5),
                                           steps=200_000, reps=24)
        assert "max_abs_stdev_err" in val and "stdev_tol" in val
        assert val["max_abs_stdev_err"] <= val["stdev_tol"]
        for a in val["anchors"]:
            assert np.isfinite(a["stdev_err"])

    def test_ok_flag_fails_on_tight_stdev_tol(self):
        # The gate is real: an artificially tight tolerance must flip ok.
        val = coaxial.validate_calibration(rhos=(0.5,), steps=40_000,
                                           reps=8, stdev_tol=1e-6)
        assert not val["ok"]
