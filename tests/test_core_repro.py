"""Validation of the COAXIAL reproduction against the paper's own claims.

Every test here pins a number the paper states explicitly (see DESIGN.md §1
for the claim table).  Tolerances reflect that our CPU model is analytical
where the paper's is cycle-level; headline aggregates are tight, per-workload
values get wider bands.
"""

import numpy as np
import pytest

from repro.core import coaxial, cpu_model, hw, queueing
from repro.core.workloads import NAMES, WORKLOADS


# ---------------------------------------------------------------------------
# §3.1 / Fig 2a: the calibrated load-latency curve hits the stated anchors.
# ---------------------------------------------------------------------------

class TestLoadLatencyCurve:
    def test_unloaded_latency(self):
        assert float(queueing.avg_latency_ns(0.0)) == pytest.approx(40.0)

    def test_avg_3x_at_50pct(self):
        assert float(queueing.avg_latency_ns(0.5)) == pytest.approx(120.0,
                                                                    rel=1e-3)

    def test_avg_4x_at_60pct(self):
        assert float(queueing.avg_latency_ns(0.6)) == pytest.approx(160.0,
                                                                    rel=1e-3)

    def test_p90_4p7x_at_50pct(self):
        assert float(queueing.p90_latency_ns(0.5)) == pytest.approx(
            4.7 * 40.0, rel=0.01)

    def test_p90_7p1x_at_60pct(self):
        assert float(queueing.p90_latency_ns(0.6)) == pytest.approx(
            7.1 * 40.0, rel=0.01)

    def test_worked_example_60_to_15(self):
        """§3.1: 4x bandwidth moves 60% util to 15%; with the 30ns premium
        the average drops ~50% and p90 ~68%."""
        base_avg = float(queueing.avg_latency_ns(0.60))
        base_p90 = float(queueing.p90_latency_ns(0.60))
        cxl_avg = float(queueing.avg_latency_ns(0.15)) + 30.0
        cxl_p90 = float(queueing.p90_latency_ns(0.15)) + 30.0
        assert 1 - cxl_avg / base_avg == pytest.approx(0.50, abs=0.05)
        assert 1 - cxl_p90 / base_p90 == pytest.approx(0.68, abs=0.05)

    def test_monotone_in_load(self):
        rhos = np.linspace(0.0, 0.95, 40)
        lat = np.asarray(queueing.avg_latency_ns(rhos))
        assert np.all(np.diff(lat) > 0)


# ---------------------------------------------------------------------------
# Fig 5 / §6.1: main result.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def c4():
    return coaxial.evaluate(coaxial.COAXIAL_4X)


class TestMainResult:
    def test_geomean_speedup(self, c4):
        # Paper: 1.52x average speedup.
        assert c4.geomean_speedup == pytest.approx(1.52, abs=0.06)

    def test_lbm_speedup(self, c4):
        # Paper: up to 3x, for lbm.
        lbm = float(c4.speedup[NAMES.index("lbm")])
        assert 2.5 <= lbm <= 3.3

    def test_count_above_2x(self, c4):
        # Paper: 10 of 35 workloads above 2x.
        assert 8 <= c4.n_above_2x <= 13

    def test_four_regressions_worst_gcc(self, c4):
        # Paper: four workloads lose performance, gcc worst at -26%.
        assert 3 <= c4.n_regressions <= 6
        name, worst = c4.worst
        assert name == "gcc"
        assert 0.60 <= worst <= 0.80

    def test_queue_share_of_latency(self, c4):
        # Paper §3.1: queuing is 72% of access latency on average, 91% max.
        s = c4.summary()
        assert s["queue_share_of_latency"] == pytest.approx(0.72, abs=0.05)
        assert s["max_queue_share"] == pytest.approx(0.91, abs=0.03)

    def test_queue_reduction(self, c4):
        # Paper §6.1: queuing 144ns -> 31ns on average (model: same story).
        s = c4.summary()
        assert s["mean_base_queue_ns"] > 4 * s["mean_queue_ns"]
        assert s["mean_queue_ns"] < 60.0

    def test_stream_copy_case_study(self, c4):
        # Paper §6.1: 348ns -> 120ns, ~2.9x more request throughput.
        row = c4.row("stream-copy")
        assert row["base_latency_ns"] == pytest.approx(348.0, abs=40.0)
        assert row["latency_ns"] == pytest.approx(120.0, abs=25.0)
        assert row["speedup"] == pytest.approx(2.9, abs=0.4)

    def test_utilization_drops_despite_more_traffic(self, c4):
        # Fig 5 bottom: average utilization drops ~54% -> ~34% band.
        s = c4.summary()
        assert s["mean_base_rho"] > 0.45
        assert s["mean_rho"] < 0.5 * s["mean_base_rho"] + 0.1

    def test_baseline_calibration_consistency(self):
        """The solved baseline must reproduce Table 4's IPC (self-check)."""
        res = cpu_model.solve(cpu_model.DDR_BASELINE)
        table = np.array([w.ipc for w in WORKLOADS])
        np.testing.assert_allclose(res.ipc, table, rtol=0.15)


# ---------------------------------------------------------------------------
# Fig 7 / §6.3: design points.
# ---------------------------------------------------------------------------

class TestDesignPoints:
    def test_coaxial_2x(self):
        c2 = coaxial.evaluate(coaxial.COAXIAL_2X)
        assert c2.geomean_speedup == pytest.approx(1.26, abs=0.08)

    def test_coaxial_asym(self):
        ca = coaxial.evaluate(coaxial.COAXIAL_ASYM)
        assert ca.geomean_speedup == pytest.approx(1.67, abs=0.16)

    def test_asym_beats_4x(self, c4):
        ca = coaxial.evaluate(coaxial.COAXIAL_ASYM)
        assert ca.geomean_speedup > c4.geomean_speedup

    def test_ordering(self, c4):
        c2 = coaxial.evaluate(coaxial.COAXIAL_2X)
        assert c2.geomean_speedup < c4.geomean_speedup


# ---------------------------------------------------------------------------
# Fig 8 / §6.4: latency sensitivity.
# ---------------------------------------------------------------------------

class TestLatencySensitivity:
    def test_50ns_speedup(self):
        c50 = coaxial.evaluate(coaxial.COAXIAL_4X,
                               iface_lat_ns=hw.CXL_LAT_PESSIMISTIC_NS)
        assert c50.geomean_speedup == pytest.approx(1.33, abs=0.12)

    def test_50ns_worse_than_30ns(self, c4):
        c50 = coaxial.evaluate(coaxial.COAXIAL_4X, iface_lat_ns=50.0)
        assert c50.geomean_speedup < c4.geomean_speedup

    def test_more_regressions_at_50ns(self, c4):
        c50 = coaxial.evaluate(coaxial.COAXIAL_4X, iface_lat_ns=50.0)
        assert c50.n_regressions >= c4.n_regressions


# ---------------------------------------------------------------------------
# Fig 9 / §6.5: core-utilization sensitivity.
# ---------------------------------------------------------------------------

class TestCoreUtilization:
    def test_single_core_slows_down(self):
        c1 = coaxial.evaluate(coaxial.COAXIAL_4X, n_active=1)
        # Paper: -17% average; our analytical model is harsher (-28%)
        # because it holds CPI_exec fixed -- the direction and "virtually
        # all workloads suffer" claim are what we pin.
        assert 0.65 <= c1.geomean_speedup <= 0.90
        assert np.mean(c1.speedup < 1.0) > 0.9

    def test_xalancbmk_llc_corner(self):
        c1 = coaxial.evaluate(coaxial.COAXIAL_4X, n_active=1)
        x = float(c1.speedup[NAMES.index("xalancbmk")])
        assert x == pytest.approx(1.0, abs=0.05)

    def test_66pct_utilization(self):
        c8 = coaxial.evaluate(coaxial.COAXIAL_4X, n_active=8)
        assert c8.geomean_speedup == pytest.approx(1.27, abs=0.08)

    def test_monotone_in_utilization(self):
        gms = [coaxial.evaluate(coaxial.COAXIAL_4X, n_active=n).geomean_speedup
               for n in (1, 4, 8, 12)]
        assert all(a < b for a, b in zip(gms, gms[1:]))


# ---------------------------------------------------------------------------
# Fig 3 / §3.2: variance-only experiment.
# ---------------------------------------------------------------------------

class TestVarianceExperiment:
    def test_geomeans(self):
        out = cpu_model.variance_experiment()
        gms = [v["geomean"] for v in out.values()]
        assert gms[0] == pytest.approx(0.86, abs=0.04)
        assert gms[1] == pytest.approx(0.78, abs=0.04)
        assert gms[2] == pytest.approx(0.71, abs=0.05)

    def test_stdevs_are_as_stated(self):
        out = cpu_model.variance_experiment()
        stds = [v["stdev_ns"] for v in out.values()]
        np.testing.assert_allclose(stds, [100.0, 150.0, 200.0], rtol=1e-6)

    def test_monotone_in_variance(self):
        out = cpu_model.variance_experiment()
        gms = [v["geomean"] for v in out.values()]
        assert gms[0] > gms[1] > gms[2]


# ---------------------------------------------------------------------------
# Tables 1-2: pins and area.
# ---------------------------------------------------------------------------

class TestPinsAndArea:
    def test_bw_per_pin_4x(self):
        # §2.3: "The 4x bandwidth gap is where we are today", and it is
        # conservative because PCIe's figure is per direction.
        rep = coaxial.pin_report()
        assert rep["bw_per_pin_ratio"] == pytest.approx(4.0, abs=0.5)
        assert rep["bw_per_pin_ratio_duplex"] > rep["bw_per_pin_ratio"]

    def test_table2_areas(self):
        rep = coaxial.area_report()
        assert rep["coaxial-5x"]["rel_area"] == pytest.approx(1.17, abs=0.01)
        assert rep["coaxial-2x"]["rel_area"] == pytest.approx(1.01, abs=0.01)
        assert rep["coaxial-4x"]["rel_area"] == pytest.approx(1.01, abs=0.01)

    def test_iso_pin_5x(self):
        rep = coaxial.area_report()
        assert rep["coaxial-5x"]["rel_pins"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Table 5 / §6.6: power and EDP.
# ---------------------------------------------------------------------------

class TestEDP:
    @pytest.fixture(scope="class")
    def edp(self):
        return coaxial.edp_report()

    def test_baseline_power(self, edp):
        assert edp["baseline"]["total_w"] == pytest.approx(713.0, abs=40.0)

    def test_coaxial_power(self, edp):
        assert edp["coaxial"]["total_w"] == pytest.approx(1180.0, abs=90.0)

    def test_edp_ratio(self, edp):
        assert edp["edp_ratio"] == pytest.approx(0.72, abs=0.06)

    def test_power_components(self, edp):
        assert edp["coaxial"]["cxl_iface_w"] == pytest.approx(77.0, abs=1.0)
        assert edp["coaxial"]["ddr_mc_phy_w"] == pytest.approx(52.0, abs=1.0)
