"""Device-parallel DES: the sharded lane axis is a pure wall-clock knob.

The contract (see ``repro.core.shardsim``): lane-keyed threefry streams,
unpadded-width chunk budgets, and global-lane histogram slots make the
``shard_map`` path BIT-IDENTICAL to the single-device path per cell --
for both engines, at any device count, divisible or not.  These tests
run under the 4 forced host devices the root ``conftest.py`` sets up.
"""

import os

import jax
import numpy as np
import pytest

from repro.core import coaxial, memsim, queuelut, shardsim
from repro.core.memsim import ChannelConfig

NDEV = len(jax.devices())

needs_multi = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 (forced) host devices")

#: Five heterogeneous cells: a non-divisible width on 4 devices, so the
#: NaN-padding path is exercised, plus a kappa/outstanding/eta spread.
CELLS = [ChannelConfig(rho=0.3),
         ChannelConfig(rho=0.6, kappa=2.0),
         ChannelConfig(rho=0.8, outstanding=8.0),
         ChannelConfig(rho=0.5, cxl_lat_ns=60.0),
         ChannelConfig(rho=0.7, eta=0.3)]


class TestResolveDevices:
    def test_default_is_one(self, monkeypatch):
        monkeypatch.delenv(shardsim.ENV_DEVICES, raising=False)
        assert shardsim.resolve_devices() == 1

    def test_env_knob(self, monkeypatch):
        monkeypatch.setenv(shardsim.ENV_DEVICES, "2")
        assert shardsim.resolve_devices() == 2
        monkeypatch.setenv(shardsim.ENV_DEVICES, "auto")
        assert shardsim.resolve_devices() == NDEV

    def test_explicit_wins_over_env(self, monkeypatch):
        monkeypatch.setenv(shardsim.ENV_DEVICES, "2")
        assert shardsim.resolve_devices(1) == 1
        assert shardsim.resolve_devices("auto") == NDEV

    def test_rejects_bad_values(self):
        with pytest.raises(ValueError, match=">= 1"):
            shardsim.resolve_devices(0)
        with pytest.raises(ValueError, match="exceeds"):
            shardsim.resolve_devices(len(jax.devices()) + 1)
        with pytest.raises(ValueError, match="int, 'auto' or None"):
            shardsim.resolve_devices("fast")

    def test_pad_width(self):
        assert shardsim.pad_width(5, 4) == 3
        assert shardsim.pad_width(8, 4) == 0
        assert shardsim.pad_width(1, 1) == 0


@needs_multi
class TestBitIdentical:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_nondivisible_cells(self, engine):
        # 5 lanes over 4 devices: 3 NaN pad lanes, still bit-identical.
        a = memsim.simulate(CELLS, steps=40_000, seed=7, engine=engine,
                            devices=1)
        b = memsim.simulate(CELLS, steps=40_000, seed=7, engine=engine,
                            devices=4)
        np.testing.assert_array_equal(a.hist, b.hist)
        np.testing.assert_array_equal(a.mean_ns, b.mean_ns)

    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_divisible_with_reps(self, engine):
        # 4 cells x 3 reps = 12 lanes: divides evenly, reps-tiled lanes
        # keep their global indices, merged stats match bitwise.
        cfgs = CELLS[:4]
        a = memsim.simulate(cfgs, steps=40_000, seed=3, reps=3,
                            engine=engine, devices=1)
        b = memsim.simulate(cfgs, steps=40_000, seed=3, reps=3,
                            engine=engine, devices=4)
        np.testing.assert_array_equal(a.hist, b.hist)

    def test_keep_reps_matches_per_replica(self):
        a = memsim.simulate_cells(
            memsim.stack_channels(CELLS[:2]), steps=40_000, seed=5,
            reps=2, keep_reps=True, devices=1)
        b = memsim.simulate_cells(
            memsim.stack_channels(CELLS[:2]), steps=40_000, seed=5,
            reps=2, keep_reps=True, devices=4)
        np.testing.assert_array_equal(a.hist, b.hist)

    def test_devices_none_honours_env(self, monkeypatch):
        monkeypatch.setenv(shardsim.ENV_DEVICES, "4")
        a = memsim.simulate(CELLS[:3], steps=30_000, seed=1)
        monkeypatch.delenv(shardsim.ENV_DEVICES)
        b = memsim.simulate(CELLS[:3], steps=30_000, seed=1)
        np.testing.assert_array_equal(a.hist, b.hist)


@needs_multi
class TestEntryPoints:
    def test_distribution_sweep_device_invariant(self):
        kw = dict(rho=(0.3, 0.7), outstanding=(8.0, 256.0),
                  steps=30_000, reps=2)
        a = coaxial.distribution_sweep(devices=1, **kw)
        b = coaxial.distribution_sweep(devices=4, **kw)
        np.testing.assert_array_equal(a.stats.hist, b.stats.hist)
        np.testing.assert_array_equal(a.stats.mean_ns, b.stats.mean_ns)

    def test_build_queue_lut_device_invariant(self):
        kw = dict(rho=(0.3, 0.7), kappa=(1.0, 2.0),
                  outstanding=(8.0, 256.0), eta=(0.3, 1.0), steps=20_000)
        a = queuelut.build_queue_lut(devices=1, **kw)
        b = queuelut.build_queue_lut(devices=4, **kw)
        np.testing.assert_array_equal(np.asarray(a.wait_ns),
                                      np.asarray(b.wait_ns))
        np.testing.assert_array_equal(np.asarray(a.sigma_ns),
                                      np.asarray(b.sigma_ns))

    def test_validate_calibration_device_invariant(self):
        a = coaxial.validate_calibration(rhos=(0.4,), steps=30_000,
                                         reps=4, devices=1)
        b = coaxial.validate_calibration(rhos=(0.4,), steps=30_000,
                                         reps=4, devices=4)
        assert a["anchors"][0]["des_mean_ns"] == \
            b["anchors"][0]["des_mean_ns"]

    def test_crosscheck_reports_se_columns(self):
        cc = coaxial.crosscheck_engines(rhos=(0.4,), steps=30_000,
                                        reps=4, devices=NDEV)
        a = cc["anchors"][0]
        for col in ("mean_se_ns", "mean_z", "p90_se_ns", "p90_z"):
            assert col in a and np.isfinite(a[col])
        assert cc["se_k"] == coaxial.ENGINE_SE_K


@needs_multi
class TestTracePins:
    @pytest.mark.parametrize("engine", memsim.ENGINES)
    def test_one_trace_per_width_and_devices(self, engine):
        # A fresh (width, devices) pair traces the engine body exactly
        # once; repeating it is a pure cache hit.  Width 7 is unused by
        # any other test in this module.
        cfgs = [ChannelConfig(rho=0.1 * i + 0.2) for i in range(7)]
        memsim.simulate(cfgs, steps=4_000, seed=0, engine=engine,
                        devices=4)                     # warm the cache
        before = memsim.sim_trace_count(engine)
        memsim.simulate(cfgs, steps=4_000, seed=1, engine=engine,
                        devices=4)
        assert memsim.sim_trace_count(engine) == before  # cache hit
