"""Distribution sweeps: DES <-> closed-form cross-validation + structure.

The contract of the memsim/sweepspec unification:

  * the DES and the calibrated closed form tell the same story -- mean
    within 15% and p90 within 20% of ``queueing`` at every rho anchor,
    and the paper's §3.1 worked example reproduced by the *mechanism*,
    not just the closed form;
  * a named-axis distribution grid of ANY dimensionality costs one XLA
    trace, slices by coordinate with the same tolerant-matching KeyError
    UX as ``SweepResult.sel``, and is bit-identical to the legacy
    ``memsim.simulate(configs)`` path for the same seed;
  * histograms conserve mass, CDFs are monotone, seeds reproduce
    exactly, the warmup window excludes the cold-start transient, and
    mean latency is monotone in rho and in the CXL premium.
"""

import numpy as np
import pytest

from repro.core import coaxial, memsim, queueing
from repro.core.memsim import ChannelConfig, LatencyStats
from repro.core.sweepspec import distribution_spec, sweep_spec

#: Shared cross-validation settings: one batched sweep, reused by the
#: whole module (seed pinned; see validate_calibration's reps-based
#: variance reduction).
VAL_STEPS = 200_000
VAL_SEED = 3
VAL_REPS = 48


@pytest.fixture(scope="module")
def val():
    return coaxial.validate_calibration(steps=VAL_STEPS, seed=VAL_SEED,
                                        reps=VAL_REPS)


class TestCrossValidation:
    """DES vs closed form (the acceptance gate)."""

    def test_mean_within_15pct_at_every_anchor(self, val):
        for a in val["anchors"]:
            assert abs(a["mean_err"]) <= 0.15, (
                f"rho={a['rho']}: DES mean {a['des_mean_ns']:.1f} vs "
                f"closed form {a['closed_mean_ns']:.1f} "
                f"({a['mean_err']:+.1%})")

    def test_p90_within_20pct_at_every_anchor(self, val):
        for a in val["anchors"]:
            assert abs(a["p90_err"]) <= 0.20, (
                f"rho={a['rho']}: DES p90 {a['des_p90_ns']:.1f} vs "
                f"closed form {a['closed_p90_ns']:.1f} "
                f"({a['p90_err']:+.1%})")

    def test_ok_flag_and_summary(self, val):
        assert val["ok"]
        assert val["max_abs_mean_err"] <= val["mean_tol"]
        assert val["max_abs_p90_err"] <= val["p90_tol"]
        # stdev is gated loosely (the closed-form sigma is a §6.2
        # workload-level calibration, so the DES runs up to ~2x above it;
        # the bound only catches drift out of that known envelope).
        assert val["max_abs_stdev_err"] <= val["stdev_tol"]
        assert all(np.isfinite(a["stdev_err"]) for a in val["anchors"])

    def test_anchor_values_match_closed_form_helpers(self, val):
        a = val["anchors"][4]
        assert a["rho"] == pytest.approx(0.5)
        cf = queueing.closed_form_stats(0.5)
        assert a["closed_mean_ns"] == pytest.approx(float(cf["mean_ns"]))
        assert a["closed_p90_ns"] == pytest.approx(float(cf["p90_ns"]))
        # kappa=1 degrades to the paper's calibrated Fig-2a anchors.
        assert float(cf["mean_ns"]) == pytest.approx(120.0)
        assert float(cf["p90_ns"]) == pytest.approx(188.0)

    def test_worked_example_60_to_15_by_des(self):
        """§3.1 via the mechanism: a 60%-utilized DDR channel moved to
        15% utilization plus a 30ns CXL premium loses ~50% of its mean
        latency and ~68% of its p90 -- the paper's numbers, which the
        closed form matches exactly; the DES must land within a few
        points of them."""
        sw = coaxial.distribution_sweep(
            rho=(0.6, 0.15), cxl_lat_ns=(0.0, 30.0),
            steps=VAL_STEPS, seed=VAL_SEED, reps=32)
        ddr = sw.sel(rho=0.6, cxl_lat_ns=0.0)
        cxl = sw.sel(rho=0.15, cxl_lat_ns=30.0)
        mean_drop = 1.0 - float(cxl.mean_ns) / float(ddr.mean_ns)
        p90_drop = 1.0 - float(cxl.p90_ns) / float(ddr.p90_ns)
        assert mean_drop == pytest.approx(0.50, abs=0.10)
        assert p90_drop == pytest.approx(0.68, abs=0.08)


class TestStructure:
    def test_three_axis_grid_is_one_trace(self):
        # A flattened cell count no other test uses forces a fresh trace
        # of the (chunked) timestep kernel; the whole 3-axis grid must
        # bump the counter by one.  The chunk length is a module
        # constant, so the cache keys on the cell count alone -- not
        # even the step budget retraces.
        spec = distribution_spec(rho=(0.2, 0.4, 0.6),
                                 kappa=(1.0, 1.7),
                                 cxl_lat_ns=(0.0, 30.0))
        before = memsim.sim_trace_count("timestep")
        sw = coaxial.distribution_sweep(spec, steps=30_000)
        assert sw.shape == (3, 2, 2)
        assert memsim.sim_trace_count("timestep") == before + 1
        # Same flattened size, different axis values AND different step
        # budget: cache hit.
        coaxial.distribution_sweep(
            distribution_spec(rho=(0.1, 0.3, 0.7), kappa=(1.2, 2.4),
                              stall_ns=(30.0, 45.0)), steps=46_000)
        assert memsim.sim_trace_count("timestep") == before + 1

    def test_batched_sweep_equals_legacy_simulate_bitwise(self):
        spec = distribution_spec(rho=(0.3, 0.6), cxl_lat_ns=(0.0, 30.0))
        sw = coaxial.distribution_sweep(spec, steps=40_000, seed=7)
        # Legacy config list in the sweep's row-major flat cell order.
        configs = [ChannelConfig(rho=r, cxl_lat_ns=c)
                   for r in (0.3, 0.6) for c in (0.0, 30.0)]
        ref = memsim.simulate(configs, steps=40_000, seed=7)
        np.testing.assert_array_equal(
            sw.stats.hist.reshape(4, -1), ref.hist)
        np.testing.assert_array_equal(
            sw.stats.mean_ns.reshape(-1), ref.mean_ns)

    def test_cdf_monotone_and_mass_conserved(self):
        stats = memsim.simulate([ChannelConfig(rho=0.5),
                                 ChannelConfig(rho=0.8)],
                                steps=60_000, seed=1)
        for i in range(2):
            x, c = stats.cdf(i)
            assert np.all(np.diff(c) >= -1e-12)
            assert c[-1] == pytest.approx(1.0)
            total = stats.hist[i].sum()
            assert total > 0
            # No silent clipping: the overflow bin holds <1% of the mass.
            assert stats.hist[i, -1] <= 0.01 * total
        # Mass == recorded arrivals: the two cells see the same arrival
        # draws scaled by rate, so counts scale ~ rho (within noise).
        n0, n1 = stats.hist.sum(axis=1)
        assert n1 / n0 == pytest.approx(0.8 / 0.5, rel=0.05)

    def test_exact_seed_reproducibility(self):
        a = memsim.simulate([ChannelConfig(rho=0.6)], steps=30_000, seed=9)
        b = memsim.simulate([ChannelConfig(rho=0.6)], steps=30_000, seed=9)
        np.testing.assert_array_equal(a.hist, b.hist)
        c = memsim.simulate([ChannelConfig(rho=0.6)], steps=30_000, seed=10)
        assert not np.array_equal(a.hist, c.hist)

    def test_reps_merge_histograms(self):
        one = memsim.simulate([ChannelConfig(rho=0.5)], steps=30_000,
                              seed=2, reps=4)
        assert one.hist.shape == (1, memsim.N_BINS)
        base = memsim.simulate([ChannelConfig(rho=0.5)], steps=30_000,
                               seed=2, reps=1)
        # 4 replicas record ~4x the arrivals of one.
        assert one.hist.sum() == pytest.approx(4 * base.hist.sum(), rel=0.1)

    def test_warmup_default_and_exclusion(self):
        cfg = [ChannelConfig(rho=0.7)]
        auto = memsim.simulate(cfg, steps=50_000, seed=4)
        explicit = memsim.simulate(cfg, steps=50_000, seed=4, warmup=5_000)
        np.testing.assert_array_equal(auto.hist, explicit.hist)
        cold = memsim.simulate(cfg, steps=50_000, seed=4, warmup=0)
        # Same seed => same sample path, so the warmup run records exactly
        # a sub-histogram: the cold run's counts minus the first 5000 ns.
        assert auto.hist.sum() < cold.hist.sum()
        assert np.all(auto.hist <= cold.hist)

    def test_warmup_removes_cold_start_bias(self):
        # The excluded window starts from an empty queue, so ITS mean is
        # below the steady-state mean; averaged over replicas this is the
        # downward bias the warmup exists to remove.  The excluded-window
        # histogram is recovered exactly as cold - warm (same paths).
        cfg = [ChannelConfig(rho=0.85)]
        warm = memsim.simulate(cfg, steps=30_000, seed=0, warmup=15_000,
                               reps=64)
        cold = memsim.simulate(cfg, steps=30_000, seed=0, warmup=0,
                               reps=64)
        excluded = cold.hist - warm.hist
        assert np.all(excluded >= 0)
        centers = (np.arange(excluded.shape[-1]) + 0.5) * memsim.BIN_NS
        mean_excluded = (excluded[0] * centers).sum() / excluded[0].sum()
        assert mean_excluded < float(warm.mean_ns[0])

    def test_stall_alpha_one_is_not_a_singularity(self):
        # The in-trace truncated-Pareto mean has an a->1 limit (log form);
        # sweeping the slope THROUGH 1.0 must yield finite, sane stats,
        # not silent NaN-into-bin-0 garbage.
        sw = coaxial.distribution_sweep(rho=(0.5,),
                                        stall_alpha=(1.0, 2.138),
                                        steps=20_000)
        cell = sw.sel(rho=0.5, stall_alpha=1.0)
        assert np.isfinite(cell.hist).all()
        assert float(cell.mean_ns) > 50.0   # heavier than the default slope

    def test_warmup_validation(self):
        with pytest.raises(ValueError, match="warmup"):
            memsim.simulate([ChannelConfig(rho=0.5)], steps=1_000,
                            warmup=1_000)
        with pytest.raises(ValueError, match="reps"):
            memsim.simulate([ChannelConfig(rho=0.5)], steps=1_000, reps=0)

    def test_mean_monotone_in_rho_and_cxl_property(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=8, deadline=None)
        @given(st.floats(0.05, 0.55), st.floats(0.12, 0.35),
               st.floats(5.0, 80.0))
        def run(rho_lo, gap, cxl):
            rho_hi = rho_lo + gap
            stats = memsim.simulate(
                [ChannelConfig(rho=rho_lo), ChannelConfig(rho=rho_hi),
                 ChannelConfig(rho=rho_lo, cxl_lat_ns=cxl)],
                steps=40_000, seed=0, reps=2)
            lo, hi, shifted = stats.mean_ns
            assert hi > lo          # more load => more queueing
            # The premium shifts the whole distribution up by ~cxl
            # (exactly, modulo 4ns histogram binning).
            assert shifted - lo == pytest.approx(cxl, abs=memsim.BIN_NS)

        run()


class TestSelUX:
    @pytest.fixture(scope="class")
    def sw(self):
        return coaxial.distribution_sweep(
            rho=tuple(np.linspace(0.2, 0.6, 3)), kappa=(1.0, 2.0),
            cxl_lat_ns=(0.0, 30.0), steps=20_000)

    def test_full_pin_returns_latency_stats(self, sw):
        cell = sw.sel(rho=0.4, kappa=2.0, cxl_lat_ns=30.0)
        assert isinstance(cell, LatencyStats)
        assert cell.hist.ndim == 1
        assert float(cell.p90_ns) >= float(cell.p50_ns)
        x, c = cell.cdf()
        assert c[-1] == pytest.approx(1.0)

    def test_partial_sel_keeps_axes(self, sw):
        sub = sw.sel(kappa=1.0)
        assert isinstance(sub, coaxial.DistributionSweepResult)
        assert sub.axis_names == ("rho", "cxl_lat_ns")
        assert sub.shape == (3, 2)
        cell = sub.sel(rho=0.2, cxl_lat_ns=0.0)
        assert isinstance(cell, LatencyStats)

    def test_tolerant_numeric_lookup(self, sw):
        # linspace coordinates resolve from clean literals, and ints
        # match floats.
        a = sw.sel(rho=0.4, kappa=2, cxl_lat_ns=30)
        b = sw.sel(rho=0.4, kappa=2.0, cxl_lat_ns=30.0)
        np.testing.assert_array_equal(a.hist, b.hist)

    def test_unknown_coordinate_lists_valid_ones(self, sw):
        with pytest.raises(KeyError, match=r"valid coordinates.*0\.4"):
            sw.sel(rho=0.45)

    def test_unknown_axis_lists_axes(self, sw):
        with pytest.raises(KeyError, match="cxl_lat_ns"):
            sw.sel(stall_prob=0.01)

    def test_cell_requires_pinning_long_axes(self, sw):
        with pytest.raises(KeyError, match="kappa"):
            sw.cell(rho=0.4)
        one = sw.sel(kappa=1.0, cxl_lat_ns=0.0)
        # Length-1 axes may be omitted after reduction.
        assert isinstance(one.cell(rho=0.4), LatencyStats)

    def test_curve_helper(self, sw):
        x, y = sw.curve("rho", "p90_ns", kappa=1.0, cxl_lat_ns=0.0)
        assert x.shape == y.shape == (3,)
        assert np.all(np.diff(y) > 0)
        with pytest.raises(KeyError, match="pinned"):
            sw.curve("rho")

    def test_spec_target_dispatch(self):
        spec = distribution_spec(rho=(0.3,), cxl_lat_ns=(0.0, 10.0))
        assert spec.target == "memsim"
        sw = spec.solve(steps=10_000)
        assert isinstance(sw, coaxial.DistributionSweepResult)
        assert sweep_spec().target == "cpu"

    def test_spec_validation_errors(self):
        with pytest.raises(ValueError, match="bindable channel fields"):
            distribution_spec(llc_mb_per_core=(1.0,))
        with pytest.raises(ValueError, match="not a channel coordinate"):
            distribution_spec(rho=(0.5, None))
        with pytest.raises(ValueError, match="at least one axis"):
            distribution_spec()
        with pytest.raises(ValueError, match="no coordinate values"):
            distribution_spec(rho=())
        # Channel fields are NOT cpu-sweep axes and vice versa.
        with pytest.raises(ValueError, match="unknown sweep axis"):
            sweep_spec(rho=(0.5,))
        with pytest.raises(TypeError, match="spec OR axis keywords"):
            coaxial.distribution_sweep(distribution_spec(rho=(0.5,)),
                                       rho=(0.6,))
