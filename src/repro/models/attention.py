"""Attention: GQA projections, chunked (flash-style) training attention,
and channelized decode attention.

Training/prefill attention is an online-softmax scan over KV chunks -- the
pure-JAX flash algorithm -- so the compiled memory footprint is O(S * chunk)
instead of O(S^2), which is what lets the 32k prefill cells compile with
sane ``memory_analysis`` numbers.

Decode attention reads one query step against a (possibly sequence-sharded)
KV cache.  With the cache sharded over the ``model`` mesh axis by sequence
blocks, each chip streams only its local KV bytes from HBM and XLA combines
the partial softmax terms with small collectives -- the paper's channelized
memory system, verbatim (DESIGN.md §3, core/planner.plan_decode_kv).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Spec, apply_rope

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig, layered: bool = True,
               n_layers: Optional[int] = None) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    nl = cfg.n_layers if n_layers is None else n_layers
    ls, la = ((nl,), ("layers",)) if layered else ((), ())
    return {
        "wq": Spec(ls + (d, nq * hd), la + ("embed", "heads")),
        "wk": Spec(ls + (d, nkv * hd), la + ("embed", "kv_heads")),
        "wv": Spec(ls + (d, nkv * hd), la + ("embed", "kv_heads")),
        "wo": Spec(ls + (nq * hd, d), la + ("heads", "embed")),
    }


ATTN_USE_SPECS = {"wq": (None, "model"), "wk": (None, "model"),
                  "wv": (None, "model"), "wo": ("model", None)}


def qkv_project(cfg: ModelConfig, p: dict, x, positions):
    """x: (B, S, D) -> q (B, S, Hq, hd), k/v (B, S, Hk, hd), roped."""
    from repro.distributed import context
    p = context.use_params(p, ATTN_USE_SPECS)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q, k = apply_rope(q, k, positions, hd, cfg.rope_theta,
                      cfg.mrope_sections)
    return q, k, v


def _expand_kv(k, groups: int):
    """(B, S, Hk, D) -> (B, S, Hk*groups, D) by repeating each KV head."""
    return jnp.repeat(k, groups, axis=2)


def reference_attention(q, k, v, causal: bool = True):
    """O(S^2) oracle used by tests and tiny models.  (B,S,H,D) layout."""
    groups = q.shape[2] // k.shape[2]
    k, v = _expand_kv(k, groups), _expand_kv(v, groups)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def flash_attention(q, k, v, causal: bool = True, chunk: int = 512):
    """Online-softmax attention, scanning KV in chunks.  (B,S,H,D) layout.

    Memory: O(B * S * H * D + B * chunk * H * D) -- no S x S score tensor.
    """
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    groups = hq // k.shape[2]
    if sk % chunk:
        chunk = sk  # fall back for odd sizes (smoke tests)
    n_chunks = sk // chunk
    scale = d ** -0.5

    k = k.reshape(b, n_chunks, chunk, -1, d).transpose(1, 0, 2, 3, 4)
    v = v.reshape(b, n_chunks, chunk, -1, d).transpose(1, 0, 2, 3, 4)
    q_scaled = (q * scale).astype(q.dtype)
    q_pos = jnp.arange(sq)

    def body(carry, xs):
        acc, m, denom, idx = carry
        kc, vc = xs                                     # (B, c, Hk, D)
        kc = _expand_kv(kc, groups)
        vc = _expand_kv(vc, groups)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_scaled,
                            kc).astype(jnp.float32)
        if causal:
            k_pos = idx * chunk + jnp.arange(chunk)
            mask = q_pos[:, None] + (sk - sq) >= k_pos[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        correction = jnp.exp(m - m_new)
        denom = denom * correction + p.sum(axis=-1)
        acc = acc * correction[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (acc, m_new, denom, idx + 1), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((b, hq, sq), jnp.float32)
    (acc, _, denom, _), _ = jax.lax.scan(body, (acc0, m0, d0, 0), (k, v))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B, S, H, D)


def decode_attention(q, k_cache, v_cache, cache_len, q_start=None):
    """Attention of new tokens against a KV cache (decode or prefill).

    q: (B, Sq, Hq, D); k/v_cache: (B, S_max, Hk, D); cache_len: (B,) valid
    lengths AFTER the new tokens were written (entries at key positions
    >= cache_len are masked out).  ``q_start`` (scalar) is the absolute
    position of q's first token; when given, causality *within* the new
    block is enforced: query i attends keys at positions <= q_start + i.

    The cache's S_max axis may carry a ``model``-axis sharding: XLA then
    computes per-shard partial (max, sum, acc) and combines -- the
    channelized-decode data path.
    """
    from repro.distributed import context
    b, sq, hq, d = q.shape
    hk = k_cache.shape[2]
    groups = hq // hk
    scale = d ** -0.5
    # GQA-native grouped einsum: contract each KV head against its G query
    # heads directly -- no materialized jnp.repeat of the cache (H8).
    qg = (q * scale).reshape(b, sq, hk, groups, d)
    k, v = k_cache, v_cache
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    if context.flag("kv_partials"):
        # Pin the logits to the cache's sequence sharding so GSPMD computes
        # shard-local partial softmax (small all-reduces of max/denom/acc)
        # instead of all-gathering the whole KV cache (EXPERIMENTS.md §Perf
        # H7 -- this is the flash-decode combine, the channelized read).
        logits = context.constrain(
            logits, ("batch", "none", "none", "none", "kv_seq"))
    k_pos = jnp.arange(k_cache.shape[1])
    mask = k_pos[None, :] < cache_len[:, None]              # (B, Sk)
    mask = mask[:, None, None, None, :]                     # (B,1,1,1,Sk)
    if q_start is not None:
        q_pos = q_start + jnp.arange(sq)                    # (Sq,)
        causal = k_pos[None, :] <= q_pos[:, None]           # (Sq, Sk)
        mask = jnp.logical_and(mask, causal[None, None, None, :, :])
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    if context.flag("kv_partials"):
        probs = context.constrain(
            probs, ("batch", "none", "none", "none", "kv_seq"))
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    out = out.reshape(b, sq, hq, d)                          # (B,Sq,Hq,D)
    if context.flag("kv_partials"):
        out = context.constrain(out, ("batch", "none", "none", "none"))
    return out
