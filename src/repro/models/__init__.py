"""Model zoo: config, layers, attention, MoE, SSM (Mamba2), RWKV6, stacks."""

from repro.models.config import ModelConfig, smoke_variant  # noqa: F401
from repro.models.model import Model, batch_spec, decode_batch_spec  # noqa: F401
