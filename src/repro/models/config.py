"""Model configuration: one frozen dataclass describes every supported arch.

The ten assigned architectures (plus reduced smoke variants) are instances of
:class:`ModelConfig`; the block layout is selected by ``family``:

  dense   -- GQA attention + SwiGLU MLP decoder (stablelm, starcoder2,
             mistral-large, and the qwen2-vl backbone with M-RoPE)
  moe     -- GQA attention + top-k routed experts (olmoe, phi3.5-moe)
  hybrid  -- Mamba2 backbone with a *shared* attention block applied every
             ``attn_every`` layers (zamba2)
  ssm     -- attention-free RWKV6 time-mix/channel-mix (rwkv6)
  audio   -- encoder-only transformer over precomputed frame embeddings
             (hubert; the conv frontend is a stub per the assignment)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int

    # Attention / positions
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple] = None   # qwen2-vl M-RoPE (t, h, w)
    sliding_window: int = 0        # 0 -> full attention

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2) / hybrid
    ssm_state: int = 0             # N: state size per head
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_head_dim: int = 64         # P: channels per SSD head
    ssm_conv: int = 4              # depthwise conv window
    attn_every: int = 6            # hybrid: shared attn block cadence

    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 32

    # Encoder-only (audio): no causal mask, no decode path.
    encoder_only: bool = False
    # Modality frontend stub: inputs arrive as embeddings, not token ids.
    embed_inputs: bool = False

    # Numerics / activations
    activation: str = "swiglu"     # swiglu | gelu | relu2
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"        # parameter/compute dtype

    # Training defaults (overridable per run)
    remat: str = "full"            # full | dots | none

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode (O(1)-state or hybrid)?"""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D MODEL_FLOPS)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d
        mlp_mats = 3 if self.activation == "swiglu" else 2
        mlp = mlp_mats * d * f
        per_layer = 0.0
        if self.family in ("dense", "vlm"):
            per_layer = attn + mlp + 2 * d
        elif self.family == "audio":
            per_layer = attn + mlp + 4 * d
        elif self.family == "moe":
            router = d * self.n_experts
            per_layer = attn + router + self.n_experts * mlp + 2 * d
        elif self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            h = self.ssm_heads
            in_proj = d * (2 * di + 2 * n + h)
            per_layer = (in_proj + self.ssm_conv * (di + 2 * n) +
                         di * d + 3 * h + 2 * d)
        elif self.family == "ssm":
            r = self.rwkv_lora_rank
            tm = 4 * d * d + d * d + 6 * (d * r + r * d) + 4 * d
            cm = 2 * d * f * 0 + d * f + f * d + 2 * d   # relu^2 channel-mix
            per_layer = tm + cm
        total = self.n_layers * per_layer + v * d + 2 * d
        if not self.tie_embeddings:
            total += d * v
        if self.family == "hybrid":  # shared attention block
            total += attn + mlp + 2 * d
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_mats = 3 if self.activation == "swiglu" else 2
        expert = mlp_mats * d * f
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return int(self.param_count() - inactive)


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2 if cfg.family != "hybrid" else 4,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_heads else 0,
        d_ff=128,
        vocab=256,
        head_dim=16 if cfg.n_heads else 0,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        capacity_factor=8.0,   # no token dropping in smoke tests
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=16,
        rwkv_head_dim=16,
        rwkv_lora_rank=8,
        attn_every=2,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else None,
        dtype="float32",
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
