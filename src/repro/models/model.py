"""Public model API: init / loss / prefill / decode_step per architecture.

The same four entry points cover all ten assigned architectures; the launch
layer (train.py / serve.py / dryrun.py) only ever talks to this interface.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.config import ModelConfig
from repro.models.transformer import FRONTEND_DIM, forward, init_cache

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def specs(self):
        return transformer.model_specs(self.cfg)

    def init(self, key):
        return layers.init_params(self.specs(), key, DTYPES[self.cfg.dtype])

    def logical_axes(self):
        return layers.logical_axes(self.specs())

    def param_count(self) -> int:
        return layers.param_count(self.specs())

    # -- training -----------------------------------------------------------
    def loss(self, params, batch):
        """Mean next-token (or masked-prediction) CE -> (loss, metrics)."""
        from repro.distributed import context
        cfg = self.cfg
        h, _ = forward(cfg, params, batch, training=True)
        w_head = layers.unembed_matrix(cfg, params["embed"])
        w_head = context.use_params({"w": w_head},
                                    {"w": (None, "model")})["w"]
        loss = layers.chunked_ce_loss(h, w_head, batch["targets"],
                                      batch["loss_mask"].astype(jnp.float32))
        metrics = {"loss": loss}
        return loss, metrics

    # -- serving ------------------------------------------------------------
    def make_cache(self, batch_size: int, max_len: int):
        dtype = DTYPES[self.cfg.dtype]
        return init_cache(self.cfg, batch_size, max_len, dtype)

    def prefill(self, params, batch, cache):
        """Run a prompt through the model, filling ``cache``.

        Returns (last-position logits (B, V), cache)."""
        h, cache = forward(self.cfg, params, batch, cache=cache)
        w_head = layers.unembed_matrix(self.cfg, params["embed"])
        logits = (h[:, -1, :] @ w_head).astype(jnp.float32)
        return logits, cache

    def decode_step(self, params, step_batch, cache):
        """One-token decode: step_batch holds (B, 1) tokens + positions.

        Returns (logits (B, V), new cache).  This is the function the
        ``decode_*`` and ``long_*`` dry-run shapes lower.
        """
        h, cache = forward(self.cfg, params, step_batch, cache=cache)
        w_head = layers.unembed_matrix(self.cfg, params["embed"])
        logits = (h[:, -1, :] @ w_head).astype(jnp.float32)
        return logits, cache

    def greedy_generate(self, params, batch, cache, steps: int):
        """Greedy decoding loop (lax.scan over steps) for examples/tests."""
        cfg = self.cfg
        logits, cache = self.prefill(params, batch, cache)
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def step(carry, _):
            tok, cache = carry
            pos = jnp.broadcast_to(cache["len"][None, None],
                                   (tok.shape[0], 1)).astype(jnp.int32)
            if cfg.mrope_sections:
                pos = jnp.broadcast_to(pos[..., None],
                                       pos.shape + (3,)).astype(jnp.int32)
            sb = dict(tokens=tok[:, None], positions=pos)
            logits, cache = self.decode_step(params, sb, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, cache), nxt

        (_, cache), toks = jax.lax.scan(step, (tok0, cache), None,
                                        length=steps)
        return jnp.moveaxis(toks, 0, 1), cache   # (B, steps)


# ---------------------------------------------------------------------------
# Batch construction helpers (shared by data pipeline and input_specs).
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs for one training batch of this architecture."""
    i32 = jnp.int32
    specs = {}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, seq, FRONTEND_DIM), DTYPES[cfg.dtype])
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if cfg.mrope_sections:
        specs["positions"] = jax.ShapeDtypeStruct((batch, seq, 3), i32)
    else:
        specs["positions"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, seq, FRONTEND_DIM), DTYPES[cfg.dtype])
        specs["vision_mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.bool_)
    specs["targets"] = jax.ShapeDtypeStruct((batch, seq), i32)
    specs["loss_mask"] = jax.ShapeDtypeStruct((batch, seq), i32)
    return specs


def decode_batch_spec(cfg: ModelConfig, batch: int) -> dict:
    """ShapeDtypeStructs for a one-token decode step."""
    i32 = jnp.int32
    specs = {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
    if cfg.mrope_sections:
        specs["positions"] = jax.ShapeDtypeStruct((batch, 1, 3), i32)
    else:
        specs["positions"] = jax.ShapeDtypeStruct((batch, 1), i32)
    return specs
