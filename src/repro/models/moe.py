"""Top-k routed mixture-of-experts with capacity-based einsum dispatch.

Expert-parallel friendly: the (E, C, D) dispatch buffers and the (E, D, F)
expert weights carry an ``experts`` logical axis that the sharding rules map
to the ``model`` mesh axis, so the grouped matmuls run as EP and XLA inserts
the token all-to-alls.  Routing uses deterministic position-in-expert ranks
(cumsum over the flattened token-slot order), the standard
Switch/GShard-style capacity discipline: overflow tokens fall back to the
residual path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Spec


def moe_specs(cfg: ModelConfig, layered: bool = True) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ls, la = ((cfg.n_layers,), ("layers",)) if layered else ((), ())
    specs = {
        "router": Spec(ls + (d, e), la + ("embed", "experts_router")),
        "wi": Spec(ls + (e, d, f), la + ("experts", "embed", "mlp")),
        "wo": Spec(ls + (e, f, d), la + ("experts", "mlp", "embed")),
    }
    if cfg.activation == "swiglu":
        specs["wg"] = Spec(ls + (e, d, f), la + ("experts", "embed", "mlp"))
    return specs


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cap, cfg.top_k)


def moe_apply(cfg: ModelConfig, p: dict, x, return_aux: bool = False):
    """x: (B, S, D) -> (B, S, D) [+ aux losses dict]."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(t, d)

    from repro.distributed import context
    p = context.use_params(p, {"router": (None, None),
                               "wi": ("model", None, None),
                               "wg": ("model", None, None),
                               "wo": ("model", None, None)})
    gate_logits = (xf @ p["router"]).astype(jnp.float32)     # (T, E)
    gates = jax.nn.softmax(gate_logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                     # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    cap = capacity(cfg, t)
    # Rank each (token, slot) within its expert, in flat priority order.
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)        # (T, k, E)
    flat = onehot.reshape(t * k, e)
    ranks = jnp.cumsum(flat, axis=0) - flat                  # exclusive
    rank_of = (ranks * flat).sum(-1).reshape(t, k)           # (T, k)
    keep = (rank_of < cap)
    slot = jnp.minimum(rank_of, cap - 1)

    eid = topi.reshape(-1)                                   # (T*k,)
    sid = slot.reshape(-1)
    w_disp = (topw * keep).astype(x.dtype).reshape(-1)       # (T*k,)

    # Dispatch: scatter token vectors into per-expert capacity buffers.
    upd = jnp.repeat(xf, k, axis=0) * (w_disp != 0)[:, None]
    buf = jnp.zeros((e, cap, d), x.dtype).at[eid, sid].add(upd)

    # Expert computation (grouped matmuls; EP-shardable on the E axis).
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["wi"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])         # (E, C, D)

    # Combine: gather each slot back and weight by the router.
    gathered = out_buf[eid, sid]                             # (T*k, D)
    y = (gathered * w_disp[:, None]).reshape(t, k, d).sum(axis=1)
    y = y.reshape(b, s, d)

    if not return_aux:
        return y
    # Switch-style load-balance loss + router z-loss.
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32),
                       axis=0)
    router_prob = jnp.mean(gates, axis=0)
    lb_loss = e * jnp.sum(density * router_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(gate_logits, axis=-1)))
    return y, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
               "moe_overflow": 1.0 - jnp.mean(keep.astype(jnp.float32))}
