"""Mamba2 (SSD) blocks: chunked training scan + O(1)-state decode step.

The SSD computation follows the Mamba2 chunked algorithm: within a chunk of
Q tokens the output is a masked (C_i . B_j) kernel against the inputs; across
chunks a (H, N, P) state is carried by an exponential-decay recurrence
(jax.lax.scan).  Decode is the plain recurrent update -- state size is
H x N x P per layer, independent of context length, which is why the hybrid
and SSM architectures are the ones assigned the 500k-token decode shape.

Layout conventions: x (B, S, D); inner activations (B, S, H, P) with
H = d_inner / P heads; B/C projections are shared across heads (one group).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Spec

#: Chunk length for the SSD scan.
SSD_CHUNK = 64


def ssm_specs(cfg: ModelConfig, layered: bool = True,
              n_layers: int | None = None) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    cw = cfg.ssm_conv
    nl = cfg.n_layers if n_layers is None else n_layers
    ls, la = ((nl,), ("layers",)) if layered else ((), ())
    return {
        # x -> [z (di), x_ssm (di), B (n), C (n), dt (h)]
        "in_proj": Spec(ls + (d, 2 * di + 2 * n + h), la + ("embed", "ssm_inner")),
        "conv_w": Spec(ls + (cw, di + 2 * n), la + ("conv", "ssm_inner"),
                       init="normal", scale=1.0),
        "conv_b": Spec(ls + (di + 2 * n,), la + ("ssm_inner",), init="zeros"),
        "a_log": Spec(ls + (h,), la + ("heads",), init="zeros"),
        "dt_bias": Spec(ls + (h,), la + ("heads",), init="zeros"),
        "d_skip": Spec(ls + (h,), la + ("heads",), init="zeros"),
        "out_proj": Spec(ls + (di, d), la + ("ssm_inner", "embed")),
        "gate_norm": Spec(ls + (di,), la + ("ssm_inner",), init="zeros"),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xc = proj[..., di:2 * di]
    b = proj[..., 2 * di:2 * di + n]
    c = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    assert dt.shape[-1] == h
    return z, xc, b, c, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv over (B, S, C) with window len(w)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    return out + b


def _ssd_chunked(xh, dt, a, bmat, cmat, init_state=None):
    """Chunked SSD.

    xh:   (B, S, H, P) inputs
    dt:   (B, S, H)    softplus'd step sizes
    a:    (H,)         negative decay rates (a < 0)
    bmat: (B, S, N)    input->state projection (shared across heads)
    cmat: (B, S, N)    state->output projection
    init_state: optional (B, H, N, P) carried state (prefill continuation)
    returns y (B, S, H, P), final_state (B, H, N, P)
    """
    bsz, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = SSD_CHUNK if s % SSD_CHUNK == 0 else s
    nc = s // q

    f32 = jnp.float32
    xh = xh.astype(f32).reshape(bsz, nc, q, h, p)
    dt = dt.astype(f32).reshape(bsz, nc, q, h)
    bm = bmat.astype(f32).reshape(bsz, nc, q, n)
    cm = cmat.astype(f32).reshape(bsz, nc, q, n)

    da = dt * a[None, None, None, :]                   # (B,nc,Q,H), <= 0
    seg = jnp.cumsum(da, axis=2)                       # within-chunk cumsum
    total = seg[:, :, -1, :]                           # (B,nc,H)

    # Within-chunk (diagonal) term.
    cb = jnp.einsum("bcin,bcjn->bcij", cm, bm)          # (B,nc,Q,Q)
    decay = jnp.exp(seg[:, :, :, None, :] - seg[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    kern = cb[..., None] * decay * dt[:, :, None, :, :]   # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", kern, xh)

    # Chunk-boundary states: contribution of chunk c to the carried state.
    decay_to_end = jnp.exp(total[:, :, None, :] - seg)     # (B,nc,Q,H)
    state_in = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                          bm, dt * decay_to_end, xh)       # (B,nc,H,N,P)

    def scan_fn(state, inputs):
        st_in, tot = inputs                                # (B,H,N,P),(B,H)
        new = state * jnp.exp(tot)[..., None, None] + st_in
        return new, state                                  # emit state *before*

    init = (jnp.zeros((bsz, h, n, p), f32) if init_state is None
            else init_state.astype(f32))
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (state_in.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,N,P)

    # Off-diagonal term: prior state read out through C with decay.
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       cm, jnp.exp(seg), prev_states)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def mamba_apply(cfg: ModelConfig, p: dict, x, state=None, conv_state=None):
    """Mamba2 block.

    Training/prefill: x (B, S, D), state=None -> (y, (state, conv_state)).
    Decode: x (B, 1, D) with carried (state, conv_state).
    """
    bsz, s, _ = x.shape
    from repro.distributed import context
    p = context.use_params(p, {"in_proj": (None, None),
                               "out_proj": (None, None)})
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xc, bmat, cmat, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)   # (B,S,di+2n)
    if state is None:
        conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv_state = conv_in[:, -(cfg.ssm_conv - 1):, :]
    else:
        window = jnp.concatenate([conv_state, conv_in], axis=1)
        conv = _causal_conv(window, p["conv_w"], p["conv_b"])[:, -s:, :]
        new_conv_state = window[:, -(cfg.ssm_conv - 1):, :]
    conv = jax.nn.silu(conv)
    xc, bmat, cmat = (conv[..., :di], conv[..., di:di + n],
                      conv[..., di + n:])

    xh = xc.reshape(bsz, s, h, pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # (H,) < 0

    if state is None:
        y, new_state = _ssd_chunked(xh, dt, a, bmat, cmat)
    elif s > 1:
        # Prefill continuation: chunked path seeded with the carried state.
        y, new_state = _ssd_chunked(xh, dt, a, bmat, cmat, init_state=state)
    else:
        # Recurrent decode step (s == 1).
        da = jnp.exp(dt[:, 0] * a[None, :])                # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bmat[:, 0].astype(jnp.float32),
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        new_state = state * da[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32),
                       new_state)[:, None]                 # (B,1,H,P)
        new_conv_state = new_conv_state

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    # Gated RMS norm (Mamba2's norm-before-out-proj).
    gated = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(gated.astype(jnp.float32)), -1, keepdims=True)
    gated = (gated.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps) *
             (1.0 + p["gate_norm"].astype(jnp.float32))).astype(x.dtype)
    out = gated @ p["out_proj"]
    return out, (new_state, new_conv_state)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """(state, conv_state) zeros for decode."""
    state = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                       cfg.ssm_head_dim), jnp.float32)
    conv_state = jnp.zeros(
        (batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype)
    return state, conv_state
