"""Shared building blocks: param specs, norms, positions, MLPs, embeddings.

Parameters are declared as :class:`Spec` trees (shape + logical axes + init
law); ``init_params`` materializes them deterministically (the RNG for each
leaf is folded in from its tree path, so adding a module never reshuffles
another module's init), and ``logical_axes`` returns the matching axes tree
used by distributed/sharding.py to build PartitionSpecs.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Param specs.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple                  # logical axis names, len == len(shape)
    init: str = "normal"         # normal | zeros | ones
    scale: float = 1.0           # stddev multiplier on top of fan-in scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _leaf_key(key, path: str):
    return jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def _materialize(spec: Spec, key, path: str, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / max(fan_in, 1) ** 0.5
    x = jax.random.normal(_leaf_key(key, path), spec.shape, jnp.float32) * std
    return x.astype(dtype)


def init_params(spec_tree, key, dtype):
    """Materialize a Spec tree into arrays (path-deterministic RNG)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec)
    leaves = []
    for path, spec in flat:
        pstr = "/".join(str(p) for p in path)
        leaves.append(_materialize(spec, key, pstr, dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def logical_axes(spec_tree):
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree,
                                  is_leaf=is_spec)


def shapes(spec_tree):
    return jax.tree_util.tree_map(lambda s: s.shape, spec_tree,
                                  is_leaf=is_spec)


def param_count(spec_tree) -> int:
    flat = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    total = 0
    for s in flat:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dtype)


def layer_norm(x, w, b, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32)) +
            b.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary positions (RoPE + M-RoPE).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)


def _rotate(x, cos, sin):
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, head_dim: int, theta: float,
               mrope_sections: Optional[tuple] = None):
    """Rotary embedding.

    q: (B, S, Hq, D), k: (B, S, Hk, D).
    positions: (B, S) int32, or (B, S, 3) for M-RoPE (t, h, w component
    positions per token, qwen2-vl style: the frequency spectrum is split
    into ``mrope_sections`` groups, each rotated by its own position).
    """
    half = head_dim // 2
    inv = rope_freqs(head_dim, theta)                      # (half,)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)                 # (B, S)
        angles = pos[..., None] * inv                       # (B, S, half)
    else:
        assert positions.ndim == 3 and positions.shape[-1] == len(
            mrope_sections)
        # Static section->component index table (numpy, not traced).
        sec = np.concatenate([np.full((n,), i, np.int32)
                              for i, n in enumerate(mrope_sections)])
        pos = jnp.asarray(positions).astype(jnp.float32)    # (B, S, 3)
        pos_per_freq = jnp.take(pos, sec, axis=-1)          # (B, S, half)
        angles = pos_per_freq * inv                         # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :].astype(q.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(q.dtype)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, layered: bool = True) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lead = ((cfg.n_layers,), ("layers",)) if layered else ((), ())
    ls, la = lead
    if cfg.activation == "swiglu":
        return {
            "wi": Spec(ls + (d, f), la + ("embed", "mlp")),
            "wg": Spec(ls + (d, f), la + ("embed", "mlp")),
            "wo": Spec(ls + (f, d), la + ("mlp", "embed")),
        }
    return {
        "wi": Spec(ls + (d, f), la + ("embed", "mlp")),
        "wo": Spec(ls + (f, d), la + ("mlp", "embed")),
    }


MLP_USE_SPECS = {"wi": (None, "model"), "wg": (None, "model"),
                 "wo": ("model", None)}


def mlp_apply(cfg: ModelConfig, p: dict, x):
    from repro.distributed import context
    p = context.use_params(p, MLP_USE_SPECS)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(x @ p["wi"])
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        raise ValueError(cfg.activation)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding with sequence-chunked cross-entropy.
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    out = {"tokens": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["head"] = Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return out


def embed_apply(cfg: ModelConfig, p: dict, token_ids):
    return jnp.take(p["tokens"], token_ids, axis=0)


def unembed_matrix(cfg: ModelConfig, p: dict):
    if cfg.tie_embeddings:
        return p["tokens"].T
    return p["head"]


def chunked_ce_loss(h, w_head, targets, mask, chunk: int = 1024):
    """Next-token CE over (B, S, D) hidden states, seq-chunked.

    Avoids materializing the full (B, S, V) logits: lax.map over sequence
    chunks keeps live logits at (B, chunk, V).  Loss is averaged over
    ``mask`` (0/1) positions in float32.
    """
    b, s, d = h.shape
    n = max(s // chunk, 1)
    chunk = s // n
    h_c = h.reshape(b, n, chunk, d).swapaxes(0, 1)           # (n, B, c, D)
    t_c = targets.reshape(b, n, chunk).swapaxes(0, 1)
    m_c = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def one(args):
        hc, tc, mc = args
        logits = (hc @ w_head).astype(jnp.float32)           # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mc
        return nll.sum(), mc.sum()

    nll, cnt = jax.lax.map(one, (h_c, t_c, m_c))
    return nll.sum() / jnp.maximum(cnt.sum(), 1.0)
