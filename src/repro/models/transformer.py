"""Stack assembly for all six families: scan-over-layers + remat + caches.

One compiled layer body per homogeneous group (jax.lax.scan over stacked
parameters) keeps compile time flat in depth -- an 88-layer mistral-large
train step compiles the same HLO as a 2-layer one, just with a bigger scan.

Families:
  dense / vlm -- pre-RMSNorm GQA + SwiGLU, causal
  moe         -- pre-RMSNorm GQA + routed experts
  audio       -- encoder-only pre-LayerNorm GQA + GELU (bidirectional)
  hybrid      -- Mamba2 groups with one *shared* attention block applied
                 after every ``attn_every`` Mamba layers (zamba2): nested
                 scan -- outer over groups, inner over Mamba layers
  ssm         -- RWKV6 time-mix + channel-mix
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import context
from repro.models import attention, layers, moe, rwkv, ssm
from repro.models.config import ModelConfig
from repro.models.layers import Spec

#: Stub modality-frontend feature width (audio frames / vision patches).
FRONTEND_DIM = 512


# ---------------------------------------------------------------------------
# Spec assembly.
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    specs: dict = {
        "embed": layers.embed_specs(cfg),
        "final_norm": Spec((d,), ("embed",), init="zeros"),
    }
    if cfg.embed_inputs or cfg.family == "vlm":
        specs["frontend"] = {
            "proj": Spec((FRONTEND_DIM, d), ("frontend", "embed"))}

    if cfg.family in ("dense", "vlm"):
        specs["layers"] = {
            "ln1": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "ln2": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "attn": attention.attn_specs(cfg),
            "mlp": layers.mlp_specs(cfg),
        }
    elif cfg.family == "moe":
        specs["layers"] = {
            "ln1": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "ln2": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "attn": attention.attn_specs(cfg),
            "moe": moe.moe_specs(cfg),
        }
    elif cfg.family == "audio":
        specs["layers"] = {
            "ln1_w": Spec((cfg.n_layers, d), ("layers", "embed"),
                          init="zeros"),
            "ln1_b": Spec((cfg.n_layers, d), ("layers", "embed"),
                          init="zeros"),
            "ln2_w": Spec((cfg.n_layers, d), ("layers", "embed"),
                          init="zeros"),
            "ln2_b": Spec((cfg.n_layers, d), ("layers", "embed"),
                          init="zeros"),
            "attn": attention.attn_specs(cfg),
            "mlp": layers.mlp_specs(cfg),
        }
    elif cfg.family == "hybrid":
        if cfg.n_layers % cfg.attn_every:
            raise ValueError("hybrid: n_layers must divide by attn_every")
        specs["layers"] = {
            "ln1": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "mamba": ssm.ssm_specs(cfg),
        }
        specs["shared_attn"] = {
            "ln1": Spec((d,), ("embed",), init="zeros"),
            "ln2": Spec((d,), ("embed",), init="zeros"),
            "attn": attention.attn_specs(cfg, layered=False),
            "mlp": layers.mlp_specs(cfg, layered=False),
        }
    elif cfg.family == "ssm":
        specs["layers"] = {
            "ln1": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "ln2": Spec((cfg.n_layers, d), ("layers", "embed"), init="zeros"),
            "rwkv": rwkv.rwkv_specs(cfg),
        }
    else:
        raise ValueError(cfg.family)
    return specs


# ---------------------------------------------------------------------------
# Remat policy.
# ---------------------------------------------------------------------------

def _maybe_remat(cfg: ModelConfig, fn, training: bool):
    if not training or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, pl, x, positions, causal, kv_cache):
    """Returns (y, new_kv_cache); kv_cache None during training/prefill-less
    runs, else {'k','v'} (B, S_max, Hk, hd) plus scalar 'len' handled by the
    caller."""
    if kv_cache is None:
        q, k, v = attention.qkv_project(cfg, pl["attn"], x, positions)
        if x.shape[1] <= 256:
            o = attention.reference_attention(q, k, v, causal=causal)
        else:
            o = attention.flash_attention(q, k, v, causal=causal)
        new_cache = None
    else:
        k_cache, v_cache, cache_len = kv_cache
        q, k, v = attention.qkv_project(cfg, pl["attn"], x, positions)
        if x.shape[1] == 1 and context.flag("kv_select_update"):
            # Sequence-sharded caches + a traced write index make GSPMD
            # fully rematerialize (replicate!) the cache around a
            # dynamic-update-slice.  A positional select is elementwise and
            # therefore shard-local -- no resharding, no replication
            # (EXPERIMENTS.md §Perf H6).
            pos = jnp.arange(k_cache.shape[1])[None, :, None, None]
            at = pos == cache_len
            k_cache = jnp.where(at, k.astype(k_cache.dtype), k_cache)
            v_cache = jnp.where(at, v.astype(v_cache.dtype), v_cache)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
        lens = jnp.full((x.shape[0],), cache_len + x.shape[1], jnp.int32)
        o = attention.decode_attention(q, k_cache, v_cache, lens,
                                       q_start=cache_len)
        new_cache = (k_cache, v_cache)
    b, s, _, _ = o.shape
    wo = context.use_params(pl["attn"], attention.ATTN_USE_SPECS)["wo"]
    y = o.reshape(b, s, -1) @ wo
    return y, new_cache


def _dense_body(cfg, x, pl, positions, causal, kv_cache):
    h = layers.rms_norm(x, pl["ln1"], cfg.norm_eps)
    a, new_kv = _attn_block(cfg, pl, h, positions, causal, kv_cache)
    x = x + a
    h = layers.rms_norm(x, pl["ln2"], cfg.norm_eps)
    x = x + layers.mlp_apply(cfg, pl["mlp"], h)
    return x, new_kv


def _moe_body(cfg, x, pl, positions, causal, kv_cache):
    h = layers.rms_norm(x, pl["ln1"], cfg.norm_eps)
    a, new_kv = _attn_block(cfg, pl, h, positions, causal, kv_cache)
    x = x + a
    h = layers.rms_norm(x, pl["ln2"], cfg.norm_eps)
    x = x + moe.moe_apply(cfg, pl["moe"], h)
    return x, new_kv


def _audio_body(cfg, x, pl, positions, causal, kv_cache):
    h = layers.layer_norm(x, pl["ln1_w"], pl["ln1_b"], cfg.norm_eps)
    a, _ = _attn_block(cfg, pl, h, positions, causal=False, kv_cache=None)
    x = x + a
    h = layers.layer_norm(x, pl["ln2_w"], pl["ln2_b"], cfg.norm_eps)
    x = x + layers.mlp_apply(cfg, pl["mlp"], h)
    return x, None


def _rwkv_body(cfg, x, pl, cache):
    tm_shift, wkv_state, cm_shift = cache
    h = layers.rms_norm(x, pl["ln1"], cfg.norm_eps)
    y, (new_tm, new_wkv) = rwkv.time_mix(cfg, pl["rwkv"], h, tm_shift,
                                         wkv_state)
    x = x + y
    h = layers.rms_norm(x, pl["ln2"], cfg.norm_eps)
    y, new_cm = rwkv.channel_mix(cfg, pl["rwkv"], h, cm_shift)
    x = x + y
    return x, (new_tm, new_wkv, new_cm)


def _mamba_body(cfg, x, pl, cache):
    state, conv = cache if cache is not None else (None, None)
    h = layers.rms_norm(x, pl["ln1"], cfg.norm_eps)
    y, (new_state, new_conv) = ssm.mamba_apply(cfg, pl["mamba"], h, state,
                                               conv)
    return x + y, (new_state, new_conv)


# ---------------------------------------------------------------------------
# Stacks.
# ---------------------------------------------------------------------------

def _scan_uniform(cfg, body, params_layers, x, training):
    """scan over stacked per-layer params; no cache (training path)."""
    fn = _maybe_remat(cfg, lambda xx, pl: body(xx, pl), training)

    def step(xx, pl):
        xx = context.constrain(xx, ("batch", "seq", "embed"))
        return fn(xx, pl), None

    x = context.constrain(x, ("batch", "seq", "embed"))
    x, _ = jax.lax.scan(step, x, params_layers)
    return x


def _scan_with_cache(body, params_layers, x, cache):
    """scan carrying x, with per-layer cache slices as scan inputs/outputs."""

    def step(xx, inp):
        pl, cl = inp
        xx, new_cl = body(xx, pl, cl)
        return xx, new_cl

    x, new_cache = jax.lax.scan(step, x, (params_layers, cache))
    return x, new_cache


def _embed_inputs(cfg: ModelConfig, params, batch):
    if cfg.family == "audio":
        return batch["frames"] @ params["frontend"]["proj"]
    x = layers.embed_apply(cfg, params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "vision_embeds" in batch:
        proj = batch["vision_embeds"] @ params["frontend"]["proj"]
        x = jnp.where(batch["vision_mask"][..., None], proj, x)
    return x


def forward(cfg: ModelConfig, params, batch, *, training: bool = False,
            cache: Optional[dict] = None):
    """Full forward pass -> (hidden (B,S,D), new_cache_or_None).

    ``batch`` keys: tokens (B,S) int32 [or frames (B,S,FRONTEND_DIM)],
    positions (B,S) [or (B,S,3) for M-RoPE].  When ``cache`` is given the
    pass is an incremental decode/prefill continuation.
    """
    x = _embed_inputs(cfg, params, batch)
    positions = batch["positions"]
    fam = cfg.family

    if fam in ("dense", "vlm", "moe", "audio"):
        body = {"dense": _dense_body, "vlm": _dense_body,
                "moe": _moe_body, "audio": _audio_body}[fam]
        if cache is None:
            x = _scan_uniform(
                cfg, lambda xx, pl: body(cfg, xx, pl, positions,
                                         not cfg.encoder_only, None)[0],
                params["layers"], x, training)
            new_cache = None
        else:
            cache_len = cache["len"]

            def cbody(xx, pl, cl):
                xx, new_kv = body(cfg, xx, pl, positions,
                                  not cfg.encoder_only,
                                  (cl[0], cl[1], cache_len))
                return xx, new_kv

            x, (k_new, v_new) = _scan_with_cache(
                cbody, params["layers"], x, (cache["k"], cache["v"]))
            new_cache = dict(k=k_new, v=v_new,
                             len=cache_len + x.shape[1])
    elif fam == "ssm":
        if cache is None:
            b = x.shape[0]
            zero = jax.tree_util.tree_map(
                lambda l: l, _rwkv_zero_cache(cfg, b, x.dtype))
            fn = _maybe_remat(
                cfg, lambda xx, inp: _rwkv_body(cfg, xx, inp[0], inp[1]),
                training)

            def step(xx, pl):
                xx, _ = fn(xx, (pl, zero))
                return xx, None

            x, _ = jax.lax.scan(step, x, params["layers"])
            new_cache = None
        else:
            x, new_c = _scan_with_cache(
                lambda xx, pl, cl: _rwkv_body(cfg, xx, pl, cl),
                params["layers"], x,
                (cache["tm_shift"], cache["wkv"], cache["cm_shift"]))
            new_cache = dict(tm_shift=new_c[0], wkv=new_c[1],
                             cm_shift=new_c[2], len=cache["len"] + x.shape[1])
    elif fam == "hybrid":
        x, new_cache = _hybrid_stack(cfg, params, x, positions, cache,
                                     training)
    else:
        raise ValueError(fam)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache


def _rwkv_zero_cache(cfg, batch, dtype):
    return rwkv.init_rwkv_cache(cfg, batch, dtype)


def _hybrid_stack(cfg: ModelConfig, params, x, positions, cache, training):
    """Zamba2-style: groups of Mamba layers + one shared attention block.

    Outer scan over groups (the shared block's weights are closed over, so
    every group applies the *same* attention parameters); inner scan over
    the group's Mamba layers.
    """
    groups = cfg.n_layers // cfg.attn_every
    per = cfg.attn_every
    # Reshape stacked (L, ...) params to (groups, per, ...).
    glayers = jax.tree_util.tree_map(
        lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"])
    shared = params["shared_attn"]

    def shared_block(xx, kv):
        h = layers.rms_norm(xx, shared["ln1"], cfg.norm_eps)
        a, new_kv = _attn_block(cfg, shared, h, positions, True, kv)
        xx = xx + a
        h = layers.rms_norm(xx, shared["ln2"], cfg.norm_eps)
        return xx + layers.mlp_apply(cfg, shared["mlp"], h), new_kv

    if cache is None:
        mamba_fn = _maybe_remat(
            cfg, lambda xx, pl: _mamba_body(cfg, xx, pl, None)[0], training)
        shared_fn = _maybe_remat(
            cfg, lambda xx: shared_block(xx, None)[0], training)

        def group_step(xx, gp):
            def inner(x2, pl):
                x2 = context.constrain(x2, ("batch", "seq", "embed"))
                return mamba_fn(x2, pl), None
            xx, _ = jax.lax.scan(inner, xx, gp)
            return shared_fn(xx), None

        x, _ = jax.lax.scan(group_step, x, glayers)
        return x, None

    cache_len = cache["len"]
    regroup = lambda a: a.reshape((groups, per) + a.shape[1:])

    def group_step(xx, inp):
        gp, (sst, cst, kc, vc) = inp

        def inner(x2, pinner):
            pl, st, cv = pinner
            x2, (nst, ncv) = _mamba_body(cfg, x2, pl, (st, cv))
            return x2, (nst, ncv)

        xx, (nst, ncv) = jax.lax.scan(inner, xx, (gp, sst, cst))
        xx, new_kv = shared_block(xx, (kc, vc, cache_len))
        return xx, (nst, ncv, new_kv[0], new_kv[1])

    x, (nst, ncv, nk, nv) = jax.lax.scan(
        group_step, x,
        (glayers, (regroup(cache["ssm_state"]), regroup(cache["conv"]),
                   cache["k"], cache["v"])))
    new_cache = dict(
        ssm_state=nst.reshape((-1,) + nst.shape[2:]),
        conv=ncv.reshape((-1,) + ncv.shape[2:]),
        k=nk, v=nv, len=cache_len + x.shape[1])
    return x, new_cache


# ---------------------------------------------------------------------------
# Cache construction.
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Zeroed decode cache sized for ``max_len`` tokens of context."""
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "vlm", "moe"):
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
        return dict(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                    len=jnp.zeros((), jnp.int32))
    if cfg.family == "ssm":
        st = rwkv.init_rwkv_cache(cfg, batch, dtype)
        stack = lambda a: jnp.broadcast_to(
            a[None], (cfg.n_layers,) + a.shape).copy()
        return dict(tm_shift=stack(st[0]), wkv=stack(st[1]),
                    cm_shift=stack(st[2]), len=jnp.zeros((), jnp.int32))
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        state, conv = ssm.init_ssm_cache(cfg, batch, dtype)
        kv_shape = (groups, batch, max_len, cfg.n_kv_heads, hd)
        return dict(
            ssm_state=jnp.zeros((cfg.n_layers,) + state.shape,
                                jnp.float32),
            conv=jnp.zeros((cfg.n_layers,) + conv.shape, dtype),
            k=jnp.zeros(kv_shape, dtype), v=jnp.zeros(kv_shape, dtype),
            len=jnp.zeros((), jnp.int32))
    raise ValueError(f"{cfg.family} has no decode cache")
