"""RWKV6 ("Finch") blocks: data-dependent-decay linear attention.

Time-mix: token-shift interpolation with data-dependent mixing (low-rank
ddlerp), per-channel data-dependent decay w_t = exp(-exp(...)), and the WKV
matrix-state recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

carried as an (H, hd, hd) fp32 state per head -- O(1) in context length,
which is why rwkv6 is assigned the 500k decode shape.  Training runs the
same recurrence as a jax.lax.scan over time (the Pallas kernel in
kernels/rwkv_wkv.py is the chunked TPU-optimized path; this module is the
semantic definition).

Channel-mix: token-shift + squared-ReLU MLP with a sigmoid receptance gate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Spec


def rwkv_specs(cfg: ModelConfig, layered: bool = True) -> dict:
    d, f, r = cfg.d_model, cfg.d_ff, cfg.rwkv_lora_rank
    h = cfg.rwkv_heads
    ls, la = ((cfg.n_layers,), ("layers",)) if layered else ((), ())
    return {
        # time-mix
        "mix_base": Spec(ls + (5, d), la + ("mix", "embed"), init="zeros"),
        "mix_w1": Spec(ls + (d, 5 * r), la + ("embed", "rank")),
        "mix_w2": Spec(ls + (5, r, d), la + ("mix", "rank", "embed")),
        "wr": Spec(ls + (d, d), la + ("embed", "heads")),
        "wk": Spec(ls + (d, d), la + ("embed", "heads")),
        "wv": Spec(ls + (d, d), la + ("embed", "heads")),
        "wg": Spec(ls + (d, d), la + ("embed", "heads")),
        "decay_base": Spec(ls + (d,), la + ("embed",), init="zeros"),
        "decay_w1": Spec(ls + (d, r), la + ("embed", "rank")),
        "decay_w2": Spec(ls + (r, d), la + ("rank", "embed")),
        "bonus_u": Spec(ls + (d,), la + ("embed",), init="zeros"),
        "ln_x": Spec(ls + (d,), la + ("embed",), init="zeros"),
        "wo": Spec(ls + (d, d), la + ("heads", "embed")),
        # channel-mix
        "cm_mix": Spec(ls + (2, d), la + ("mix", "embed"), init="zeros"),
        "cm_wk": Spec(ls + (d, f), la + ("embed", "mlp")),
        "cm_wr": Spec(ls + (d, d), la + ("embed", "heads")),
        "cm_wv": Spec(ls + (f, d), la + ("mlp", "embed")),
    }


def _token_shift(x, prev):
    """Shift right by one: position t sees x_{t-1}; ``prev`` seeds t=0."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_scan(r, k, v, w, u, state):
    """WKV recurrence over time.

    r/k/v: (B, S, H, hd); w: (B, S, H, hd) decays in (0,1);
    u: (H, hd) bonus; state: (B, H, hd, hd) fp32 (key x value layout).
    Returns y (B, S, H, hd), new_state.
    """
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp                     # (B,H,hd) each
        a_t = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * a_t)
        s = s * wt[..., None] + a_t
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state       # (B,S,H,hd)


def time_mix(cfg: ModelConfig, p: dict, x, shift_state, wkv_state):
    """x: (B, S, D) -> (y, (new_shift, new_wkv))."""
    from repro.distributed import context
    p = context.use_params(p, {"wr": (None, "model"), "wk": (None, "model"),
                               "wv": (None, "model"), "wg": (None, "model"),
                               "wo": ("model", None)})
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    xx = _token_shift(x, shift_state)
    delta = xx - x

    # Data-dependent lerp (ddlerp): one shared low-rank tower -> 5 mixes.
    lora = jnp.tanh(x @ p["mix_w1"]).reshape(b, s, 5, -1)
    mixes = p["mix_base"][None, None] + jnp.einsum(
        "bsmr,mrd->bsmd", lora, p["mix_w2"])    # (B,S,5,D)
    xr, xk, xv, xw, xg = (x + delta * jax.nn.sigmoid(mixes[:, :, i])
                          for i in range(5))

    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])

    # Data-dependent per-channel decay in (0, 1).
    dd = p["decay_base"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32) - 3.0))     # near 1.0 init
    w = w.reshape(b, s, h, hd)
    u = p["bonus_u"].reshape(h, hd).astype(jnp.float32)

    y, new_state = _wkv_scan(r, k, v, w, u, wkv_state)
    y = y.reshape(b, s, d).astype(x.dtype)
    # Group norm over heads (ln_x) then output gate + projection.
    yh = y.reshape(b, s, h, hd).astype(jnp.float32)
    var = jnp.mean(jnp.square(yh), axis=-1, keepdims=True)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var - jnp.square(mu) + cfg.norm_eps)
    y = (yh.reshape(b, s, d) *
         (1.0 + p["ln_x"].astype(jnp.float32))).astype(x.dtype)
    out = (y * g) @ p["wo"]
    return out, (x[:, -1, :], new_state)


def channel_mix(cfg: ModelConfig, p: dict, x, shift_state):
    from repro.distributed import context
    p = context.use_params(p, {"cm_wk": (None, "model"),
                               "cm_wr": (None, "model"),
                               "cm_wv": ("model", None)})
    xx = _token_shift(x, shift_state)
    delta = xx - x
    xk = x + delta * jax.nn.sigmoid(p["cm_mix"][0])[None, None]
    xr = x + delta * jax.nn.sigmoid(p["cm_mix"][1])[None, None]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    rr = jax.nn.sigmoid(xr @ p["cm_wr"])
    return rr * (kk @ p["cm_wv"]), x[:, -1, :]


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """(tm_shift, wkv_state, cm_shift) zeros for decode/stream."""
    d, h, hd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    return (jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, h, hd, hd), jnp.float32),
            jnp.zeros((batch, d), dtype))
