"""Int8-on-the-wire gradient all-reduce (shard_map-explicit).

§Perf H4 showed that quantize->dequantize *inside* a pjit leaves GSPMD
reducing f32 — the compression was numerically active but moved no fewer
bytes.  This module is H4': the reduction itself runs on int8 payloads,
expressed with shard_map so the collectives are explicit:

    1. quantize the local gradient (per-tensor scale, int8);
    2. all_to_all the int8 chunks (each member receives its 1/N slice from
       every peer) -- int8 wire bytes;
    3. dequantize with the gathered peer scales, sum in f32 (no overflow);
    4. requantize the reduced slice and all_gather int8 -- int8 wire bytes.

Wire traffic: ~2x int8 tensor size, vs ~2x f32 for a ring all-reduce -- a
4x reduction, proven at the HLO level by ``repro.launch.dryrun
--collective-proof`` (results/dryrun/int8_proof.json), which parses the
compiled collective bytes of both versions on the production mesh.

This is the CXL-asym idea executed on the training write path: gradients
are the "writes" of a data-parallel step, and the scarce cross-pod links
are provisioned to what the traffic actually needs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _quantize(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_all_reduce(x, axis_name: str):
    """All-reduce-mean of f32 ``x`` with int8 wire payloads.

    Call inside shard_map with ``x`` replicated over ``axis_name``.
    The leading-dim size must divide the axis size after padding.
    """
    n = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    q, scale = _quantize(flat)
    chunks = q.reshape(n, -1)                       # (N, size/N) int8
    # Each member ships chunk i to member i: int8 on the wire.
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, axis_name)   # (N,) f32 (tiny)
    partial = jnp.sum(recv.astype(jnp.float32) *
                      scales[:, None], axis=0) / n  # my 1/N slice, reduced
    q2, s2 = _quantize(partial)
    gathered = jax.lax.all_gather(q2, axis_name)    # (N, size/N) int8
    s2_all = jax.lax.all_gather(s2, axis_name)
    out = (gathered.astype(jnp.float32) *
           s2_all[:, None]).reshape(-1)
    out = out[:x.size] if pad else out
    return out.reshape(x.shape)


def f32_all_reduce(x, axis_name: str):
    """Reference: plain psum-mean (f32 on the wire)."""
    n = jax.lax.psum(1, axis_name)
    return jax.lax.psum(x, axis_name) / n


def make_reducer(mesh: Mesh, axis: str = "data", int8: bool = True):
    """A jit-able tree reducer over one mesh axis (grads replicated on the
    other axes)."""
    fn = int8_all_reduce if int8 else f32_all_reduce

    def reduce_tree(tree):
        def one(x):
            return fn(x, axis)

        inner = shard_map(
            lambda t: jax.tree_util.tree_map(one, t), mesh=mesh,
            in_specs=(P(),), out_specs=P(), check_rep=False)
        return inner(tree)

    return reduce_tree
