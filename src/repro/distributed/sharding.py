"""Logical-axis -> mesh sharding rules (MaxText-style), per arch x shape.

Parameters and activations carry *logical* axis names (see models/layers.py
Specs); this module maps them onto the physical mesh:

  * ``data`` mesh axis (plus ``pod`` when multi-pod): FSDP -- parameters are
    sharded along their ``embed`` dimension and all-gathered per layer;
    batch dims of activations are data-parallel over the same axis.
  * ``model`` mesh axis: tensor parallelism over heads / mlp / vocab /
    experts, and -- the COAXIAL move -- the *sequence* axis of decode KV
    caches (``kv_seq``), spreading KV-cache bytes over N chips' HBM
    (channelized sharding, DESIGN.md §3).

Rules drop a mesh axis per-tensor whenever the dimension does not divide by
the axis size (e.g. hubert's vocab of 504 on a 16-way model axis) -- GSPMD
could pad, but undivisible shards are never what we want at scale.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.model import Model


def fsdp_axes(mesh: Mesh):
    """The mesh axes used for data/FSDP sharding ('pod' folds into it)."""
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


#: logical name -> mesh axes, training rules.  None = replicated.
def train_rules(mesh: Mesh, cfg: ModelConfig) -> dict:
    fsdp = fsdp_axes(mesh)
    rules = {
        "embed": fsdp,             # FSDP: shard params along d_model
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),     # EP
        "layers": None,
        "experts_router": None,
        "ssm_inner": None,
        "conv": None,
        "rank": None,
        "mix": None,
        "frontend": None,
    }
    if cfg.family == "moe":
        # EP owns the model axis; per-expert mats replicated across it.
        rules["mlp"] = None
    return rules


def decode_rules(mesh: Mesh, cfg: ModelConfig) -> dict:
    """Serving rules: weights TP-sharded; FSDP gathering at every decode
    step would be latency-poison, so ``embed`` stays replicated and the
    batch axis carries data parallelism."""
    rules = train_rules(mesh, cfg)
    rules = dict(rules, embed=None)
    return rules


def spec_for(shape, axes, rules, mesh) -> P:
    parts = []
    for dim, name in zip(shape, axes):
        mesh_axes = rules.get(name)
        if mesh_axes is None:
            parts.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        if dim % axis_size(mesh, mesh_axes) != 0:
            parts.append(None)          # undivisible -> replicate
        else:
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*parts)


def param_shardings(model: Model, mesh: Mesh, rules: dict):
    """NamedSharding pytree matching the model's parameter tree."""
    from repro.models import layers as L
    specs = model.specs()

    def one(spec):
        pspec = spec_for(spec.shape, spec.axes, rules, mesh)
        return NamedSharding(mesh, pspec)

    return jax.tree_util.tree_map(one, specs, is_leaf=L.is_spec)


def batch_shardings(mesh: Mesh, batch_tree) -> dict:
    """Batch dims shard over (pod+)data; everything else replicated."""
    fsdp = fsdp_axes(mesh)
    fa = fsdp if len(fsdp) > 1 else fsdp[0]

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        if leaf.shape[0] % axis_size(mesh, fsdp) != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*((fa,) + (None,) * (nd - 1))))

    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree,
                    kv_channels: bool = True) -> dict:
    """Decode-cache shardings.

    KV tensors (layers/groups, B, S, Hk, hd): batch over (pod+)data and --
    when ``kv_channels`` -- sequence over ``model``: the channelized layout
    where each chip owns 1/N of the context and streams only local HBM.
    SSM states (small, per-sequence) shard over batch only.
    """
    fsdp = fsdp_axes(mesh)
    fa = fsdp if len(fsdp) > 1 else fsdp[0]
    data_n = axis_size(mesh, fsdp)
    model_n = mesh.shape.get("model", 1)

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if nd <= 1:
            return NamedSharding(mesh, P())
        if name in ("k", "v"):
            seq = leaf.shape[2]
            batch_ok = leaf.shape[1] % data_n == 0
            seq_ok = kv_channels and seq % model_n == 0
            return NamedSharding(mesh, P(
                None, fa if batch_ok else None,
                "model" if seq_ok else None, None, None))
        # ssm_state / conv / shift states: (L, B, ...)
        batch_ok = leaf.shape[1] % data_n == 0
        return NamedSharding(
            mesh, P(*((None, fa if batch_ok else None) +
                      (None,) * (nd - 2))))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
