"""Fault tolerance and straggler mitigation for the training loop.

Three mechanisms, composable around any step function:

  * :class:`ResilientRunner` -- retries a failing step (transient XLA /
    host errors), and after ``max_retries`` escalates to a checkpoint
    restore ("restart from last good state"), exactly the
    checkpoint/restart discipline a 1000-node job needs.  Failure
    injection hooks make this testable without real hardware faults.

  * :class:`StragglerMonitor` -- tracks per-step wall times; a step slower
    than ``threshold`` x the rolling median is flagged.  On a real
    multi-pod deployment the flag triggers the documented mitigations
    (re-shard away from the slow host / skip its optimizer gather once);
    here it records and reports, and the train loop uses it to decide to
    rebuild its data prefetcher (the single-process analogue).

  * :class:`Heartbeat` -- a liveness file other processes (or a cluster
    agent) can watch; missed beats -> the agent restarts the job, which
    then resumes from the latest checkpoint (elastic re-shard supported by
    checkpoint/ckpt.restore).
"""

from __future__ import annotations

import collections
import json
import os
import statistics
import time


class StepFailure(RuntimeError):
    pass


class ResilientRunner:
    def __init__(self, step_fn, *, max_retries: int = 2,
                 on_restore=None, failure_injector=None):
        self.step_fn = step_fn
        self.max_retries = max_retries
        self.on_restore = on_restore
        self.failure_injector = failure_injector
        self.retries_total = 0
        self.restores_total = 0

    def run_step(self, *args, **kwargs):
        attempt = 0
        while True:
            try:
                if self.failure_injector is not None:
                    self.failure_injector()
                return self.step_fn(*args, **kwargs)
            except StepFailure:
                attempt += 1
                self.retries_total += 1
                if attempt > self.max_retries:
                    if self.on_restore is None:
                        raise
                    args, kwargs = self.on_restore(*args, **kwargs)
                    self.restores_total += 1
                    attempt = 0


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.5):
        self.window = window
        self.threshold = threshold
        self.times = collections.deque(maxlen=window)
        self.straggler_steps: list[int] = []
        self._t0 = None
        self._step = 0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record one step; True if it was a straggler."""
        dt = time.monotonic() - self._t0
        is_straggler = False
        if len(self.times) >= max(self.window // 4, 4):
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                is_straggler = True
                self.straggler_steps.append(self._step)
        self.times.append(dt)
        self._step += 1
        return is_straggler

    @property
    def median_s(self):
        return statistics.median(self.times) if self.times else float("nan")


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int):
        now = time.time()
        if now - self._last < self.interval_s:
            return
        self._last = now
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def is_alive(path: str, stale_s: float = 60.0) -> bool:
        try:
            with open(path) as f:
                beat = json.load(f)
        except (OSError, ValueError):
            return False
        return (time.time() - beat["time"]) < stale_s
