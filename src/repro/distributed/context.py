"""Activation-sharding context: constraint injection without config plumbing.

Model code calls ``constrain(x, ("batch", "seq", "embed"))`` at layer
boundaries; by default it is a no-op.  The launcher (train.py / dryrun.py)
activates rules for the duration of tracing:

    with activation_rules(mesh, {"batch": ("pod", "data"), "seq": "model"}):
        lowered = jax.jit(step, ...).lower(...)

The headline use is Megatron-style sequence parallelism of the residual
stream: sharding the scan carry's sequence axis over ``model`` divides the
per-chip activation stash by the model-axis size -- the hillclimb move that
brings the big train cells under HBM (EXPERIMENTS.md §Perf) at the price of
attention-time gather collectives.  The same trade as the paper's: memory
capacity/bandwidth bought with interconnect latency.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


@contextlib.contextmanager
def activation_rules(mesh, rules: dict):
    """Enable logical->mesh activation constraints while tracing."""
    old = getattr(_STATE, "value", None)
    _STATE.value = (mesh, rules)
    try:
        yield
    finally:
        _STATE.value = old


def fsdp_gather_active() -> bool:
    state = getattr(_STATE, "value", None)
    return bool(state and state[1].get("fsdp_gather"))


def flag(name: str) -> bool:
    state = getattr(_STATE, "value", None)
    return bool(state and state[1].get(name))


def use_params(tree: dict, spec_map: dict):
    """Constrain parameter *use* sites to their gathered (FSDP-unsharded)
    layout: embed-dim replicated, TP dims kept on ``model``.

    This pins GSPMD to the canonical FSDP lowering -- all-gather the layer's
    WEIGHTS (megabytes) instead of resharding ACTIVATIONS (gigabytes); see
    EXPERIMENTS.md §Perf, hypothesis H1.  No-op unless the active rules set
    ``fsdp_gather``.
    """
    state = getattr(_STATE, "value", None)
    if not state or not state[1].get("fsdp_gather"):
        return tree
    mesh, _ = state
    out = dict(tree)
    for name, parts in spec_map.items():
        if name not in out:
            continue
        x = out[name]
        fixed = []
        for dim, part in zip(x.shape, parts):
            if part is None:
                fixed.append(None)
                continue
            size = mesh.shape[part] if isinstance(part, str) else 1
            fixed.append(part if dim % size == 0 else None)
        out[name] = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))
    return out


def constrain(x, logical_axes: tuple):
    """Apply the active sharding constraint to ``x`` (no-op by default)."""
    state = getattr(_STATE, "value", None)
    if state is None:
        return x
    mesh, rules = state
    parts = []
    for dim, name in zip(x.shape, logical_axes):
        axes = rules.get(name)
        if axes is None:
            parts.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size != 0:
            parts.append(None)
        else:
            parts.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
