"""Jitted, sharded train/serve step factories.

``make_train_step`` wires value_and_grad -> (optional int8 error-feedback
gradient compression) -> AdamW, as one pjit-compiled function whose in/out
shardings come from the logical-axis rules.  ``make_serve_step`` is the
one-token decode step the ``decode_*`` / ``long_*`` dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw, compression
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    opt: AdamWConfig = AdamWConfig()
    compress_grads: bool = False
    param_dtype: str = "bfloat16"
    #: gradient-accumulation microbatches per step (1 = off).  Divides the
    #: per-chip activation working set by the same factor -- the memory-
    #: capacity lever for the biggest train cells (EXPERIMENTS.md §Perf H3).
    microbatch: int = 1


def init_train_state(model: Model, key, step_cfg: TrainStepConfig):
    params = model.init(key)
    state = dict(params=params, opt=adamw.init(params),
                 step=jnp.zeros((), jnp.int32))
    if step_cfg.compress_grads:
        state["ef"] = compression.init_error_feedback(params)
    return state


def train_state_specs(model: Model, step_cfg: TrainStepConfig):
    """ShapeDtypeStructs of the train state (no allocation; dry-run path)."""
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0), step_cfg))


def make_train_step(model: Model, step_cfg: TrainStepConfig):
    param_dtype = {"bfloat16": jnp.bfloat16,
                   "float32": jnp.float32}[step_cfg.param_dtype]

    def train_step(state, batch):
        if step_cfg.microbatch > 1:
            m = step_cfg.microbatch

            def split(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mbatches = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def micro(acc, mb):
                (l, _), g = jax.value_and_grad(
                    model.loss, has_aux=True)(state["params"], mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32) / m, acc, g)
                return acc, l

            grads, losses = jax.lax.scan(micro, zero, mbatches)
            metrics = {"loss": losses.mean()}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(state["params"], batch)
        if step_cfg.compress_grads:
            # Quantize (with error feedback) before the DP reduction: the
            # reduce-scatter moves int8 + scales instead of fp32.
            comp, new_ef = compression.compress(grads, state["ef"])
            grads = compression.decompress(comp)
        new_params, new_opt, opt_metrics = adamw.update(
            step_cfg.opt, grads, state["opt"], state["step"],
            param_dtype=param_dtype)
        new_state = dict(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if step_cfg.compress_grads:
            new_state["ef"] = new_ef
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return train_step


def make_serve_step(model: Model):
    def serve_step(params, step_batch, cache):
        return model.decode_step(params, step_batch, cache)

    return serve_step


def make_prefill(model: Model):
    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill
