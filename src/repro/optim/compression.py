"""Int8 error-feedback gradient compression for DP all-reduce.

The COAXIAL-asym idea (§4.3: provision scarce interconnect bandwidth
according to traffic demand) applied to the training write path: gradients
are the dominant "write" traffic of a data-parallel step.  Compressing them
to int8 with per-tensor scales cuts reduce-scatter bytes 4x (bf16->int8 is
2x, fp32->int8 is 4x); the quantization error is carried in an error-
feedback buffer and re-injected next step, which keeps SGD convergence
(Seide et al. / EF-SGD).

Usage: wrap the grads between value_and_grad and the optimizer:

    comp, ef = compress(grads, ef)        # quantize + error feedback
    grads = decompress(comp)              # after the (cheaper) all-reduce
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_one(g, ef):
    g = g.astype(jnp.float32) + ef
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    err = g - q.astype(jnp.float32) * scale
    return (q, scale), err


def compress(grads, error_feedback):
    """-> (compressed pytree of (int8, scale), new error feedback)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    qs, errs = zip(*[_quantize_one(g, e) for g, e in zip(flat_g, flat_e)])
    comp = jax.tree_util.tree_unflatten(treedef, list(qs))
    new_ef = jax.tree_util.tree_unflatten(treedef, list(errs))
    return comp, new_ef


def decompress(comp):
    def one(leaf):
        q, scale = leaf
        return q.astype(jnp.float32) * scale

    return jax.tree_util.tree_map(one, comp,
                                  is_leaf=lambda x: isinstance(x, tuple)
                                  and len(x) == 2
                                  and hasattr(x[0], "dtype"))


def compressed_bytes(comp) -> int:
    leaves = jax.tree_util.tree_leaves(comp)
    return sum(l.size for l in leaves if l.dtype == jnp.int8)
