"""AdamW with fp32 master weights, global-norm clipping and LR schedules.

ZeRO-1-style by construction: optimizer states mirror the parameter tree,
so under the FSDP sharding rules (params sharded along ``embed`` over the
data axis) every chip holds exactly its parameter shard's optimizer state.
No separate partitioning machinery is needed -- the sharding *is* the
parameter sharding.

Pure-functional: ``init`` and ``update`` are pytree->pytree, jit/pjit-safe.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum((step + 1.0) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params):
    """Optimizer state: fp32 master copy + first/second moments."""
    # copy=True: when params are already fp32, astype would alias the same
    # buffer and break donation (donate(a), donate(a)).
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return dict(
        master=jax.tree_util.tree_map(f32, params),
        mu=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params),
        nu=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params),
    )


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state, step, param_dtype=jnp.bfloat16):
    """One AdamW step -> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def one(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * m
        m = m - lr * upd
        return m, mu, nu

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["master"])
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [one(g, m, mu, nu) for g, m, mu, nu in
           zip(flat_g, flat_m, flat_mu, flat_nu)]
    new_master = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda m: m.astype(param_dtype), new_master)
    new_state = dict(master=new_master, mu=new_mu, nu=new_nu)
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_params, new_state, metrics
