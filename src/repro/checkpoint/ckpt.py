"""Checkpointing: async atomic save, elastic restore, retention.

Format: one ``.npy`` file per pytree leaf (named by its tree path) plus a
``meta.json`` with step, tree structure and shapes.  Writes go to a temp
directory that is atomically renamed -- a crash mid-save never corrupts the
latest checkpoint (the classic two-phase commit of checkpoint systems).

Elasticity: leaves are stored as *global* arrays, so a restore may target a
different mesh/sharding than the save used -- ``restore(..., shardings=)``
device_puts each leaf under the new sharding.  That is the re-shard path
used when a job restarts on a different slice size.

Async: ``AsyncCheckpointer.save`` snapshots device arrays to host, then
writes on a background thread so the train loop overlaps checkpoint I/O
with compute (the standard large-scale trick; on 1000+ nodes each process
writes only its addressable shards -- noted in DESIGN.md; this
implementation gathers, which is exact on a single process).
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        name = "__".join(
            p.key if hasattr(p, "key") else str(p.idx) for p in path)
        items.append((name, leaf))
    return items, treedef


def save(tree, directory: str, step: int):
    """Synchronous atomic checkpoint write."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp-step-{step}")
    final = os.path.join(directory, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    items, _ = _flatten(tree)
    meta = {"step": step, "leaves": []}
    for name, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        meta["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(directory)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore(tree_like, directory: str, step: int | None = None,
            shardings=None):
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings -- the elastic
    re-shard path (mesh shape at restore may differ from save).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step-{step:08d}")
    items, treedef = _flatten(tree_like)
    leaves = []
    for name, ref in items:
        arr = np.load(os.path.join(d, name + ".npy"))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step


def retain(directory: str, keep: int = 3):
    """Delete all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("-")[1]) for d in os.listdir(directory)
                   if d.startswith("step-"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step-{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Overlap checkpoint writes with training compute."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: concurrent.futures.Future | None = None

    def save(self, tree, step: int):
        # Snapshot to host synchronously (cheap vs. a train step), write
        # asynchronously.
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()

        def _write():
            path = save(host_tree, self.directory, step)
            retain(self.directory, self.keep)
            return path

        self._pending = self._pool.submit(_write)
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self):
        self.wait()
        self._pool.shutdown()
