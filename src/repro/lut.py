"""CLI: ``python -m repro.lut`` -- manage the persistent QueueLUT store.

Subcommands::

    python -m repro.lut prebuild [--harvest] [--engine event] [--refine]
    python -m repro.lut inspect
    python -m repro.lut gc [--older-than-days N | --all]

``prebuild`` resolves the default-grid surface(s) through the store
(``$REPRO_LUT_CACHE``; see :mod:`repro.core.lutstore`) and prints, per
surface, the resolution wall-clock and how many DES traces it cost -- a
warm read prints ``traces=0``.  Run it once in an image build or a CI
cache-seeding step and every later ``repro.designer`` /
``repro.serving.plan`` / test session starts warm.  ``--refine`` runs
:func:`repro.core.queuelut.refine_queue_lut` instead, printing the
round-by-round convergence trajectory (each round's grown grid is itself
stored, so refinement also seeds the store).

``inspect`` lists every stored surface with its build meta; ``gc`` drops
quarantined artifacts plus entries that are stale (fingerprint mismatch)
or older than ``--older-than-days`` (``--all`` empties the store).
"""

from __future__ import annotations

import argparse
import time

from repro.core import lutstore, memsim, queuelut


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.lut",
        description="prebuild / inspect / gc the on-disk QueueLUT store")
    sub = p.add_subparsers(dest="cmd", required=True)

    pb = sub.add_parser("prebuild",
                        help="resolve default surfaces into the store")
    pb.add_argument("--engine", choices=memsim.ENGINES, action="append",
                    help="engine(s) to build for (default: event)")
    pb.add_argument("--steps", type=int, default=queuelut.DEFAULT_STEPS)
    pb.add_argument("--seed", type=int, default=0)
    pb.add_argument("--reps", type=int, default=queuelut.DEFAULT_REPS)
    pb.add_argument("--harvest", action="store_true",
                    help="also build the 5-axis harvesting surface")
    pb.add_argument("--refine", action="store_true",
                    help="run the adaptive refinement loop instead of "
                         "the fixed default grid")
    pb.add_argument("--tol", type=float, default=0.01,
                    help="refinement convergence tolerance (rel.)")

    sub.add_parser("inspect", help="list stored surfaces")

    g = sub.add_parser("gc", help="drop stale/quarantined entries")
    g.add_argument("--older-than-days", type=float, default=None)
    g.add_argument("--all", action="store_true",
                   help="empty the store entirely")
    return p


def _fmt_bytes(n: int) -> str:
    return f"{n / 1024:.0f} KiB" if n < 1 << 20 else f"{n / 1e6:.1f} MB"


def _prebuild(args) -> int:
    if lutstore.cache_dir() is None:
        print(f"WARNING: ${lutstore.ENV_VAR} is unset -- surfaces are "
              "built but not persisted")
    engines = tuple(dict.fromkeys(args.engine or ["event"]))
    harvests = (False, True) if args.harvest else (False,)
    if args.refine:
        for engine in engines:
            lut, hist = queuelut.refine_queue_lut(
                steps=args.steps, seed=args.seed, reps=args.reps,
                engine=engine, tol=args.tol)
            for r in hist:
                extra = ("" if "d_geomean" not in r else
                         f" d_gm={r['d_geomean']:.4f} "
                         f"d_p99={r['d_token_p99']:.4f}")
                print(f"refine[{engine}] round {r['round']}: "
                      f"shape={r['shape']} cells={r['cells']} "
                      f"gm={r['geomean_speedup']:.4f} "
                      f"tok99={r['token_p99_ms']:.1f}ms "
                      f"worst_err={r['worst_err']:.3f} "
                      f"{r['seconds']:.1f}s{extra}")
            print(f"refine[{engine}]: "
                  + ("converged" if hist[-1]["converged"]
                     else "round budget exhausted"))
        return 0
    for engine in engines:
        for harvest in harvests:
            t0, n0 = time.perf_counter(), memsim.sim_trace_count()
            lut = queuelut.default_queue_lut(
                steps=args.steps, seed=args.seed, reps=args.reps,
                engine=engine, harvest=harvest)
            dt = time.perf_counter() - t0
            traces = memsim.sim_trace_count() - n0
            import numpy as np
            shape = tuple(np.shape(np.asarray(lut.wait_ns)))
            print(f"prebuild engine={engine} harvest={harvest}: "
                  f"shape={shape} {dt:.2f}s traces={traces}"
                  + (" (warm)" if traces == 0 else ""))
    return 0


def _inspect() -> int:
    root = lutstore.cache_dir()
    if root is None:
        print(f"${lutstore.ENV_VAR} is unset -- no store")
        return 1
    rows = lutstore.entries()
    print(f"store {root}: {len(rows)} surface(s), fingerprint "
          f"{lutstore.mechanism_fingerprint()[:12]}")
    fp = lutstore.mechanism_fingerprint()
    for e in rows:
        stale = "" if e.get("fingerprint") == fp else "  [STALE]"
        print(f"  {e['path'].rsplit('/', 1)[-1]}  "
              f"{_fmt_bytes(e['bytes'])}  engine={e.get('engine', '?')} "
              f"steps={e.get('steps', '?')} shape={e.get('shape', '?')}"
              f"{stale}")
    return 0


def _gc(args) -> int:
    out = lutstore.gc(max_age_days=args.older_than_days,
                      everything=args.all)
    print(f"gc: removed {out['removed']} file(s), "
          f"freed {_fmt_bytes(out['bytes'])}")
    return 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.cmd == "prebuild":
        return _prebuild(args)
    if args.cmd == "inspect":
        return _inspect()
    return _gc(args)


if __name__ == "__main__":
    raise SystemExit(main())
