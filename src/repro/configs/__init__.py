"""Architecture registry: ``get_config(arch_id)`` + the assigned shape grid.

All ten assigned architectures are selectable by id (``--arch <id>``); each
is paired with the four assigned input shapes.  ``cells()`` enumerates the
(arch x shape) grid with per-cell applicability (encoder-only archs have no
decode step; 500k decode requires a sub-quadratic family), exactly as
DESIGN.md §5 documents.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, smoke_variant

_MODULES = {
    "stablelm-1.6b": "stablelm_1_6b",
    "starcoder2-3b": "starcoder2_3b",
    "mistral-large-123b": "mistral_large_123b",
    "stablelm-3b": "stablelm_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = (
    Shape("train_4k", 4096, 256, "train"),
    Shape("prefill_32k", 32768, 32, "prefill"),
    Shape("decode_32k", 32768, 128, "decode"),
    Shape("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> Shape:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_status(cfg: ModelConfig, shape: Shape) -> str:
    """'ok' or a skip reason for one (arch x shape) cell."""
    if shape.kind == "decode":
        if not cfg.has_decode:
            return "skip: encoder-only arch has no decode step"
        if shape.seq_len >= 500_000 and not cfg.sub_quadratic:
            return ("skip: 500k decode needs sub-quadratic attention "
                    "(full-attention arch, per assignment)")
    return "ok"


def cells():
    """Yield (arch_id, config, shape, status) for the full 40-cell grid."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            yield arch, cfg, shape, cell_status(cfg, shape)
