"""StableLM 3B: dense GQA decoder.

Assigned config: [hf:stabilityai/stablelm-2-1_6b family; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
name="stablelm-3b",
family="dense",
n_layers=32,
d_model=2560,
n_heads=32,
n_kv_heads=32,
d_ff=6912,
vocab=50304,
)
