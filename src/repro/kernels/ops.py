"""Jitted public wrappers for the Pallas kernels.

On a TPU runtime these dispatch the compiled kernels; everywhere else
(CPU CI, this container) they run interpret=True, which executes the same
kernel body in Python -- bit-for-bit the algorithm the TPU runs, minus the
hardware.  ``on_tpu()`` picks automatically.
"""

from __future__ import annotations

import jax

from repro.kernels import decode_attn as _da
from repro.kernels import rwkv_wkv as _wkv
from repro.kernels import stream as _stream


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def stream_copy(a):
    return _stream.stream_copy(a, interpret=_interp())


def stream_scale(a, alpha):
    return _stream.stream_scale(a, alpha, interpret=_interp())


def stream_add(a, b):
    return _stream.stream_add(a, b, interpret=_interp())


def stream_triad(a, b, alpha):
    return _stream.stream_triad(a, b, alpha, interpret=_interp())


def decode_attn(q, k, v, length, block_s: int = _da.BLOCK_S):
    return _da.decode_attn(q, k, v, length, block_s=block_s,
                           interpret=_interp())


def wkv(r, k, v, w, u, state, block_t: int = _wkv.BLOCK_T):
    return _wkv.wkv(r, k, v, w, u, state, block_t=block_t,
                    interpret=_interp())
