"""GQA flash-decode attention as a Pallas TPU kernel.

This is the per-chip hot loop of the channelized decode path (DESIGN.md §3):
one query token attends a long KV cache; the kernel streams the KV cache
from HBM in (BLOCK_S, D) tiles, maintaining online-softmax running
(max, denom, acc) in VMEM scratch.  Arithmetic intensity is ~2 flops/byte,
so this kernel IS the HBM bandwidth roofline of decode -- tiling exists to
keep the stream DMA-friendly, not to feed the MXU.

Layout: the grid is (batch, kv_head, seq_blocks); the sequence dimension is
innermost so TPU grid iteration carries scratch across KV tiles.  Each tile
serves all G = Hq/Hk query heads of its KV head at once (the GQA trick:
one KV byte feeds G queries, multiplying arithmetic intensity by G).

In the distributed layout, the cache's sequence axis is sharded over the
``model`` mesh axis; each chip runs this kernel on its local S/N slice and
the (m, l, acc) partials are merged across chips (flash-decode combine) --
COAXIAL's channels, with this kernel as the per-channel controller.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (BLOCK_S, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scale = q.shape[-1] ** -0.5
    logits = jnp.dot(q * scale, k.T,
                     preferred_element_type=jnp.float32)   # (G, BLOCK_S)
    positions = s_idx * BLOCK_S + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    logits = jnp.where(positions < len_ref[0], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attn(q, k, v, length, *, block_s: int = BLOCK_S,
                interpret: bool = False):
    """q: (B, Hq, D); k/v: (B, S, Hk, D); length: () int32 -> (B, Hq, D)."""
    b, hq, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = hq // hk
    block_s = min(block_s, s)
    grid = (b, hk, pl.cdiv(s, block_s))

    qg = q.reshape(b, hk, g, d)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    out = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (0,)),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d),
                         lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1, d),
                         lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(length, qg, k, v)
    return out.reshape(b, hq, d)
