"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` mirrors the semantics of its kernel exactly; kernel tests
sweep shapes/dtypes and assert_allclose against these (interpret=True on
CPU, compiled on real TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --- STREAM (paper §5 workloads: copy/scale/add/triad) ---------------------

def stream_copy_ref(a):
    return a + 0  # materialize a copy


def stream_scale_ref(a, alpha):
    return alpha * a


def stream_add_ref(a, b):
    return a + b


def stream_triad_ref(a, b, alpha):
    return a + alpha * b


# --- GQA flash-decode attention --------------------------------------------

def decode_attn_ref(q, k, v, length):
    """q: (B, Hq, D); k/v: (B, S, Hk, D); length: () valid prefix length.

    Returns (B, Hq, D): softmax(q k^T / sqrt(D)) v over the valid prefix,
    with GQA head grouping (Hq = G * Hk).
    """
    b, hq, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    mask = jnp.arange(s)[None, None, None, :] < length
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


# --- RWKV6 WKV recurrence ---------------------------------------------------

def wkv_ref(r, k, v, w, u, state):
    """r/k/v/w: (B, T, H, D); u: (H, D); state: (B, H, D, D) fp32.

    y_t = r_t . (S_{t-1} + u * k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns (y (B, T, H, D), final state).
    """
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp
        a = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * a)
        s = s * wt[..., None] + a
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(f32), xs)
    return ys.transpose(1, 0, 2, 3), state
