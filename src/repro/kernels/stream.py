"""STREAM kernels (copy / scale / add / triad) as Pallas TPU kernels.

The paper runs STREAM as its bandwidth-roofline probe (§5); these are the
TPU-native equivalents and double as the framework's HBM-bandwidth
microbenchmarks.  Each kernel is purely bandwidth-bound: the BlockSpec
tiling streams (BLOCK_M, LANES)-sized tiles HBM->VMEM->HBM with zero
arithmetic intensity beyond the axpy, so achieved bytes/s vs. 819 GB/s *is*
the memory roofline term.

Tiling: last dim is a multiple of 128 lanes; rows tile by BLOCK_M=512 so a
tile is 512x128x4B = 256 KiB -- three tiles (two in, one out) stay well
under the ~16 MiB/core VMEM budget while deep enough to hide DMA latency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 512
LANES = 128


def _grid_spec(shape, n_in):
    m, n = shape
    bm = min(BLOCK_M, m)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, LANES))
    spec = pl.BlockSpec((bm, LANES), lambda i, j: (i, j))
    return grid, [spec] * n_in, spec


def _copy_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...]


def _scale_kernel(alpha_ref, a_ref, o_ref):
    o_ref[...] = alpha_ref[0] * a_ref[...]


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(alpha_ref, a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + alpha_ref[0] * b_ref[...]


def _call(kernel, arrays, scalars=(), interpret=False):
    shape = arrays[0].shape
    grid, in_specs, out_spec = _grid_spec(shape, len(arrays))
    if scalars:
        # Scalars ride along as (1,)-shaped inputs broadcast to every tile.
        scalar_spec = pl.BlockSpec((1,), lambda i, j: (0,))
        in_specs = [scalar_spec] * len(scalars) + in_specs
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape, arrays[0].dtype),
        interpret=interpret,
    )(*scalars, *arrays)


def stream_copy(a, *, interpret=False):
    return _call(_copy_kernel, (a,), interpret=interpret)


def stream_scale(a, alpha, *, interpret=False):
    alpha = jnp.asarray([alpha], a.dtype)
    return _call(_scale_kernel, (a,), (alpha,), interpret=interpret)


def stream_add(a, b, *, interpret=False):
    return _call(_add_kernel, (a, b), interpret=interpret)


def stream_triad(a, b, alpha, *, interpret=False):
    alpha = jnp.asarray([alpha], a.dtype)
    return _call(_triad_kernel, (a, b), (alpha,), interpret=interpret)


def stream_bytes(name: str, shape, dtype=jnp.float32) -> int:
    """Bytes moved per invocation (for roofline accounting)."""
    n = 1
    for d in shape:
        n *= d
    per = jnp.dtype(dtype).itemsize
    return {"copy": 2, "scale": 2, "add": 3, "triad": 3}[name] * n * per
