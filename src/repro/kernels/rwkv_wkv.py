"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The WKV state is an (D, D) matrix per (batch, head); the recurrence

    y_t = r_t (S + u * k_t^T v_t);   S <- diag(w_t) S + k_t^T v_t

is sequential in t but embarrassingly parallel over (batch, head) -- which
is exactly the grid: each grid cell owns one head's state in VMEM scratch
and walks its time tile with a fori_loop.  The time axis is the innermost
grid dimension so the state persists across tiles (TPU grid order is
sequential), making the kernel O(1) in sequence length for VMEM: state
(D x D x 4B = 16 KiB at D=64) + one (BLOCK_T, D) tile per operand.

This is the exactness-first recurrence form; the chunked matmul
formulation (better MXU utilization for training) is the documented
next optimization -- semantics pinned by ref.wkv_ref either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_ref):
    t_idx = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                      # (D,)
    bt = r_ref.shape[1]

    def step(i, _):
        rt = r_ref[0, i, 0, :].astype(jnp.float32)        # (D,)
        kt = k_ref[0, i, 0, :].astype(jnp.float32)
        vt = v_ref[0, i, 0, :].astype(jnp.float32)
        wt = w_ref[0, i, 0, :].astype(jnp.float32)
        a = kt[:, None] * vt[None, :]                     # (D, D) outer
        s = state_ref[...]
        y = jnp.sum(rt[:, None] * (s + u[:, None] * a), axis=0)
        y_ref[0, i, 0, :] = y.astype(y_ref.dtype)
        state_ref[...] = s * wt[:, None] + a
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(t_idx == n_t - 1)
    def _finish():
        sout_ref[0, 0] = state_ref[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv(r, k, v, w, u, state, *, block_t: int = BLOCK_T,
        interpret: bool = False):
    """r/k/v/w: (B, T, H, D); u: (H, D); state: (B, H, D, D) fp32.

    Returns (y (B, T, H, D) fp32, final state (B, H, D, D) fp32).
    """
    b, t, h, d = r.shape
    block_t = min(block_t, t)
    grid = (b, h, pl.cdiv(t, block_t))

    seq_spec = pl.BlockSpec((1, block_t, 1, d),
                            lambda bi, hi, ti: (bi, ti, hi, 0))
    y, sout = pl.pallas_call(
        _wkv_kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, d), lambda bi, hi, ti: (hi, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sout
