"""Per-decode-step memory demand of a model config, as a ``Workload``.

LLM decode is the throughput-server workload of the paper's argument: a
batch of sequences each reads its whole KV cache (or recurrent state)
plus its share of the streamed weights for every generated token.  This
module turns a :class:`repro.models.ModelConfig` at a given (batch,
context) operating point into the same (ipc, mpki, wb, exec_frac, ws_mb)
vector Table 4 gives for the paper's 35 workloads, so every sweep axis,
figure, and drift row of the evaluator works on LLM workloads unchanged.

The derivation has two halves:

* **Bytes and flops per token** are exact arithmetic on the config:
  family-aware state reads (GQA KV for attention archs, SSD/RWKV state
  for recurrent ones, both for hybrids), weight streaming amortized over
  the batch, and the matching flop count.  This mirrors what
  ``kernels/decode_attn.py`` actually moves per step.

* **(ipc, exec_frac)** come from the planner's roofline math evaluated
  on the paper's *baseline* machine (12 cores @ 2 GHz, one DDR5-4800
  channel) -- Table 4's IPC column is defined on that machine, so the
  derived workloads must anchor the CPU model the same way.  Roofline
  terms: ``compute_s`` at the socket's SIMD peak, ``memory_s`` at the
  single channel's bandwidth, derated by :data:`MEM_QUEUE_DERATE` for
  queuing + latency above the pure-bandwidth floor.  The derate is fitted
  so the mapping reproduces the paper's own streaming rows when fed
  STREAM-like demand: stream-copy's (mpki 58, wb 0.4) maps to ipc 0.18
  vs Table 4's 0.17, lbm's (64, 0.5) to 0.15 vs 0.14.
"""

from __future__ import annotations

import dataclasses

from repro.core import hw
from repro.core.planner import roofline_terms
from repro.core.workloads import (Workload, by_name, register_workload,
                                  unregister_workload)
from repro.models.config import ModelConfig

#: Useful flops retired per instruction on the baseline cores (SIMD FMA
#: streams; the same granularity Table 4's MPKI denominators imply).
FLOPS_PER_INST = 8.0
#: Peak SIMD flops per core-cycle (2 FMA ports x 8 bf16 lanes x 2).
CORE_FLOPS_PER_CYCLE = 32.0
#: Queuing + exposed-latency derate of the single-channel baseline's
#: memory time over the pure-bandwidth roofline term (fit to Table 4's
#: STREAM/lbm rows, see module docstring).
MEM_QUEUE_DERATE = 0.6
#: Suite tag for derived LLM workloads.
LLM_SUITE = "llm"

#: Default operating point: the decode_32k serving shape.
DEFAULT_BATCH = 128
DEFAULT_CONTEXT = 32768

#: The paper's baseline machine, phrased as a roofline spec: socket SIMD
#: peak and ONE DDR5-4800 channel (Table 4's measurement machine).  The
#: collective term never fires (no inter-socket traffic in decode).
BASELINE_SPEC = hw.TpuSpec(
    peak_flops=hw.SIM_CORES * CORE_FLOPS_PER_CYCLE * hw.CORE_CLK_GHZ * 1e9,
    hbm_bw=hw.DDR5_CH_BW_GBPS * 1e9,
    ici_bw_per_link=1e30, ici_links=1, ici_hop_s=0.0,
    hbm_bytes=hw.TPU_HBM_BYTES)

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}


def _dtype_bytes(dtype: str) -> int:
    return _DTYPE_BYTES.get(dtype, 2)


@dataclasses.dataclass(frozen=True)
class DecodeDemand:
    """Memory behavior of one decode step at a fixed operating point.

    Per-token quantities are per generated token of ONE sequence; the
    batch enters only through weight amortization (weights are read once
    per step and shared by all ``batch`` tokens) and the working set.
    """

    arch: str
    family: str
    batch: int
    context: int
    state_read_bytes: float    # KV/recurrent state read per token
    state_write_bytes: float   # KV append / state rewrite per token
    weight_bytes: float        # amortized weight stream per token
    flops_per_token: float
    inst_per_token: float
    compute_s: float           # roofline terms for one whole step
    memory_s: float            # (batch tokens) on the DDR baseline
    mpki: float
    wb: float
    ipc: float
    exec_frac: float
    ws_mb: float

    @property
    def read_bytes(self) -> float:
        """Total bytes read per generated token."""
        return self.state_read_bytes + self.weight_bytes

    @property
    def dominant(self) -> str:
        return "compute" if self.compute_s > self.memory_s else "memory"


def _state_bytes(cfg: ModelConfig, context: int) -> tuple[float, float]:
    """(read, write) state bytes per generated token of one sequence."""
    b = _dtype_bytes(cfg.dtype)
    hd = cfg.resolved_head_dim
    ctx = min(context, cfg.sliding_window) if cfg.sliding_window else context
    read = write = 0.0
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        n_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = max(cfg.n_layers // max(cfg.attn_every, 1), 1)
    else:
        n_attn = 0
    if cfg.encoder_only:
        n_attn = 0          # no KV cache; every frame is recomputed
    if n_attn:
        # K and V for every cached position, every attention layer ...
        read += 2.0 * n_attn * cfg.n_kv_heads * hd * ctx * b
        # ... plus appending this token's slot.
        write += 2.0 * n_attn * cfg.n_kv_heads * hd * b
    if cfg.family == "hybrid":
        # SSD recurrence: the full (heads x P x N) state is read and
        # rewritten every token, in every mamba layer.
        ssd = cfg.n_layers * cfg.d_inner * cfg.ssm_state * b
        read += ssd
        write += ssd
    if cfg.family == "ssm":
        # RWKV6 time-mix state (heads x D x D) + channel-mix shift.
        st = cfg.n_layers * (cfg.d_model * cfg.rwkv_head_dim +
                             2 * cfg.d_model) * b
        read += st
        write += st
    return read, write


def _flops_per_token(cfg: ModelConfig, context: int) -> float:
    ctx = min(context, cfg.sliding_window) if cfg.sliding_window else context
    hd = cfg.resolved_head_dim
    flops = 2.0 * cfg.active_param_count()
    if cfg.family in ("dense", "vlm", "moe", "audio") and not cfg.encoder_only:
        flops += 4.0 * cfg.n_layers * cfg.n_heads * hd * ctx
    elif cfg.family == "hybrid":
        n_attn = max(cfg.n_layers // max(cfg.attn_every, 1), 1)
        flops += 4.0 * n_attn * cfg.n_heads * hd * ctx
        flops += 4.0 * cfg.n_layers * cfg.d_inner * cfg.ssm_state
    elif cfg.family == "ssm":
        flops += 4.0 * cfg.n_layers * cfg.d_model * cfg.rwkv_head_dim
    return flops


def decode_demand(cfg: ModelConfig | str, *, batch: int = DEFAULT_BATCH,
                  context: int = DEFAULT_CONTEXT) -> DecodeDemand:
    """Derive one decode step's memory behavior at (batch, context).

    Accepts a :class:`ModelConfig` or an arch id from ``repro.configs``.
    Encoder-only configs have no KV cache; their demand is the weight
    stream alone (still finite and positive).
    """
    if isinstance(cfg, str):
        from repro.configs import get_config
        cfg = get_config(cfg)
    if batch < 1 or context < 1:
        raise ValueError("batch and context must be >= 1")
    b = _dtype_bytes(cfg.dtype)
    state_rd, state_wr = _state_bytes(cfg, context)
    weight = cfg.active_param_count() * b / batch
    flops = _flops_per_token(cfg, context)
    inst = flops / FLOPS_PER_INST
    read = state_rd + weight
    mpki = (read / hw.CACHE_LINE_B) / inst * 1000.0
    wb = state_wr / read

    # Whole-step roofline on the Table-4 baseline machine.
    terms = roofline_terms(hlo_flops=batch * flops,
                           hlo_bytes=batch * (read + state_wr),
                           collective_bytes=0.0, chips=1, spec=BASELINE_SPEC)
    compute_s = terms["compute_s"]
    memory_s = terms["memory_s"] / MEM_QUEUE_DERATE
    exec_frac = min(max(compute_s / (compute_s + memory_s), 0.02), 0.95)
    cpi = ((compute_s + memory_s) * hw.CORE_CLK_GHZ * 1e9 * hw.SIM_CORES
           / (batch * inst))
    ipc = min(max(1.0 / cpi, 0.02), 2.0)

    ws_mb = min((batch * state_rd + cfg.active_param_count() * b) / 1e6,
                1e6)
    return DecodeDemand(
        arch=cfg.name, family=cfg.family, batch=batch, context=context,
        state_read_bytes=state_rd, state_write_bytes=state_wr,
        weight_bytes=weight, flops_per_token=flops, inst_per_token=inst,
        compute_s=compute_s, memory_s=memory_s, mpki=mpki, wb=wb, ipc=ipc,
        exec_frac=exec_frac, ws_mb=ws_mb)


def llm_workload(cfg: ModelConfig | str, *, batch: int = DEFAULT_BATCH,
                 context: int = DEFAULT_CONTEXT, name: str | None = None,
                 kappa: float = 1.6, eta: float = 1.0, gamma: float = 0.1,
                 pf_boost: float = 1.5) -> Workload:
    """A first-class ``Workload`` for a model config's decode demand.

    The demand vector (ipc, mpki, wb, exec_frac, ws_mb) comes from
    :func:`decode_demand`; the behavioral parameters default to the
    streaming profile (decode reads KV sequentially with MSHRs kept
    full: even banks, prefetch-friendly, few dependent chains) except
    ``kappa``, where serving arrivals are burstier than STREAM's loop.
    """
    d = decode_demand(cfg, batch=batch, context=context)
    if name is None:
        name = f"llm-{d.arch}"
    return Workload(name=name, suite=LLM_SUITE, ipc=d.ipc, mpki=d.mpki,
                    wb=d.wb, kappa=kappa, eta=eta, exec_frac=d.exec_frac,
                    gamma=gamma, pf_boost=pf_boost, ws_mb=d.ws_mb)


def register_llm_workloads(archs, *, batch: int = DEFAULT_BATCH,
                           context: int = DEFAULT_CONTEXT,
                           overwrite: bool = False, **kw) -> tuple:
    """Derive and register one workload per arch; returns them in order.

    Already-registered names are returned as-is unless ``overwrite``."""
    out = []
    for arch in archs:
        w = llm_workload(arch, batch=batch, context=context, **kw)
        try:
            out.append(register_workload(w, overwrite=overwrite))
        except ValueError:
            out.append(by_name(w.name))
    return tuple(out)


def unregister_llm_workloads(archs_or_workloads) -> None:
    """Remove previously registered LLM workloads (no-op for absent)."""
    for item in archs_or_workloads:
        name = getattr(item, "name", None)
        if name is None:
            name = item if str(item).startswith("llm-") else f"llm-{item}"
        try:
            unregister_workload(name)
        except KeyError:
            pass
