"""repro.serving -- LLM-inference capacity planning on the COAXIAL engine.

The paper's headline claim is about throughput-oriented servers; the
modern throughput-server workload is LLM decode serving.  This package
connects the repo's serving substrate (``repro.configs``' model configs,
``repro.core.planner``'s roofline math, the decode-attention kernel's
bytes-per-step arithmetic) to the CoaXiaL evaluator in three layers:

  :mod:`repro.serving.demand`    model config -> per-decode-step memory
                                 demand -> a first-class ``Workload``;
  :mod:`repro.serving.traffic`   request-rate traces -> per-epoch
                                 (rho, kappa) MMPP operating points;
  :mod:`repro.serving.capacity`  the planner: which (channels, LLC, CXL
                                 premium, tier split) meets a p99
                                 token-latency SLO at minimum area.

CLI: ``python -m repro.serving.plan --arch mistral-large-123b
--slo-p99-ms 60 --trace synthetic-diurnal``.
"""

from repro.serving.capacity import (CapacityPlan, DesignVerdict,
                                    candidate_designs, plan_capacity)
from repro.serving.demand import (DecodeDemand, decode_demand, llm_workload,
                                  register_llm_workloads,
                                  unregister_llm_workloads)
from repro.serving.traffic import (Epoch, Trace, get_trace, load_csv,
                                   poisson_burst, synthetic_diurnal)

__all__ = [
    "DecodeDemand", "decode_demand", "llm_workload",
    "register_llm_workloads", "unregister_llm_workloads",
    "Epoch", "Trace", "get_trace", "load_csv", "poisson_burst",
    "synthetic_diurnal",
    "CapacityPlan", "DesignVerdict", "candidate_designs", "plan_capacity",
]
