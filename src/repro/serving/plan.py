"""CLI: ``python -m repro.serving.plan`` -- pick a design for an SLO.

Examples::

    python -m repro.serving.plan --arch mistral-large-123b \
        --slo-p99-ms 400 --trace synthetic-diurnal
    python -m repro.serving.plan --arch stablelm-1.6b --arch rwkv6-1.6b \
        --slo-p99-ms 50 --trace poisson-burst --peak-rps 0.5

With ``--peak-rps`` the trace's absolute rates are replaced so its peak
hits that request rate; otherwise ``--peak-util`` (default 0.65) scales
the trace so peak offered bytes sit at that fraction of the largest
candidate's bandwidth -- the planner then answers "which design clears
the SLO at a load the biggest machine could carry at 65%".
"""

from __future__ import annotations

import argparse

from repro.core import hw
from repro.serving.capacity import plan_capacity
from repro.serving.traffic import TRACES, get_trace


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.serving.plan",
        description="LLM serving capacity planner on the COAXIAL engine")
    p.add_argument("--arch", action="append", required=True,
                   help="model arch id (repeat for a fleet)")
    p.add_argument("--slo-p99-ms", type=float, required=True,
                   help="p99 token-latency SLO, milliseconds")
    p.add_argument("--trace", default="synthetic-diurnal",
                   help=f"trace name {sorted(TRACES)} or a CSV path")
    p.add_argument("--batch", type=int, default=128)
    p.add_argument("--context", type=int, default=4096)
    p.add_argument("--tokens-per-req", type=float, default=128.0)
    p.add_argument("--peak-rps", type=float, default=None,
                   help="pin the trace's peak request rate (abs. load)")
    p.add_argument("--peak-util", type=float, default=0.65,
                   help="scale trace to this peak utilization of the "
                        "largest candidate (ignored with --peak-rps)")
    p.add_argument("--channels", type=int, nargs="+", default=[2, 4, 8])
    p.add_argument("--llc-mb", type=float, nargs="+", default=[1.0])
    p.add_argument("--premium-ns", type=float, nargs="+",
                   default=[hw.CXL_LAT_NS, hw.CXL_LAT_PESSIMISTIC_NS])
    p.add_argument("--tier-splits", type=float, nargs="+",
                   default=[0.0, 0.5])
    p.add_argument("--no-measured", action="store_true",
                   help="exclude the measured 2303.15375 device points")
    p.add_argument("--steps", type=int, default=None,
                   help="DES simulated-time budget per cell, ns")
    p.add_argument("--engine", choices=("event", "timestep"),
                   default="event")
    p.add_argument("--seed", type=int, default=0)
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    trace = get_trace(args.trace)
    peak_util = None if args.peak_rps is not None else args.peak_util
    if args.peak_rps is not None:
        trace = trace.scaled(args.peak_rps / trace.peak_rps)
    plan = plan_capacity(
        tuple(args.arch), trace, slo_p99_ms=args.slo_p99_ms,
        batch=args.batch, context=args.context,
        tokens_per_req=args.tokens_per_req,
        channels=tuple(args.channels), llc_mb=tuple(args.llc_mb),
        premium_ns=tuple(args.premium_ns),
        tier_splits=tuple(args.tier_splits),
        include_measured=not args.no_measured, peak_util=peak_util,
        steps=args.steps, seed=args.seed, engine=args.engine)
    for d in plan.demands:
        print(f"demand {d.arch}: {d.read_bytes / 1e6:.1f} MB/token "
              f"(mpki {d.mpki:.2f}, wb {d.wb:.3f}, ipc {d.ipc:.2f}, "
              f"exec_frac {d.exec_frac:.2f})")
    print(f"trace {plan.trace}: peak {plan.peak_rps:.3g} req/s, "
          f"{len(trace.epochs)} epochs; engine={plan.engine} "
          f"steps={plan.steps}")
    print(plan.table())
    best = plan.best
    if best is None:
        c = plan.closest
        print(f"\nNO design meets p99 <= {plan.slo_p99_ms:g} ms; closest: "
              f"{c.name} at {c.token_p99_ms:.1f} ms "
              f"(channels={c.channels}, llc={c.llc_mb_per_core:g} MB/core, "
              f"premium={c.premium_ns:g} ns, split={c.tier_split:g})")
        return 1
    print(f"\nPICK {best.name}: channels={best.channels}, "
          f"llc={best.llc_mb_per_core:g} MB/core, "
          f"premium={best.premium_ns:g} ns, tier_split={best.tier_split:g} "
          f"-- rel_area {best.rel_area:.3f}, p99 {best.token_p99_ms:.1f} ms "
          f"<= SLO {plan.slo_p99_ms:g} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
