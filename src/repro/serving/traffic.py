"""Request-rate traces -> per-epoch (rho, kappa) MMPP operating points.

Serving load is doubly stochastic: a slow daily (or incident-driven)
envelope modulates the request rate, and within any epoch the arrivals
are bursty.  The DES already models the fast time scale exactly -- its
MMPP arrival process (``kappa``, ``burst_duty``, ``burst_sojourn_ns``)
is the within-epoch burstiness -- so a trace only has to supply the slow
envelope: a piecewise-constant sequence of :class:`Epoch` s, each with a
mean request rate and a peak-to-mean ``kappa`` for the DES to apply
inside the epoch.  The capacity planner turns each epoch into one DES
cell per memory tier (rho from offered bytes vs design bandwidth, kappa
verbatim), so p99 access latency per epoch comes from the event engine's
per-request records, not from a formula.

Three sources of traces:

* :func:`synthetic_diurnal` -- sinusoidal day: rate swings between a
  trough and a peak, burstiness rises with load (busy hours are also the
  bursty hours).
* :func:`poisson_burst`    -- flash-crowd pattern: a base rate with
  seeded random burst epochs at a multiple of it.
* :func:`load_csv`         -- measured traces, rows of ``t_s,rps[,kappa]``.

``get_trace`` resolves a CLI name or CSV path to a :class:`Trace`.
"""

from __future__ import annotations

import dataclasses
import math
import os

import numpy as np

#: kappa floor: even "calm" serving traffic is burstier than Poisson.
KAPPA_MIN = 1.0


@dataclasses.dataclass(frozen=True)
class Epoch:
    """One piecewise-constant segment of the request-rate envelope."""

    t_s: float       # epoch start, seconds since trace start
    dur_s: float     # epoch length, seconds
    rps: float       # mean offered request rate in the epoch
    kappa: float     # within-epoch burst peak-to-mean ratio (>= 1)
    #: Idle-I/O harvest lent-time fraction inside this epoch (arXiv
    #: 2511.12349): how much of the epoch the I/O links are idle enough
    #: to lend to the memory pool.  0 (the default) = no harvesting;
    #: :meth:`Trace.with_harvest` fills it anti-correlated with load.
    harvest_duty: float = 0.0

    def __post_init__(self):
        if self.dur_s <= 0 or self.rps < 0 or self.kappa < KAPPA_MIN:
            raise ValueError(f"bad epoch {self!r}")
        if not 0.0 <= self.harvest_duty < 1.0:
            raise ValueError(f"harvest_duty must be in [0, 1): {self!r}")


@dataclasses.dataclass(frozen=True)
class Trace:
    """A named request-rate trace (piecewise-constant envelope)."""

    name: str
    epochs: tuple[Epoch, ...]

    def __post_init__(self):
        if not self.epochs:
            raise ValueError("a trace needs at least one epoch")

    @property
    def peak_rps(self) -> float:
        return max(e.rps for e in self.epochs)

    @property
    def duration_s(self) -> float:
        return sum(e.dur_s for e in self.epochs)

    def scaled(self, factor: float) -> "Trace":
        """Same shape, every epoch's rate multiplied by ``factor``."""
        return Trace(self.name, tuple(
            dataclasses.replace(e, rps=e.rps * factor)
            for e in self.epochs))

    def with_harvest(self, duty_max: float) -> "Trace":
        """Fill per-epoch harvest duty ANTI-correlated with load.

        I/O links are idle when request load is low, so each epoch lends
        ``duty_max * (1 - rps / peak_rps)`` of its time: zero at the
        trace's peak epoch, approaching ``duty_max`` at a dead-idle one.
        ``duty_max=0`` clears harvesting (every epoch back to 0).
        """
        if not 0.0 <= duty_max < 1.0:
            raise ValueError(f"duty_max must be in [0, 1): {duty_max!r}")
        peak = self.peak_rps
        return Trace(self.name, tuple(
            dataclasses.replace(
                e, harvest_duty=duty_max * (1.0 - (e.rps / peak
                                                   if peak > 0 else 1.0)))
            for e in self.epochs))

    def to_csv(self, path: str) -> None:
        harvested = any(e.harvest_duty for e in self.epochs)
        with open(path, "w") as f:
            f.write("t_s,rps,kappa,harvest_duty\n" if harvested
                    else "t_s,rps,kappa\n")
            for e in self.epochs:
                row = f"{e.t_s:g},{e.rps:g},{e.kappa:g}"
                if harvested:
                    row += f",{e.harvest_duty:g}"
                f.write(row + "\n")


def synthetic_diurnal(n_epochs: int = 8, epoch_s: float = 3 * 3600.0,
                      peak_rps: float = 1.0, trough_frac: float = 0.25,
                      kappa_base: float = 1.3,
                      kappa_peak: float = 2.2) -> Trace:
    """A sinusoidal day sampled into ``n_epochs`` constant segments.

    Rate swings between ``trough_frac * peak_rps`` and ``peak_rps``;
    burstiness interpolates from ``kappa_base`` at the trough to
    ``kappa_peak`` at the peak (busy hours are bursty hours).
    """
    if not 0.0 < trough_frac <= 1.0:
        raise ValueError("trough_frac must be in (0, 1]")
    epochs = []
    for i in range(n_epochs):
        # Phase puts the peak mid-trace; s in [0, 1].
        s = 0.5 - 0.5 * math.cos(2.0 * math.pi * (i + 0.5) / n_epochs)
        rps = peak_rps * (trough_frac + (1.0 - trough_frac) * s)
        kappa = kappa_base + (kappa_peak - kappa_base) * s
        epochs.append(Epoch(i * epoch_s, epoch_s, rps, kappa))
    return Trace("synthetic-diurnal", tuple(epochs))


def poisson_burst(n_epochs: int = 12, epoch_s: float = 600.0,
                  base_rps: float = 0.4, burst_prob: float = 0.25,
                  burst_mult: float = 3.0, kappa_base: float = 1.4,
                  kappa_burst: float = 2.8, seed: int = 0) -> Trace:
    """Flash-crowd envelope: seeded random epochs at ``burst_mult``x."""
    rng = np.random.default_rng(seed)
    epochs = []
    for i in range(n_epochs):
        burst = bool(rng.random() < burst_prob)
        jitter = float(rng.uniform(0.85, 1.15))
        rps = base_rps * (burst_mult if burst else 1.0) * jitter
        kappa = kappa_burst if burst else kappa_base
        epochs.append(Epoch(i * epoch_s, epoch_s, rps, kappa))
    return Trace("poisson-burst", tuple(epochs))


def load_csv(path: str, name: str | None = None,
             default_kappa: float = 1.5) -> Trace:
    """Load ``t_s,rps[,kappa[,harvest_duty]]`` rows (header optional,
    ``#`` comments).

    Epoch durations come from consecutive start times; the last epoch
    reuses the previous duration (or 60 s for a one-row trace).

    The loader validates instead of guessing: ``t_s`` must be strictly
    increasing (a duplicate or out-of-order timestamp would silently
    become a zero- or negative-duration epoch), ``rps`` non-negative,
    ``kappa >= KAPPA_MIN``, ``harvest_duty`` in [0, 1), and every field
    float-parseable.  Violations raise ``ValueError`` naming the 1-based
    line number.  Only the FIRST non-comment line may be a non-numeric
    header.
    """
    rows = []
    seen_any = False
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = [p.strip() for p in line.split(",")]
            if len(parts) < 2:
                raise ValueError(
                    f"{path}:{lineno}: expected "
                    f"t_s,rps[,kappa[,harvest_duty]], got {line!r}")
            try:
                t = float(parts[0])
            except ValueError:
                if not seen_any:
                    seen_any = True
                    continue       # header row
                raise ValueError(
                    f"{path}:{lineno}: non-numeric t_s {parts[0]!r} "
                    f"(a header is only allowed as the first row)"
                ) from None
            seen_any = True
            try:
                rps = float(parts[1])
                kappa = (float(parts[2]) if len(parts) > 2
                         else default_kappa)
                duty = float(parts[3]) if len(parts) > 3 else 0.0
            except ValueError as e:
                raise ValueError(f"{path}:{lineno}: {e}") from None
            if rows and t <= rows[-1][1][0]:
                op = "duplicates" if t == rows[-1][1][0] else "precedes"
                raise ValueError(
                    f"{path}:{lineno}: t_s={t:g} {op} the previous "
                    f"row's t_s={rows[-1][1][0]:g}; timestamps must be "
                    f"strictly increasing")
            if rps < 0:
                raise ValueError(
                    f"{path}:{lineno}: negative rps {rps:g}")
            if kappa < KAPPA_MIN:
                raise ValueError(
                    f"{path}:{lineno}: kappa {kappa:g} below the "
                    f"{KAPPA_MIN:g} floor")
            if not 0.0 <= duty < 1.0:
                raise ValueError(
                    f"{path}:{lineno}: harvest_duty {duty:g} outside "
                    f"[0, 1)")
            rows.append((lineno, (t, rps, kappa, duty)))
    if not rows:
        raise ValueError(f"no data rows in trace CSV {path!r}")
    rows = [r for _, r in rows]
    epochs = []
    for i, (t, rps, kappa, duty) in enumerate(rows):
        if i + 1 < len(rows):
            dur = rows[i + 1][0] - t
        elif epochs:
            dur = epochs[-1].dur_s
        else:
            dur = 60.0
        epochs.append(Epoch(t, dur, rps, kappa, duty))
    if name is None:
        name = os.path.splitext(os.path.basename(path))[0]
    return Trace(name, tuple(epochs))


#: Named generators the CLI accepts directly.
TRACES = {
    "synthetic-diurnal": synthetic_diurnal,
    "poisson-burst": poisson_burst,
}


def get_trace(name_or_path: str) -> Trace:
    """Resolve a built-in trace name or a CSV path to a :class:`Trace`."""
    gen = TRACES.get(name_or_path)
    if gen is not None:
        return gen()
    if os.path.exists(name_or_path):
        return load_csv(name_or_path)
    raise KeyError(f"unknown trace {name_or_path!r}; named traces: "
                   f"{sorted(TRACES)} (or a CSV path)")
