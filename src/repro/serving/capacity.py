"""Capacity planner: min-area design meeting a p99 token-latency SLO.

The deployment question the paper's argument implies: given a model
fleet at a (batch, context) operating point and a request-rate trace,
which memory-system design -- (channels, LLC, CXL premium, tier split)
-- meets a p99 token-latency SLO at minimum silicon area?

The planner composes the repo's two existing truths instead of adding a
third model:

* **Model side** (``cpu_model`` via :func:`coaxial.solve_spec`): every
  candidate design is solved against the fleet's derived LLM workloads
  in one vmapped grid, giving per-design IPC -- the compute/bandwidth-
  coupled floor on decode-step time.

* **Mechanism side** (``memsim``, event engine): every (design, tier
  split, trace epoch) becomes one or two DES cells -- a direct-DDR lane
  and a CXL lane -- with ``rho`` from offered bytes vs lane bandwidth
  and ``kappa`` from the epoch.  All cells across all candidates run as
  ONE batched simulation, and p99 access latency is read from the event
  engine's exact per-request records (:class:`LatencyStats` histograms),
  not from a closed form.

Token latency composes the two: one decode step issues
``batch * read_bytes / 64`` line fetches with at most ``MAX_MLP x
cores`` in flight, i.e. ``waves = lines / in_flight`` dependent rounds;
each wave's completion is gated by its slowest straggler, which for
hundreds of in-flight accesses is the high-percentile access latency.
So ``token_p99 = waves * access_p99`` floored by the model-side step
time.  The 12-core simulated slice is scaled to the paper's 144-core
server (Table 2's own x12) for capacity and in-flight accounting.

Tier split ``s`` models a DDR+CXL tiered point (CXL-enabled Tiered
Memory, 2503.17864): ``round(s * channels)`` channels move to a
direct-attached DDR tier (no premium, full DDR pins paid), the rest
stay behind CXL; traffic stripes proportionally to channel count.
"""

from __future__ import annotations

import dataclasses
import math
import os

import numpy as np

from repro.core import coaxial, hw, memsim
from repro.core.cpu_model import DDR_BASELINE, MemSystem
from repro.core.devices import MEASURED_DEVICES
from repro.serving.demand import DecodeDemand, decode_demand, llm_workload
from repro.serving.traffic import Trace

#: Simulated 12-core slice -> full server (Table 2's scale factor).
SCALE = coaxial.FULL_CORES // hw.SIM_CORES
#: Default simulated-time budget per DES cell, ns (overridable via the
#: ``steps`` argument; benchmarks pass their ``des_budget``).
DEFAULT_STEPS = 60_000


def _per_channel_gbps(channels: int, links: int, link_rd_gbps: float) -> float:
    """Read bandwidth one channel can actually deliver, GB/s."""
    if links:
        return min(hw.DDR5_CH_BW_GBPS, links * link_rd_gbps / channels)
    return hw.DDR5_CH_BW_GBPS


def _design_per_ch(d: MemSystem) -> float:
    return _per_channel_gbps(d.dram_channels, d.links, d.link_rd_gbps)


def capacity_gbps(d: MemSystem) -> float:
    """Full-server read bandwidth of a candidate design, GB/s."""
    return d.dram_channels * _design_per_ch(d) * SCALE


def candidate_designs(channels=(2, 4, 8), llc_mb=(1.0,),
                      premium_ns=(hw.CXL_LAT_NS, hw.CXL_LAT_PESSIMISTIC_NS),
                      include_registry: bool = True,
                      include_measured: bool = True) -> tuple:
    """The candidate set: registry designs + a generated CXL grid +
    measured devices, deduplicated by name (first wins).

    Generated points follow the coaxial-Nx idiom (one x8 link per DDR
    channel behind it) with Table-1/2 area accounting via
    :func:`coaxial.design_cost`.
    """
    out: dict[str, MemSystem] = {DDR_BASELINE.name: DDR_BASELINE}
    if include_registry:
        for d in coaxial.all_designs():
            out.setdefault(d.name, d)
    for ch in channels:
        for llc in llc_mb:
            for prem in premium_ns:
                name = f"cxl-{ch}ch-llc{llc:g}-{prem:g}ns"
                if name in out:
                    continue
                cost = coaxial.design_cost(ch, ch, llc)
                out[name] = MemSystem(
                    name, dram_channels=int(ch), links=int(ch),
                    link_rd_gbps=hw.CXL_X8_RD_GBPS,
                    link_wr_gbps=hw.CXL_X8_WR_GBPS,
                    iface_lat_ns=float(prem), llc_mb_per_core=float(llc),
                    rel_area=float(cost["rel_area"]),
                    rel_pins=float(cost["rel_pins"]))
    if include_measured:
        for d in MEASURED_DEVICES:
            out.setdefault(d.name, d)
    return tuple(out.values())


def _tiered_cost(d: MemSystem, n_hot: int, links_cold: int) -> dict:
    """Table-1/2 accounting for a DDR+CXL tiered variant of ``d``.

    ``design_cost`` models pure designs; a tiered point is the hot
    tier's DDR channels plus the cold tier's links, so combine two pure
    calls and subtract the double-counted core+LLC base."""
    llc = d.llc_mb_per_core
    hot = coaxial.design_cost(n_hot, 0, llc)
    cold = coaxial.design_cost(0, links_cold, llc)
    none = coaxial.design_cost(0, 0, llc)
    return dict(
        rel_area=float(hot["rel_area"] + cold["rel_area"] -
                       none["rel_area"]),
        rel_pins=float(hot["rel_pins"] + cold["rel_pins"]))


@dataclasses.dataclass(frozen=True)
class _Variant:
    """One (design, tier split) point and its lane geometry."""

    design: MemSystem
    tier_split: float
    n_hot: int
    n_cold: int
    links_cold: int
    rel_area: float
    rel_pins: float

    @property
    def name(self) -> str:
        if self.tier_split:
            return f"{self.design.name}+tier{self.tier_split:g}"
        return self.design.name

    @property
    def lanes(self) -> tuple:
        """((channel_count, per_channel_gbps, premium_ns), ...)."""
        out = []
        if self.n_hot:
            out.append((self.n_hot, hw.DDR5_CH_BW_GBPS, 0.0))
        if self.n_cold:
            per = _per_channel_gbps(self.n_cold, self.links_cold,
                                    self.design.link_rd_gbps)
            out.append((self.n_cold, per, self.design.iface_lat_ns))
        return tuple(out)

    @property
    def capacity_gbps(self) -> float:
        return sum(n * per for n, per, _ in self.lanes) * SCALE


def _variants(designs, tier_splits) -> list:
    out = []
    for d in designs:
        if d.links == 0:
            # Pure direct-DDR design: one hot lane, split is moot.
            out.append(_Variant(d, 0.0, d.dram_channels, 0, 0,
                                d.rel_area, d.rel_pins))
            continue
        seen = set()
        for s in tier_splits:
            n_hot = int(round(s * d.dram_channels))
            if n_hot in seen:
                continue
            seen.add(n_hot)
            n_cold = d.dram_channels - n_hot
            links_cold = (max(1, math.ceil(d.links * n_cold /
                                           d.dram_channels))
                          if n_cold else 0)
            if n_hot == 0:
                out.append(_Variant(d, 0.0, 0, n_cold, d.links,
                                    d.rel_area, d.rel_pins))
            else:
                cost = _tiered_cost(d, n_hot, links_cold)
                out.append(_Variant(d, n_hot / d.dram_channels, n_hot,
                                    n_cold, links_cold,
                                    cost["rel_area"], cost["rel_pins"]))
    return out


@dataclasses.dataclass(frozen=True)
class DesignVerdict:
    """One candidate's fate against the SLO."""

    name: str
    design: str              # underlying registry/generated design name
    channels: int
    llc_mb_per_core: float
    premium_ns: float
    tier_split: float
    rel_area: float
    rel_pins: float
    ipc: tuple               # model-side per-arch IPC on this design
    peak_rho: float          # worst-epoch lane utilization
    access_p99_ns: float     # worst-epoch byte-weighted access p99 (DES)
    token_p99_ms: float      # worst epoch x arch, wave model + IPC floor
    token_mean_ms: float
    meets_slo: bool


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Every candidate's verdict, cheapest-first, plus the pick."""

    archs: tuple
    batch: int
    context: int
    tokens_per_req: float
    trace: str
    peak_rps: float
    slo_p99_ms: float
    engine: str
    steps: int
    demands: tuple           # DecodeDemand per arch
    verdicts: tuple          # sorted by (rel_area, rel_pins, name)

    @property
    def best(self) -> DesignVerdict | None:
        """Minimum-area verdict meeting the SLO (None if none do)."""
        for v in self.verdicts:
            if v.meets_slo:
                return v
        return None

    @property
    def closest(self) -> DesignVerdict:
        """Fallback pick: the lowest-p99 candidate."""
        return min(self.verdicts, key=lambda v: v.token_p99_ms)

    def table(self) -> str:
        hdr = (f"{'design':34s} {'area':>6s} {'pins':>6s} {'rho':>5s} "
               f"{'acc p99':>9s} {'tok p99':>10s} {'SLO':>4s}")
        lines = [hdr]
        for v in self.verdicts:
            lines.append(
                f"{v.name:34s} {v.rel_area:6.3f} {v.rel_pins:6.3f} "
                f"{v.peak_rho:5.2f} {v.access_p99_ns:7.0f}ns "
                f"{v.token_p99_ms:8.1f}ms {'ok' if v.meets_slo else 'NO':>4s}")
        return "\n".join(lines)


def default_steps() -> int:
    """Library default DES budget, honoring ``$REPRO_DES_STEPS``."""
    cap = os.environ.get("REPRO_DES_STEPS")
    if cap:
        return min(DEFAULT_STEPS, int(cap))
    return DEFAULT_STEPS


def plan_capacity(archs, trace: Trace, *, slo_p99_ms: float,
                  batch: int = 128, context: int = 4096,
                  tokens_per_req: float = 128.0,
                  channels=(2, 4, 8), llc_mb=(1.0,),
                  premium_ns=(hw.CXL_LAT_NS, hw.CXL_LAT_PESSIMISTIC_NS),
                  tier_splits=(0.0, 0.5),
                  include_registry: bool = True,
                  include_measured: bool = True,
                  peak_util: float | None = None,
                  harvest_bw_gbps: float = 0.0,
                  steps: int | None = None, seed: int = 0,
                  engine: str = "event", devices=None,
                  p99_source: str = "des", lut=None) -> CapacityPlan:
    """Sweep candidates against a trace; return every verdict + the pick.

    ``archs`` is one arch id or a fleet of them (requests split evenly).
    ``peak_util`` rescales the trace so its peak offered load hits that
    utilization of the LARGEST candidate (shape-only traces); omit it to
    take the trace's absolute request rates.  ``steps`` is the DES
    simulated-time budget per cell (default :func:`default_steps`).

    ``p99_source`` picks where access latency comes from: ``"des"``
    (default) runs the batched per-cell simulation; ``"lut"`` reads the
    mean and p99 wait from a :class:`~repro.core.queuelut.QueueLUT`
    (``lut``, or the shared default surface) -- the same in-loop tail
    the designer ascends, so a plan and a ``repro.core.designer`` run
    judge candidates by one law.  LUT mode approximates each lane by
    the LUT's build-base transfer/service constants (the per-lane
    ``t_xfer_ns`` is folded into ``rho`` already), trading per-cell DES
    fidelity for a zero-simulation sweep -- with a warm
    ``$REPRO_LUT_CACHE`` (the persistent LUT store,
    :mod:`repro.core.lutstore`) the whole plan then runs without a
    single DES trace.

    ``harvest_bw_gbps > 0`` enables idle-I/O harvesting (arXiv
    2511.12349): each epoch lends that much idle I/O bandwidth per
    channel for its ``harvest_duty`` fraction of time (fill the trace
    via :meth:`~repro.serving.traffic.Trace.with_harvest`, which
    anti-correlates duty with load, or a 4th CSV column).  DES cells
    run the true two-state chain; LUT mode queries the harvest axis at
    the reference-bandwidth ``duty_eff`` reduction.
    """
    if isinstance(archs, str):
        archs = (archs,)
    archs = tuple(archs)
    if steps is None:
        steps = default_steps()
    demands = tuple(decode_demand(a, batch=batch, context=context)
                    for a in archs)
    workloads = tuple(llm_workload(a, batch=batch, context=context)
                      for a in archs)

    designs = candidate_designs(channels=channels, llc_mb=llc_mb,
                                premium_ns=premium_ns,
                                include_registry=include_registry,
                                include_measured=include_measured)
    variants = _variants(designs, tier_splits)

    # --- model side: one vmapped solve of every design x arch ----------
    sw = coaxial.solve_spec(coaxial.sweep_spec(design=designs),
                            workloads=workloads)
    ipc_tab = np.asarray(sw.results.ipc, np.float64)
    ipc_tab = ipc_tab.reshape(len(sw.designs), len(workloads))
    ipc_by_name = {d.name: tuple(float(x) for x in ipc_tab[i])
                   for i, d in enumerate(sw.designs)}

    # --- traffic: offered bytes per second, per epoch -------------------
    # Each request decodes tokens_per_req tokens; each token moves the
    # arch's read+write bytes.  The fleet splits the request rate evenly.
    bytes_per_req = sum(
        tokens_per_req * (d.read_bytes + d.state_write_bytes)
        for d in demands) / len(demands)
    if peak_util is not None:
        cap_max = max(v.capacity_gbps for v in variants)
        peak_offered = trace.peak_rps * bytes_per_req / 1e9
        if peak_offered > 0:
            trace = trace.scaled(peak_util * cap_max / peak_offered)
    epochs = trace.epochs

    # --- mechanism side: ONE batched DES over every (variant, epoch,
    # lane) cell; p99 access latency from per-request records. ----------
    configs, index = [], {}
    for vi, v in enumerate(variants):
        total_ch = v.n_hot + v.n_cold
        for ei, e in enumerate(epochs):
            offered = e.rps * bytes_per_req / 1e9          # GB/s
            for li, (n_ch, per_gbps, prem) in enumerate(v.lanes):
                share = n_ch / total_ch
                rho = min(max(offered * share /
                              (n_ch * per_gbps * SCALE), 0.02), 0.95)
                index[(vi, ei, li)] = len(configs)
                configs.append(memsim.ChannelConfig(
                    rho=rho, kappa=e.kappa,
                    outstanding=hw.MAX_MLP * hw.SIM_CORES / total_ch,
                    t_xfer_ns=hw.CACHE_LINE_B / per_gbps,
                    cxl_lat_ns=prem,
                    harvest_duty=e.harvest_duty,
                    harvest_bw_gbps=float(harvest_bw_gbps)))
    if p99_source == "lut":
        from repro.core import queuelut
        needs_h = (float(harvest_bw_gbps) > 0.0
                   and any(e.harvest_duty > 0.0 for e in epochs))
        if lut is None:
            lut = queuelut.default_queue_lut(steps=steps, engine=engine,
                                             harvest=needs_h)
        elif needs_h and lut.harvest_grid is None:
            raise ValueError(
                "harvesting trace needs a QueueLUT with the harvest "
                "axis; build_queue_lut(harvest=...) or pass lut=None")
        arr = lambda attr: np.asarray([getattr(c, attr) for c in configs],
                                      np.float64)
        if lut.harvest_grid is not None:
            hq = (arr("harvest_duty") * arr("harvest_bw_gbps") /
                  queuelut.HARVEST_REF_BW_GBPS)
            w_mean, _, w_p99, _ = lut.lookup(
                arr("rho"), arr("kappa"), arr("outstanding"),
                arr("eta"), hq)
        else:
            w_mean, _, w_p99, _ = lut.lookup(arr("rho"), arr("kappa"),
                                             arr("outstanding"),
                                             arr("eta"))
        prem = arr("cxl_lat_ns")
        mean = hw.DRAM_SERVICE_NS + np.asarray(w_mean, np.float64) + prem
        p99 = hw.DRAM_SERVICE_NS + np.asarray(w_p99, np.float64) + prem
    elif p99_source == "des":
        stats = memsim.simulate(configs, steps=steps, seed=seed,
                                engine=engine, devices=devices)
        p99 = np.asarray(stats.p99_ns, np.float64)
        mean = np.asarray(stats.mean_ns, np.float64)
    else:
        raise ValueError(f"p99_source must be 'des' or 'lut', "
                         f"got {p99_source!r}")
    rho_of = np.asarray([c.rho for c in configs], np.float64)

    # --- compose token latency, judge the SLO ---------------------------
    in_flight = hw.MAX_MLP * hw.SIM_CORES * SCALE
    verdicts = []
    for vi, v in enumerate(variants):
        total_ch = v.n_hot + v.n_cold
        shares = [n / total_ch for n, _, _ in v.lanes]
        worst_p99 = worst_mean = worst_rho = 0.0
        for ei in range(len(epochs)):
            cells = [index[(vi, ei, li)] for li in range(len(v.lanes))]
            acc99 = float(sum(s * p99[c] for s, c in zip(shares, cells)))
            accmu = float(sum(s * mean[c] for s, c in zip(shares, cells)))
            worst_p99 = max(worst_p99, acc99)
            worst_mean = max(worst_mean, accmu)
            worst_rho = max(worst_rho, float(rho_of[cells].max()))
        ipcs = ipc_by_name[v.design.name]
        tok99 = tokmu = 0.0
        for d, ipc in zip(demands, ipcs):
            lines = batch * d.read_bytes / hw.CACHE_LINE_B
            waves = max(lines / in_flight, 1.0)
            # Model-side floor: the step also retires instructions.
            t_model = (batch * d.inst_per_token /
                       (ipc * hw.CORE_CLK_GHZ * 1e9 *
                        hw.SIM_CORES * SCALE))
            tok99 = max(tok99, waves * worst_p99 * 1e-9, t_model)
            tokmu = max(tokmu, waves * worst_mean * 1e-9, t_model)
        verdicts.append(DesignVerdict(
            name=v.name, design=v.design.name,
            channels=v.design.dram_channels,
            llc_mb_per_core=v.design.llc_mb_per_core,
            premium_ns=v.design.iface_lat_ns if v.n_cold else 0.0,
            tier_split=v.tier_split, rel_area=v.rel_area,
            rel_pins=v.rel_pins, ipc=ipcs, peak_rho=worst_rho,
            access_p99_ns=worst_p99, token_p99_ms=tok99 * 1e3,
            token_mean_ms=tokmu * 1e3,
            meets_slo=bool(tok99 * 1e3 <= slo_p99_ms)))
    verdicts.sort(key=lambda v: (v.rel_area, v.rel_pins, v.name))
    return CapacityPlan(
        archs=archs, batch=batch, context=context,
        tokens_per_req=tokens_per_req, trace=trace.name,
        peak_rps=trace.peak_rps, slo_p99_ms=slo_p99_ms,
        engine=engine if p99_source == "des" else "lut",
        steps=steps, demands=demands, verdicts=tuple(verdicts))
