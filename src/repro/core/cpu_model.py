"""Fixed-point loaded-CPU performance model (the ChampSim stand-in).

The paper simulates a 12-core OoO CPU (Table 3) with ChampSim+DRAMsim3.  For
the reproduction we use a bottleneck model that captures exactly the effects
the paper's argument rests on:

    CPI = max(CPI_exec + CPI_mem,  CPI_bw)
    CPI_mem = (MPKI/1000) * (L_mean + gamma * L_stdev) * f_clk / MLP
    CPI_bw  = per-instruction bytes / available bandwidth  (any interface)

with L_mean = DRAM service + queue wait + CXL premium (+ link queue), and the
queue wait from the calibrated load-latency model (queueing.py).  Utilization
rho depends on achieved IPC and IPC depends on the latency at rho -- a closed
loop -- so we solve a damped fixed point, jointly for all 35 workloads
(vectorized in jnp).

Calibration: per workload, the effective MLP and ``CPI_exec`` are derived so
the *baseline* DDR system reproduces Table 4's IPC exactly, given the
workload's ``exec_frac`` (non-memory CPI share).  COAXIAL designs are then
evaluated with identical per-workload parameters -- the speedups are
predictions of the model, not fits.

Design-space batching: a :class:`MemSystem` is a frozen-dataclass façade for
humans; the solver itself consumes :class:`MemSystemArrays`, a pytree of
float leaves (``is_cxl`` is a 0/1 mask) that can be stacked along a leading
design axis.  All model terms are branch-free in the design dimension
(``jnp.where``/mask arithmetic instead of ``if sys.is_cxl``), so one jitted
function -- :data:`_solve_cells_jit` -- serves every solve surface.

Named-axis sweeps: the jitted solver consumes ONE flattened cell axis plus
two overrides pytrees (``design_overrides`` / ``workload_overrides``, NaN =
"keep the design's / workload's own value", applied branch-free inside the
trace exactly like ``iface_override_ns``'s NaN mask).  Any grid of named
axes -- designs x iface latencies x LLC sizes x kappa x ... -- lowers to
the same flat call, so a sweep of ANY dimensionality costs one XLA compile
per flattened cell count.  :func:`solve` and :func:`solve_batch` are thin
shims over it; ``sweepspec.SweepSpec`` is the declarative front end.

The whole solve is differentiable end to end (the fixed point unrolls
through ``lax.fori_loop`` with static bounds): :func:`design_gradient`
exposes d(geomean speedup)/d(design field) for gradient-based design
optimization.

Queue-wait backends: the queue model inside the fixed point is pluggable.
``queue_model="closed_form"`` (the default) uses the calibrated
``queueing.effective_queue_wait_ns`` / ``stdev_latency_ns`` pair exactly
as before -- bit-identical to the historical solver.  ``queue_model=
"memsim"`` replaces both with a DES-derived :class:`repro.core.queuelut.
QueueLUT`: mean wait and latency stdev are read from the mechanism's
measured (rho, kappa, outstanding) tables through differentiable
multilinear interpolation.  The LUT is passed into the jitted solver as
a pytree operand (``lut=None`` selects the closed form), so the
pytree-structure difference keys the jit cache -- each backend still
costs ONE trace per flattened cell count, and ``design_gradient``
differentiates straight through the table.

Tail path: on the memsim backend the LUT also carries the DES-measured
p99 queue wait, and the solver threads a differentiable per-workload
``latency_p99_ns`` / ``cpi_mem_p99`` alongside the mean-based fixed
point (the p99 latency at the CONVERGED operating point; it does not
feed the fixed point itself, which stays mean + gamma*sigma).  The
closed form has no calibrated tail law, so those outputs are NaN under
``queue_model="closed_form"`` -- the tail surface is mechanism-only by
construction.  ``repro.core.designer`` differentiates through this path
to enforce p99 SLOs during gradient ascent.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw, queueing
from repro.core.workloads import (SWEEPABLE_FIELDS as SWEEPABLE_WORKLOAD_FIELDS,
                                  WORKLOADS, WorkloadArrays, as_arrays)

#: Architectural bound on outstanding misses per core (MSHRs / 256-ROB).
MAX_MLP = hw.MAX_MLP
#: Floor on the calibrated non-memory CPI.
MIN_CPI_EXEC = 0.02
#: LLC miss-rate sensitivity to capacity: MPKI ~ C^-alpha (sqrt(2)-rule-ish).
ALPHA_LLC = 0.25
#: MPKI multiplier when the working set fits in the LLC.
LLC_FIT_FACTOR = 0.05
#: Working sets at/above this are treated as streaming (compulsory misses):
#: their MPKI does not react to LLC capacity.
STREAMING_WS_MB = 1024.0
#: Fixed-point iterations / damping.
FP_ITERS = 120
FP_DAMP = 0.5

#: Pluggable queue-wait backends of the fixed point (see module docstring).
QUEUE_MODELS = ("closed_form", "memsim")


def resolve_queue_lut(queue_model: str, lut=None, *,
                      harvest: bool = False):
    """Map a backend name to the LUT operand the jitted solver consumes.

    ``closed_form`` -> ``None`` (the calibrated ``queueing`` closed form);
    ``memsim`` -> the given :class:`repro.core.queuelut.QueueLUT`, or the
    default surface when none is passed (built by the DES's per-request
    event engine at the default grids, resolved through the persistent
    LUT store -- memory -> ``$REPRO_LUT_CACHE`` -> build; a warm store
    read costs zero DES traces).  ``harvest=True``
    means the solve needs the harvest axis: the default build gains it,
    and an explicitly passed 4-D surface is rejected rather than
    silently dropping the mechanism.  The runtime import keeps
    ``queuelut`` (which builds its tables through ``coaxial``) out of this
    module's import cycle.
    """
    if queue_model not in QUEUE_MODELS:
        raise ValueError(f"unknown queue_model {queue_model!r}; "
                         f"choose from {QUEUE_MODELS}")
    if queue_model == "closed_form":
        return None
    if lut is None:
        from repro.core import queuelut
        lut = queuelut.default_queue_lut(harvest=harvest)
    elif harvest and lut.harvest_grid is None:
        raise ValueError(
            "designs harvest (harvest_duty * harvest_bw_gbps > 0) but "
            "the given QueueLUT has no harvest axis; build it with "
            "build_queue_lut(harvest=...) or pass lut=None")
    return lut


def _any_harvest(sysa: MemSystemArrays, sys_ov=None) -> bool:
    """Host-side peek: does any cell harvest (effective ``harvest_duty``
    AND ``harvest_bw_gbps`` > 0 with NaN-masked overrides applied)?  Used
    only to pick the default LUT surface -- concrete values, never a jit
    cache key (mirrors memsim's ``_harvest_active``)."""
    ov = sys_ov or {}

    def eff(f):
        s = np.asarray(getattr(sysa, f), np.float64)
        v = np.asarray(ov.get(f, np.nan), np.float64)
        return np.where(np.isnan(v), s, v)

    return bool(np.any((eff("harvest_duty") > 0.0)
                       & (eff("harvest_bw_gbps") > 0.0)))


@dataclasses.dataclass(frozen=True)
class MemSystem:
    """One server memory-system design point (Table 2, scaled to 12 cores)."""

    name: str
    dram_channels: int          # DDR5 channels behind all interfaces
    links: int                  # CXL links (0 => direct DDR attach)
    link_rd_gbps: float         # per-link read goodput
    link_wr_gbps: float         # per-link write goodput
    iface_lat_ns: float         # CXL end-to-end latency premium
    llc_mb_per_core: float
    rel_area: float = 1.0       # die area relative to the DDR baseline
    rel_pins: float = 1.0       # memory-interface pins relative to baseline
    #: Idle-I/O harvesting (arXiv 2511.12349): fraction of time idle CXL
    #: I/O links are lent to the memory pool, and the lent bandwidth each
    #: DRAM channel gains while they are.  0/0 (the default) disables the
    #: mechanism; it only acts under ``queue_model="memsim"`` (the closed
    #: form has no harvest law and ignores both fields).
    harvest_duty: float = 0.0
    harvest_bw_gbps: float = 0.0

    @property
    def is_cxl(self) -> bool:
        return self.links > 0

    def as_arrays(self) -> "MemSystemArrays":
        """Scalar-leaved pytree view of this design (solver calling form)."""
        f = lambda x: jnp.asarray(float(x))
        return MemSystemArrays(
            dram_channels=f(self.dram_channels), links=f(self.links),
            link_rd_gbps=f(self.link_rd_gbps),
            link_wr_gbps=f(self.link_wr_gbps),
            iface_lat_ns=f(self.iface_lat_ns),
            llc_mb_per_core=f(self.llc_mb_per_core),
            harvest_duty=f(self.harvest_duty),
            harvest_bw_gbps=f(self.harvest_bw_gbps),
            is_cxl=f(1.0 if self.is_cxl else 0.0))


class MemSystemArrays(NamedTuple):
    """Pytree of design-point parameters, batchable along a leading axis.

    All leaves are float arrays of a common shape: ``()`` for one design,
    ``(D,)`` for a stacked design axis.  ``is_cxl`` is a 0/1 mask so the
    solver can stay branch-free in the design dimension.
    """

    dram_channels: jnp.ndarray
    links: jnp.ndarray
    link_rd_gbps: jnp.ndarray
    link_wr_gbps: jnp.ndarray
    iface_lat_ns: jnp.ndarray
    llc_mb_per_core: jnp.ndarray
    harvest_duty: jnp.ndarray
    harvest_bw_gbps: jnp.ndarray
    is_cxl: jnp.ndarray


#: Design fields a sweep axis may override (everything except the derived
#: ``is_cxl`` mask and ``iface_lat_ns``, which has its own NaN-masked
#: override argument with the legacy CXL-only semantics).
SWEEPABLE_DESIGN_FIELDS = ("dram_channels", "links", "link_rd_gbps",
                           "link_wr_gbps", "llc_mb_per_core",
                           "harvest_duty", "harvest_bw_gbps")


def stack_designs(designs) -> MemSystemArrays:
    """Stack ``MemSystem`` façades into one ``(D,)``-leaved pytree."""
    leaves = [d.as_arrays() for d in designs]
    return MemSystemArrays(*(jnp.stack(xs) for xs in zip(*leaves)))


def _apply_design_overrides(sysa: MemSystemArrays, ov) -> MemSystemArrays:
    """NaN-masked per-field substitution; ``is_cxl`` is re-derived from the
    effective link count so a ``links`` axis can cross the DDR/CXL boundary
    branch-free."""
    eff = {f: jnp.where(jnp.isnan(v), getattr(sysa, f), v)
           for f, v in ov.items()}
    sysa = sysa._replace(**eff)
    return sysa._replace(is_cxl=(sysa.links > 0).astype(sysa.links.dtype))


def _apply_workload_overrides(wl: WorkloadArrays, ov) -> WorkloadArrays:
    """NaN-masked substitution of one scalar per behavioral parameter,
    broadcast over all workloads (a bound axis redefines the parameter for
    the whole suite -- a synthetic-workload sweep)."""
    repl = {f: jnp.where(jnp.isnan(v), getattr(wl, f), v)
            for f, v in ov.items()}
    return dataclasses.replace(wl, **repl)


def _bw_efficiency(wb):
    """Sustained/peak DDR efficiency: 70-90% depending on R/W turnaround."""
    write_share = wb / (1.0 + wb)
    return 0.92 - 0.18 * write_share


@dataclasses.dataclass
class ModelResult:
    """Per-workload outputs of one (memory system x utilization) evaluation.

    Arrays are ``(n_workloads,)`` for a single design point;
    :func:`solve_batch` returns the same structure with leading
    ``(designs, iface_lats, core_counts)`` axes.
    """

    ipc: np.ndarray
    cpi: np.ndarray
    latency_ns: np.ndarray       # mean LLC-miss latency
    queue_ns: np.ndarray         # queue-wait component (DRAM + link)
    iface_ns: np.ndarray         # CXL interface component
    service_ns: np.ndarray       # DRAM service component
    sigma_ns: np.ndarray         # latency stdev
    rho: np.ndarray              # DRAM-side bandwidth utilization
    read_gbps: np.ndarray
    write_gbps: np.ndarray
    latency_p99_ns: np.ndarray   # p99 LLC-miss latency (NaN: closed form)
    cpi_mem_p99: np.ndarray      # memory CPI at the p99 latency (NaN: cf)

    def speedup_vs(self, base: "ModelResult") -> np.ndarray:
        return self.ipc / base.ipc

    def __getitem__(self, idx) -> "ModelResult":
        """Slice every field identically (e.g. one design from a batch)."""
        pick = lambda x: x[idx]
        return ModelResult(**{f.name: pick(getattr(self, f.name))
                              for f in dataclasses.fields(self)})

    def reshape(self, *grid_shape) -> "ModelResult":
        """Reshape the leading (cell) axes; the workload axis stays last."""
        re = lambda x: x.reshape(tuple(grid_shape) + x.shape[-1:])
        return ModelResult(**{f.name: re(getattr(self, f.name))
                              for f in dataclasses.fields(self)})


def _mpki_eff(wl: WorkloadArrays, sysa: MemSystemArrays, n_active):
    scale = (2.0 / sysa.llc_mb_per_core) ** ALPHA_LLC
    streaming = wl.ws_mb >= STREAMING_WS_MB
    mpki = wl.mpki * jnp.where(streaming, 1.0, scale)
    llc_total = sysa.llc_mb_per_core * hw.SIM_CORES
    fits = (wl.ws_mb * n_active) <= llc_total
    return jnp.where(fits, wl.mpki * LLC_FIT_FACTOR, mpki)


def _latency_terms(wl, sysa: MemSystemArrays, read_gbps, write_gbps,
                   n_active, iface_lat_ns, lut=None):
    """Mean latency components + stdev + p99 at the given traffic level.

    Branch-free in the design dimension: link terms are computed with
    guarded denominators and zeroed by the ``is_cxl`` mask, so a DDR design
    (links == 0) yields exactly the legacy no-link values.

    ``lut`` selects the queue-wait backend at trace time: ``None`` is the
    calibrated closed form; a :class:`~repro.core.queuelut.QueueLUT`
    replaces the DRAM-side wait with the DES-measured mean-wait table
    (``eta`` is a real grid axis of the 4-D surface -- the DES simulates
    the workload's DRAM sensitivity as a scaled blocking-episode
    probability, so no post-hoc multiplier remains) and the sigma
    heuristic with the DES-measured latency-stdev table.  The CXL *link*
    queue keeps its closed form either way -- the LUT tabulates the DRAM
    channel, not the serial link.

    Returns ``(latency, queue, sigma, rho, latency_p99)``.  The p99 term
    is the tail counterpart of ``latency``: DRAM service + DES-measured
    p99 queue wait + (mean) link wait + interface premium.  The closed
    form has no calibrated p99 law, so it returns NaN there -- consumers
    that need the tail must solve under ``queue_model="memsim"``.
    """
    eff = _bw_efficiency(wl.wb)
    ch_bw = hw.DDR5_CH_BW_GBPS * eff
    rho = (read_gbps + write_gbps) / (sysa.dram_channels * ch_bw)
    outstanding = n_active * MAX_MLP / sysa.dram_channels
    if lut is None:
        w_dram = queueing.effective_queue_wait_ns(
            rho, kappa=wl.kappa, eta=wl.eta,
            outstanding_per_channel=outstanding, channel_bw_gbps=ch_bw)
    elif lut.harvest_grid is not None:
        # Harvest query in table units: lent-time fraction scaled to the
        # one-channel reference bandwidth the axis was built at
        # (duty_eff = duty * bw / ref; see queuelut.HARVEST_REF_BW_GBPS).
        harvest = (sysa.harvest_duty * sysa.harvest_bw_gbps /
                   hw.DDR5_CH_BW_GBPS)
        w_mem, _, w_p99, sigma_mem = lut.lookup(rho, wl.kappa,
                                                outstanding, wl.eta,
                                                harvest)
        w_dram = w_mem
    else:
        w_mem, _, w_p99, sigma_mem = lut.lookup(rho, wl.kappa,
                                                outstanding, wl.eta)
        w_dram = w_mem
    link_rd_bw = jnp.maximum(sysa.links * sysa.link_rd_gbps, 1e-9)
    rho_rx = read_gbps / link_rd_bw
    svc_rx = hw.CACHE_LINE_B / jnp.maximum(sysa.link_rd_gbps, 1e-9)
    w_link = sysa.is_cxl * queueing.link_queue_wait_ns(rho_rx, svc_rx,
                                                       wl.kappa)
    queue = w_dram + w_link
    sigma = (queueing.stdev_latency_ns(queue) if lut is None
             else jnp.broadcast_to(sigma_mem, jnp.shape(queue)))
    latency = hw.DRAM_SERVICE_NS + queue + iface_lat_ns
    if lut is None:
        latency_p99 = jnp.full_like(latency, jnp.nan)
    else:
        latency_p99 = jnp.broadcast_to(
            hw.DRAM_SERVICE_NS + w_p99 + w_link + iface_lat_ns,
            jnp.shape(latency))
    return latency, queue, sigma, rho, latency_p99


def _cpi_mem(wl, mpki_eff, latency, sigma, mlp):
    l_eff_cyc = (latency + wl.gamma * sigma) * hw.CORE_CLK_GHZ
    return (mpki_eff / 1000.0) * l_eff_cyc / mlp


def _cpi_mem_p99(mpki_eff, latency_p99, mlp):
    """Memory CPI with every miss charged the p99 latency -- the tail
    counterpart of :func:`_cpi_mem` (no sigma term: the p99 already IS a
    distributional statistic)."""
    return (mpki_eff / 1000.0) * latency_p99 * hw.CORE_CLK_GHZ / mlp


def _cpi_bw(wl, mpki_eff, sysa: MemSystemArrays, n_active):
    """Bandwidth-bound CPI floor for every interface in the system.

    The CXL-link floors are masked by ``is_cxl``; ``max`` with a masked 0
    leaves the DDR-only floor untouched, so DDR designs are bit-identical
    to the legacy branched code.
    """
    bytes_rd = (mpki_eff / 1000.0) * hw.CACHE_LINE_B          # per inst
    bytes_wr = bytes_rd * wl.wb
    eff = _bw_efficiency(wl.wb)
    cpi = (bytes_rd + bytes_wr) * n_active * hw.CORE_CLK_GHZ / \
        (sysa.dram_channels * hw.DDR5_CH_BW_GBPS * eff)
    link_rd_bw = jnp.maximum(sysa.links * sysa.link_rd_gbps, 1e-9)
    link_wr_bw = jnp.maximum(sysa.links * sysa.link_wr_gbps, 1e-9)
    cpi = jnp.maximum(cpi, sysa.is_cxl * bytes_rd * n_active *
                      hw.CORE_CLK_GHZ / link_rd_bw)
    cpi = jnp.maximum(cpi, sysa.is_cxl * bytes_wr * n_active *
                      hw.CORE_CLK_GHZ / link_wr_bw)
    return cpi


def _traffic(wl, ipc, mpki_eff, n_active):
    read = ipc * hw.CORE_CLK_GHZ * n_active * (mpki_eff / 1000.0) * \
        hw.CACHE_LINE_B  # GB/s
    return read, read * wl.wb


def _mlp_eff(wl, mlp_cal, rho):
    """Load-adaptive effective MLP.

    Hardware prefetchers run further ahead when bandwidth is free and
    throttle under contention, so the effective overlap grows as utilization
    drops: mlp_eff = mlp_cal * (1 + pf_boost * (1 - rho)), within the
    architectural [1, MAX_MLP].
    """
    return jnp.clip(mlp_cal * (1.0 + wl.pf_boost * (1.0 - _rho01(rho))),
                    1.0, MAX_MLP)


def _rho01(rho):
    return jnp.clip(rho, 0.0, 1.0)


def _calibrate(wl: WorkloadArrays, base: MemSystemArrays, n_active,
               lut=None):
    """Traceable core of :func:`calibrate` (baseline as a pytree).

    Calibration runs under the SAME queue backend as the solve: the
    memsim-backed model re-derives (cpi_exec, mlp_cal) against the DES
    waits so its baseline meets the Table-4 budget self-consistently.
    """
    mpki_eff = _mpki_eff(wl, base, n_active)
    read, write = _traffic(wl, wl.ipc, mpki_eff, n_active)
    latency, _, sigma, rho_base, _ = _latency_terms(
        wl, base, read, write, n_active, base.iface_lat_ns, lut)
    l_eff_cyc = (latency + wl.gamma * sigma) * hw.CORE_CLK_GHZ
    budget = (1.0 - wl.exec_frac) / wl.ipc
    mlp_raw = (mpki_eff / 1000.0) * l_eff_cyc / jnp.maximum(budget, 1e-9)
    mlp_base = jnp.clip(mlp_raw, 1.0, MAX_MLP)
    mlp_cal = mlp_base / (1.0 + wl.pf_boost * (1.0 - _rho01(rho_base)))
    cpi_exec = jnp.maximum(
        1.0 / wl.ipc - (mpki_eff / 1000.0) * l_eff_cyc / mlp_base,
        MIN_CPI_EXEC)
    return cpi_exec, mlp_cal


def calibrate(wl: WorkloadArrays, baseline, n_active=hw.SIM_CORES,
              queue_model: str = "closed_form", lut=None):
    """Per-workload (cpi_exec, mlp_cal) reproducing Table 4 on the baseline.

    Given exec_frac, the memory-CPI budget at the table operating point is
    (1 - exec_frac)/IPC; the effective MLP at the *baseline* utilization is
    whatever makes the latency model meet that budget, clamped to the
    architectural [1, MAX_MLP]; mlp_cal back-solves the load-adaptive form.

    ``baseline`` may be a :class:`MemSystem` façade or a
    :class:`MemSystemArrays` pytree.  ``queue_model`` picks the wait
    backend the calibration is run against (see module docstring).
    """
    if isinstance(baseline, MemSystem):
        baseline = baseline.as_arrays()
    return _calibrate(wl, baseline, n_active,
                      resolve_queue_lut(queue_model, lut))


def _solve_point(wl, sysa: MemSystemArrays, base: MemSystemArrays,
                 n_active, iface_override_ns, lut=None):
    """Calibrate + solve ONE design point (all workloads vectorized).

    ``iface_override_ns`` replaces the CXL latency premium of CXL designs;
    ``nan`` means "use the design's own premium".  Non-CXL designs keep
    their (zero) premium, so a baseline sliced out of any latency grid is
    identical to the baseline solved alone.  ``lut`` (None = closed form)
    picks the queue-wait backend for calibration AND the fixed point.
    """
    cpi_exec, mlp = _calibrate(wl, base, n_active, lut)
    premium = jnp.where(
        sysa.is_cxl > 0.0,
        jnp.where(jnp.isnan(iface_override_ns), sysa.iface_lat_ns,
                  iface_override_ns),
        sysa.iface_lat_ns)
    mpki_eff = _mpki_eff(wl, sysa, n_active)
    cpi_bw = _cpi_bw(wl, mpki_eff, sysa, n_active)

    def body(_, ipc):
        read, write = _traffic(wl, ipc, mpki_eff, n_active)
        latency, _, sigma, rho, _ = _latency_terms(
            wl, sysa, read, write, n_active, premium, lut)
        mlp_eff = _mlp_eff(wl, mlp, rho)
        cpi = jnp.maximum(
            cpi_exec + _cpi_mem(wl, mpki_eff, latency, sigma, mlp_eff),
            cpi_bw)
        return (1 - FP_DAMP) * ipc + FP_DAMP / cpi

    ipc = jax.lax.fori_loop(0, FP_ITERS, body, wl.ipc)
    read, write = _traffic(wl, ipc, mpki_eff, n_active)
    latency, queue, sigma, rho, lat_p99 = _latency_terms(
        wl, sysa, read, write, n_active, premium, lut)
    iface = jnp.broadcast_to(premium, jnp.shape(ipc))
    cpi_p99 = _cpi_mem_p99(mpki_eff, lat_p99, _mlp_eff(wl, mlp, rho))
    return (ipc, latency, queue, sigma, rho, read, write, iface,
            lat_p99, cpi_p99)


#: Number of times the jitted solver has been TRACED (not called).  A trace
#: only happens on a new flattened cell count, so a whole named-axis grid
#: -- however many axes -- bumps this by exactly one; tests pin that.
_TRACE_COUNT = [0]


def solve_trace_count() -> int:
    return _TRACE_COUNT[0]


def _solve_cells(wl, sysa, base, n_active, iface_ov, sys_ov, wl_ov,
                 lut=None):
    """vmap ``_solve_point`` over ONE flattened axis of grid cells.

    Every per-cell input -- the design leaves, the core count, the CXL
    latency override and both overrides pytrees -- is ``(N,)``; overrides
    are applied branch-free inside the cell before the fixed point runs.
    ``lut`` is shared across cells (closed over, not vmapped).  Output
    leaves are ``(N, n_workloads)``.
    """
    _TRACE_COUNT[0] += 1  # side effect runs at trace time only

    def cell(s, n, io, so, wo):
        return _solve_point(_apply_workload_overrides(wl, wo),
                            _apply_design_overrides(s, so), base, n, io,
                            lut)

    return jax.vmap(cell)(sysa, n_active, iface_ov, sys_ov, wl_ov)


_solve_cells_jit = jax.jit(_solve_cells)


def _pack_result(out, squeeze: bool) -> ModelResult:
    (ipc, latency, queue, sigma, rho, read, write, iface,
     lat_p99, cpi_p99) = out
    to_np = lambda x: np.asarray(x, np.float64)
    if squeeze:
        to_np = lambda x: np.asarray(x, np.float64)[0]
    ipc = to_np(ipc)
    return ModelResult(
        ipc=ipc, cpi=1.0 / ipc, latency_ns=to_np(latency),
        queue_ns=to_np(queue), iface_ns=to_np(iface),
        service_ns=np.full_like(ipc, hw.DRAM_SERVICE_NS),
        sigma_ns=to_np(sigma), rho=to_np(rho), read_gbps=to_np(read),
        write_gbps=to_np(write), latency_p99_ns=to_np(lat_p99),
        cpi_mem_p99=to_np(cpi_p99))


def _grid(values) -> jnp.ndarray:
    return jnp.asarray([float('nan') if v is None else float(v)
                        for v in values])


def _nan_cells(n: int, fields) -> dict:
    nans = jnp.full((n,), jnp.nan)
    return {f: nans for f in fields}


def solve_cells(sysa: MemSystemArrays, *, n_active, iface_override_ns=None,
                design_overrides=None, workload_overrides=None,
                baseline: MemSystem | None = None,
                workloads=WORKLOADS, queue_model: str = "closed_form",
                lut=None) -> ModelResult:
    """Solve N flattened grid cells in one jitted call.

    ``sysa`` leaves and ``n_active`` are ``(N,)``; ``iface_override_ns``
    and every overrides entry are ``(N,)`` with NaN meaning "keep the
    design's / workload's own value".  Missing override fields are filled
    with NaN so the jit cache keys on N alone -- any axis combination of
    the same flattened size shares one compile.  ``queue_model`` picks the
    wait backend (``"memsim"`` resolves ``lut`` to the cached default
    surface when none is given); per backend the grid still costs one
    trace per N.
    """
    wl = _to_jnp(as_arrays(workloads))
    base = (baseline or DDR_BASELINE).as_arrays()
    n = int(np.shape(sysa.dram_channels)[0])
    j = lambda x: jnp.asarray(np.asarray(x, np.float64))
    sysa = MemSystemArrays(*(j(leaf) for leaf in sysa))
    iface = (jnp.full((n,), jnp.nan) if iface_override_ns is None
             else j(iface_override_ns))
    sys_ov = _nan_cells(n, SWEEPABLE_DESIGN_FIELDS)
    sys_ov.update({f: j(v) for f, v in (design_overrides or {}).items()})
    lut = resolve_queue_lut(queue_model, lut,
                            harvest=_any_harvest(sysa, sys_ov))
    wl_ov = _nan_cells(n, SWEEPABLE_WORKLOAD_FIELDS)
    wl_ov.update({f: j(v) for f, v in (workload_overrides or {}).items()})
    out = _solve_cells_jit(wl, sysa, base, j(n_active), iface, sys_ov,
                           wl_ov, lut)
    return _pack_result(out, squeeze=False)


def solve(sys: MemSystem, *, baseline: MemSystem | None = None,
          n_active: int = hw.SIM_CORES, iface_lat_ns: float | None = None,
          workloads=WORKLOADS, queue_model: str = "closed_form",
          lut=None) -> ModelResult:
    """Evaluate all workloads on ``sys`` (calibrated against ``baseline``).

    Thin wrapper over the cell solver with N=1: every single-design call,
    for ANY design / core count / latency premium, shares one XLA
    compilation (per queue backend).  ``queue_model="memsim"`` evaluates
    the fixed point through the DES-derived :class:`~repro.core.queuelut.
    QueueLUT` instead of the closed form.
    """
    sysa = stack_designs([sys])
    if iface_lat_ns is not None:
        # Legacy solve() applied an explicit override even to non-CXL
        # designs; mirroring the field keeps that behaviour under the mask.
        sysa = sysa._replace(
            iface_lat_ns=jnp.full_like(sysa.iface_lat_ns,
                                       float(iface_lat_ns)))
    res = solve_cells(sysa, n_active=_grid([n_active]),
                      iface_override_ns=_grid([iface_lat_ns]),
                      baseline=baseline, workloads=workloads,
                      queue_model=queue_model, lut=lut)
    return res[0]


def solve_batch(designs, *, n_active_grid=(hw.SIM_CORES,),
                iface_lat_grid=(None,), baseline: MemSystem | None = None,
                workloads=WORKLOADS, queue_model: str = "closed_form",
                lut=None) -> ModelResult:
    """Evaluate a designs x iface-latencies x core-counts grid in ONE jit.

    ``iface_lat_grid`` entries override the CXL latency premium; ``None``
    means "each design's own premium".  Non-CXL designs ignore the override
    (their premium stays 0), so the DDR baseline column of the grid equals
    the standalone baseline bit-for-bit.

    Returns a :class:`ModelResult` whose arrays have shape
    ``(len(designs), len(iface_lat_grid), len(n_active_grid), n_workloads)``.
    """
    designs = tuple(designs)
    d, l, c = len(designs), len(iface_lat_grid), len(n_active_grid)
    sysa = stack_designs(designs)
    # Flatten design-major / core-minor: cell (i, j, k) -> i*L*C + j*C + k.
    sysa = MemSystemArrays(*(jnp.repeat(leaf, l * c) for leaf in sysa))
    iface = jnp.tile(jnp.repeat(_grid(iface_lat_grid), c), d)
    n_active = jnp.tile(_grid(n_active_grid), d * l)
    res = solve_cells(sysa, n_active=n_active, iface_override_ns=iface,
                      baseline=baseline, workloads=workloads,
                      queue_model=queue_model, lut=lut)
    return res.reshape(d, l, c)


def _to_jnp(wl: WorkloadArrays) -> WorkloadArrays:
    j = lambda x: jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64
                              else jnp.float32)
    return WorkloadArrays(
        name=wl.name, ipc=j(wl.ipc), mpki=j(wl.mpki), wb=j(wl.wb),
        kappa=j(wl.kappa), eta=j(wl.eta), exec_frac=j(wl.exec_frac),
        gamma=j(wl.gamma), pf_boost=j(wl.pf_boost), ws_mb=j(wl.ws_mb))


# ---------------------------------------------------------------------------
# Design points (Table 2, scaled to the simulated 12-core slice, Table 3).
# ---------------------------------------------------------------------------

DDR_BASELINE = MemSystem(
    "ddr-baseline", dram_channels=1, links=0, link_rd_gbps=0.0,
    link_wr_gbps=0.0, iface_lat_ns=0.0, llc_mb_per_core=2.0,
    rel_area=1.0, rel_pins=1.0)

COAXIAL_2X = MemSystem(
    "coaxial-2x", dram_channels=2, links=2, link_rd_gbps=hw.CXL_X8_RD_GBPS,
    link_wr_gbps=hw.CXL_X8_WR_GBPS, iface_lat_ns=hw.CXL_LAT_NS,
    llc_mb_per_core=2.0, rel_area=1.01, rel_pins=24 * 32 / (12 * 160))

COAXIAL_4X = MemSystem(
    "coaxial-4x", dram_channels=4, links=4, link_rd_gbps=hw.CXL_X8_RD_GBPS,
    link_wr_gbps=hw.CXL_X8_WR_GBPS, iface_lat_ns=hw.CXL_LAT_NS,
    llc_mb_per_core=1.0, rel_area=1.01, rel_pins=48 * 32 / (12 * 160))

COAXIAL_5X = MemSystem(
    "coaxial-5x", dram_channels=5, links=5, link_rd_gbps=hw.CXL_X8_RD_GBPS,
    link_wr_gbps=hw.CXL_X8_WR_GBPS, iface_lat_ns=hw.CXL_LAT_NS,
    llc_mb_per_core=2.0, rel_area=1.17, rel_pins=1.0)

#: 4 CXL-asym links, each feeding TWO DDR controllers on the type-3 device
#: (§4.3): 8 DRAM channels' worth of banks behind 4 asymmetric links.
COAXIAL_ASYM = MemSystem(
    "coaxial-asym", dram_channels=8, links=4,
    link_rd_gbps=hw.CXL_ASYM_RD_GBPS, link_wr_gbps=hw.CXL_ASYM_WR_GBPS,
    iface_lat_ns=hw.CXL_LAT_NS, llc_mb_per_core=1.0,
    rel_area=1.01, rel_pins=48 * 32 / (12 * 160))

DESIGNS = (DDR_BASELINE, COAXIAL_2X, COAXIAL_4X, COAXIAL_5X, COAXIAL_ASYM)


# ---------------------------------------------------------------------------
# Fig 3: variance-only experiment (bimodal latency, constant 150ns average).
# ---------------------------------------------------------------------------

#: The five Fig-3 workloads, in decreasing memory-bandwidth intensity.
FIG3_WORKLOADS = ("pagerank", "components", "masstree", "omnetpp", "raytrace")
FIG3_MEAN_NS = 150.0
#: (low, high) bimodal points with 4:1 ratio -> stdev 100/150/200 ns.
FIG3_DISTS = ((100.0, 350.0), (75.0, 450.0), (50.0, 550.0))


def variance_experiment(workload_names=FIG3_WORKLOADS, dists=FIG3_DISTS):
    """Relative performance under bimodal latency vs fixed 150ns (Fig 3)."""
    wls = [w for n in workload_names for w in WORKLOADS if w.name == n]
    wl = _to_jnp(as_arrays(wls))
    cpi_exec, mlp_cal = calibrate(wl, DDR_BASELINE)
    # The toy system of Fig 3 is unloaded (fixed-latency memory).
    mlp = _mlp_eff(wl, mlp_cal, jnp.zeros_like(wl.ipc))

    def perf(sigma_ns):
        l_eff = (FIG3_MEAN_NS + wl.gamma * sigma_ns) * hw.CORE_CLK_GHZ
        cpi = cpi_exec + (wl.mpki / 1000.0) * l_eff / mlp
        l_fix = FIG3_MEAN_NS * hw.CORE_CLK_GHZ
        cpi_fix = cpi_exec + (wl.mpki / 1000.0) * l_fix / mlp
        return np.asarray(cpi_fix / cpi, np.float64)

    out = {}
    for lo, hi in dists:
        sigma = float(np.sqrt(0.8 * (FIG3_MEAN_NS - lo) ** 2 +
                              0.2 * (hi - FIG3_MEAN_NS) ** 2))
        rel = perf(sigma)
        out[(lo, hi)] = dict(
            stdev_ns=sigma,
            per_workload=dict(zip(wl.name, rel.tolist())),
            geomean=float(np.exp(np.mean(np.log(rel)))))
    return out


def geomean(x, names=None) -> float:
    """Geometric mean of strictly positive values.

    Non-positive (or NaN) entries would silently propagate NaN out of the
    log; raise instead, naming the offending workloads when ``names`` is
    given (``Comparison.geomean_speedup`` passes its workload names).
    """
    x = np.asarray(x, np.float64)
    good = x > 0  # NaN compares false
    if not np.all(good):
        bad = np.flatnonzero(~good.reshape(-1))
        flat = x.reshape(-1)
        label = lambda i: names[i] if names is not None else f"[{i}]"
        detail = ", ".join(f"{label(int(i))}={flat[i]:g}" for i in bad[:8])
        more = "" if bad.size <= 8 else f" (+{bad.size - 8} more)"
        raise ValueError(
            f"geomean requires positive inputs; offending entries: "
            f"{detail}{more}")
    return float(np.exp(np.mean(np.log(x))))


# ---------------------------------------------------------------------------
# Gradient-based design optimization: jax.grad through the fixed point.
# ---------------------------------------------------------------------------

#: Design fields :func:`design_gradient` may differentiate with respect to
#: (the continuous fields; ``is_cxl`` topology is held fixed).
GRADIENT_FIELDS = SWEEPABLE_DESIGN_FIELDS + ("iface_lat_ns",)


def _gm_speedup(vals, sysa0, wl, basea, n_active, base_ipc, lut):
    """Geomean speedup of ``sysa0`` with ``vals`` substituted, vs a fixed
    baseline IPC vector -- the scalar :func:`design_gradient` derives."""
    sysa = sysa0._replace(**{k: jnp.asarray(v) for k, v in vals.items()})
    nan = jnp.asarray(float("nan"))
    ipc = _solve_point(wl, sysa, basea, n_active, nan, lut)[0]
    return jnp.exp(jnp.mean(jnp.log(ipc / base_ipc)))


#: Module-level jit so repeated gradient calls (e.g. an optimizer loop)
#: recompile only per distinct field set, not per call.
_design_grad_jit = jax.jit(jax.grad(_gm_speedup))


def design_gradient(sys: MemSystem | None = None,
                    fields=GRADIENT_FIELDS, *,
                    n_active: int = hw.SIM_CORES,
                    baseline: MemSystem | None = None,
                    workloads=WORKLOADS,
                    queue_model: str = "closed_form",
                    lut=None) -> dict[str, float]:
    """d(geomean speedup vs baseline) / d(design field) at ``sys``.

    Differentiates straight through the damped fixed point (the
    ``fori_loop`` has static bounds, so JAX unrolls its reverse pass via
    scan).  The ``is_cxl`` topology mask is held at the design's own value
    -- gradients flow through capacities (channels, links, bandwidths,
    LLC), not through the discrete DDR/CXL switch.  Under
    ``queue_model="memsim"`` the reverse pass also flows through the
    :class:`~repro.core.queuelut.QueueLUT`'s multilinear interpolation
    (piecewise-constant slope between grid nodes), with the baseline
    reference solved under the same backend.  Returns
    ``{field: gradient}`` in the order requested.

    Example::

        >>> from repro.core.cpu_model import COAXIAL_4X, design_gradient
        >>> g = design_gradient(COAXIAL_4X,
        ...                     ("dram_channels", "iface_lat_ns"))
        >>> sorted(g)
        ['dram_channels', 'iface_lat_ns']
        >>> g["dram_channels"] > 0.0    # more channels always help
        True
        >>> g["iface_lat_ns"] < 0.0     # a slower link never does
        True
    """
    sys = sys if sys is not None else COAXIAL_4X
    unknown = [f for f in fields if f not in GRADIENT_FIELDS]
    if unknown:
        raise ValueError(f"non-differentiable or unknown design fields "
                         f"{unknown}; choose from {GRADIENT_FIELDS}")
    baseline = baseline or DDR_BASELINE
    lut = resolve_queue_lut(
        queue_model, lut,
        harvest=(_any_harvest(sys.as_arrays())
                 or "harvest_duty" in fields
                 or "harvest_bw_gbps" in fields))
    wl = _to_jnp(as_arrays(workloads))
    # The reference is constant under the differentiated fields; reuse the
    # shared cell solver's compile for it.
    base_ipc = jnp.asarray(
        solve(baseline, baseline=baseline, n_active=n_active,
              workloads=workloads, queue_model=queue_model, lut=lut).ipc)
    vals = {f: jnp.asarray(float(getattr(sys, f))) for f in fields}
    grads = _design_grad_jit(vals, sys.as_arrays(), wl,
                             baseline.as_arrays(),
                             jnp.asarray(float(n_active)), base_ipc, lut)
    return {f: float(grads[f]) for f in fields}
