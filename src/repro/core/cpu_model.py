"""Fixed-point loaded-CPU performance model (the ChampSim stand-in).

The paper simulates a 12-core OoO CPU (Table 3) with ChampSim+DRAMsim3.  For
the reproduction we use a bottleneck model that captures exactly the effects
the paper's argument rests on:

    CPI = max(CPI_exec + CPI_mem,  CPI_bw)
    CPI_mem = (MPKI/1000) * (L_mean + gamma * L_stdev) * f_clk / MLP
    CPI_bw  = per-instruction bytes / available bandwidth  (any interface)

with L_mean = DRAM service + queue wait + CXL premium (+ link queue), and the
queue wait from the calibrated load-latency model (queueing.py).  Utilization
rho depends on achieved IPC and IPC depends on the latency at rho -- a closed
loop -- so we solve a damped fixed point, jointly for all 35 workloads
(vectorized in jnp).

Calibration: per workload, the effective MLP and ``CPI_exec`` are derived so
the *baseline* DDR system reproduces Table 4's IPC exactly, given the
workload's ``exec_frac`` (non-memory CPI share).  COAXIAL designs are then
evaluated with identical per-workload parameters -- the speedups are
predictions of the model, not fits.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw, queueing
from repro.core.workloads import WORKLOADS, WorkloadArrays, as_arrays

#: Architectural bound on outstanding misses per core (MSHRs / 256-ROB).
MAX_MLP = hw.MAX_MLP
#: Floor on the calibrated non-memory CPI.
MIN_CPI_EXEC = 0.02
#: LLC miss-rate sensitivity to capacity: MPKI ~ C^-alpha (sqrt(2)-rule-ish).
ALPHA_LLC = 0.25
#: MPKI multiplier when the working set fits in the LLC.
LLC_FIT_FACTOR = 0.05
#: Working sets at/above this are treated as streaming (compulsory misses):
#: their MPKI does not react to LLC capacity.
STREAMING_WS_MB = 1024.0
#: Fixed-point iterations / damping.
FP_ITERS = 120
FP_DAMP = 0.5


@dataclasses.dataclass(frozen=True)
class MemSystem:
    """One server memory-system design point (Table 2, scaled to 12 cores)."""

    name: str
    dram_channels: int          # DDR5 channels behind all interfaces
    links: int                  # CXL links (0 => direct DDR attach)
    link_rd_gbps: float         # per-link read goodput
    link_wr_gbps: float         # per-link write goodput
    iface_lat_ns: float         # CXL end-to-end latency premium
    llc_mb_per_core: float
    rel_area: float = 1.0       # die area relative to the DDR baseline
    rel_pins: float = 1.0       # memory-interface pins relative to baseline

    @property
    def is_cxl(self) -> bool:
        return self.links > 0


def _bw_efficiency(wb):
    """Sustained/peak DDR efficiency: 70-90% depending on R/W turnaround."""
    write_share = wb / (1.0 + wb)
    return 0.92 - 0.18 * write_share


@dataclasses.dataclass
class ModelResult:
    """Per-workload outputs of one (memory system x utilization) evaluation."""

    ipc: np.ndarray
    cpi: np.ndarray
    latency_ns: np.ndarray       # mean LLC-miss latency
    queue_ns: np.ndarray         # queue-wait component (DRAM + link)
    iface_ns: np.ndarray         # CXL interface component
    service_ns: np.ndarray       # DRAM service component
    sigma_ns: np.ndarray         # latency stdev
    rho: np.ndarray              # DRAM-side bandwidth utilization
    read_gbps: np.ndarray
    write_gbps: np.ndarray

    def speedup_vs(self, base: "ModelResult") -> np.ndarray:
        return self.ipc / base.ipc


def _mpki_eff(wl: WorkloadArrays, sys: MemSystem, n_active: int):
    scale = (2.0 / sys.llc_mb_per_core) ** ALPHA_LLC
    streaming = wl.ws_mb >= STREAMING_WS_MB
    mpki = wl.mpki * jnp.where(streaming, 1.0, scale)
    llc_total = sys.llc_mb_per_core * hw.SIM_CORES
    fits = (wl.ws_mb * n_active) <= llc_total
    return jnp.where(fits, wl.mpki * LLC_FIT_FACTOR, mpki)


def _latency_terms(wl, sys: MemSystem, read_gbps, write_gbps, n_active,
                   iface_lat_ns):
    """Mean latency components + stdev at the given traffic level."""
    eff = _bw_efficiency(wl.wb)
    ch_bw = hw.DDR5_CH_BW_GBPS * eff
    rho = (read_gbps + write_gbps) / (sys.dram_channels * ch_bw)
    outstanding = n_active * MAX_MLP / sys.dram_channels
    w_dram = queueing.effective_queue_wait_ns(
        rho, kappa=wl.kappa, eta=wl.eta,
        outstanding_per_channel=outstanding, channel_bw_gbps=ch_bw)
    if sys.is_cxl:
        rho_rx = read_gbps / (sys.links * sys.link_rd_gbps)
        svc_rx = hw.CACHE_LINE_B / sys.link_rd_gbps
        w_link = queueing.link_queue_wait_ns(rho_rx, svc_rx, wl.kappa)
    else:
        w_link = jnp.zeros_like(rho)
    queue = w_dram + w_link
    sigma = queueing.stdev_latency_ns(queue)
    latency = hw.DRAM_SERVICE_NS + queue + iface_lat_ns
    return latency, queue, sigma, rho


def _cpi_mem(wl, mpki_eff, latency, sigma, mlp):
    l_eff_cyc = (latency + wl.gamma * sigma) * hw.CORE_CLK_GHZ
    return (mpki_eff / 1000.0) * l_eff_cyc / mlp


def _cpi_bw(wl, mpki_eff, sys: MemSystem, n_active):
    """Bandwidth-bound CPI floor for every interface in the system."""
    bytes_rd = (mpki_eff / 1000.0) * hw.CACHE_LINE_B          # per inst
    bytes_wr = bytes_rd * wl.wb
    eff = _bw_efficiency(wl.wb)
    cpi = (bytes_rd + bytes_wr) * n_active * hw.CORE_CLK_GHZ / \
        (sys.dram_channels * hw.DDR5_CH_BW_GBPS * eff)
    if sys.is_cxl:
        cpi = jnp.maximum(cpi, bytes_rd * n_active * hw.CORE_CLK_GHZ /
                          (sys.links * sys.link_rd_gbps))
        cpi = jnp.maximum(cpi, bytes_wr * n_active * hw.CORE_CLK_GHZ /
                          (sys.links * sys.link_wr_gbps))
    return cpi


def _traffic(wl, ipc, mpki_eff, n_active):
    read = ipc * hw.CORE_CLK_GHZ * n_active * (mpki_eff / 1000.0) * \
        hw.CACHE_LINE_B  # GB/s
    return read, read * wl.wb


def _mlp_eff(wl, mlp_cal, rho):
    """Load-adaptive effective MLP.

    Hardware prefetchers run further ahead when bandwidth is free and
    throttle under contention, so the effective overlap grows as utilization
    drops: mlp_eff = mlp_cal * (1 + pf_boost * (1 - rho)), within the
    architectural [1, MAX_MLP].
    """
    return jnp.clip(mlp_cal * (1.0 + wl.pf_boost * (1.0 - _rho01(rho))),
                    1.0, MAX_MLP)


def _rho01(rho):
    return jnp.clip(rho, 0.0, 1.0)


def calibrate(wl: WorkloadArrays, baseline: MemSystem,
              n_active=hw.SIM_CORES):
    """Per-workload (cpi_exec, mlp_cal) reproducing Table 4 on the baseline.

    Given exec_frac, the memory-CPI budget at the table operating point is
    (1 - exec_frac)/IPC; the effective MLP at the *baseline* utilization is
    whatever makes the latency model meet that budget, clamped to the
    architectural [1, MAX_MLP]; mlp_cal back-solves the load-adaptive form.
    """
    mpki_eff = _mpki_eff(wl, baseline, n_active)
    read, write = _traffic(wl, wl.ipc, mpki_eff, n_active)
    latency, _, sigma, rho_base = _latency_terms(
        wl, baseline, read, write, n_active, baseline.iface_lat_ns)
    l_eff_cyc = (latency + wl.gamma * sigma) * hw.CORE_CLK_GHZ
    budget = (1.0 - wl.exec_frac) / wl.ipc
    mlp_raw = (mpki_eff / 1000.0) * l_eff_cyc / jnp.maximum(budget, 1e-9)
    mlp_base = jnp.clip(mlp_raw, 1.0, MAX_MLP)
    mlp_cal = mlp_base / (1.0 + wl.pf_boost * (1.0 - _rho01(rho_base)))
    cpi_exec = jnp.maximum(
        1.0 / wl.ipc - (mpki_eff / 1000.0) * l_eff_cyc / mlp_base,
        MIN_CPI_EXEC)
    return cpi_exec, mlp_cal


@functools.partial(jax.jit, static_argnames=("sys", "n_active"))
def _solve_jit(wl_arrays, cpi_exec, mlp, sys: MemSystem,
               n_active: int, iface_lat_ns):
    wl = wl_arrays
    mpki_eff = _mpki_eff(wl, sys, n_active)
    cpi_bw = _cpi_bw(wl, mpki_eff, sys, n_active)

    def body(_, ipc):
        read, write = _traffic(wl, ipc, mpki_eff, n_active)
        latency, _, sigma, rho = _latency_terms(
            wl, sys, read, write, n_active, iface_lat_ns)
        mlp_eff = _mlp_eff(wl, mlp, rho)
        cpi = jnp.maximum(
            cpi_exec + _cpi_mem(wl, mpki_eff, latency, sigma, mlp_eff),
            cpi_bw)
        return (1 - FP_DAMP) * ipc + FP_DAMP / cpi

    ipc = jax.lax.fori_loop(0, FP_ITERS, body, wl.ipc)
    read, write = _traffic(wl, ipc, mpki_eff, n_active)
    latency, queue, sigma, rho = _latency_terms(
        wl, sys, read, write, n_active, iface_lat_ns)
    return ipc, latency, queue, sigma, rho, read, write


def solve(sys: MemSystem, *, baseline: MemSystem | None = None,
          n_active: int = hw.SIM_CORES, iface_lat_ns: float | None = None,
          workloads=WORKLOADS) -> ModelResult:
    """Evaluate all workloads on ``sys`` (calibrated against ``baseline``)."""
    wl = _to_jnp(as_arrays(workloads))
    base = baseline or DDR_BASELINE
    cpi_exec, mlp = calibrate(wl, base, n_active=n_active)
    lat_premium = sys.iface_lat_ns if iface_lat_ns is None else iface_lat_ns
    ipc, latency, queue, sigma, rho, read, write = _solve_jit(
        wl, cpi_exec, mlp, sys, int(n_active), float(lat_premium))
    to_np = lambda x: np.asarray(x, np.float64)
    return ModelResult(
        ipc=to_np(ipc), cpi=to_np(1.0 / ipc), latency_ns=to_np(latency),
        queue_ns=to_np(queue),
        iface_ns=np.full(len(wl.ipc), float(lat_premium)),
        service_ns=np.full(len(wl.ipc), hw.DRAM_SERVICE_NS),
        sigma_ns=to_np(sigma), rho=to_np(rho), read_gbps=to_np(read),
        write_gbps=to_np(write))


def _to_jnp(wl: WorkloadArrays) -> WorkloadArrays:
    j = lambda x: jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64
                              else jnp.float32)
    return WorkloadArrays(
        name=wl.name, ipc=j(wl.ipc), mpki=j(wl.mpki), wb=j(wl.wb),
        kappa=j(wl.kappa), eta=j(wl.eta), exec_frac=j(wl.exec_frac),
        gamma=j(wl.gamma), pf_boost=j(wl.pf_boost), ws_mb=j(wl.ws_mb))


# ---------------------------------------------------------------------------
# Design points (Table 2, scaled to the simulated 12-core slice, Table 3).
# ---------------------------------------------------------------------------

DDR_BASELINE = MemSystem(
    "ddr-baseline", dram_channels=1, links=0, link_rd_gbps=0.0,
    link_wr_gbps=0.0, iface_lat_ns=0.0, llc_mb_per_core=2.0,
    rel_area=1.0, rel_pins=1.0)

COAXIAL_2X = MemSystem(
    "coaxial-2x", dram_channels=2, links=2, link_rd_gbps=hw.CXL_X8_RD_GBPS,
    link_wr_gbps=hw.CXL_X8_WR_GBPS, iface_lat_ns=hw.CXL_LAT_NS,
    llc_mb_per_core=2.0, rel_area=1.01, rel_pins=24 * 32 / (12 * 160))

COAXIAL_4X = MemSystem(
    "coaxial-4x", dram_channels=4, links=4, link_rd_gbps=hw.CXL_X8_RD_GBPS,
    link_wr_gbps=hw.CXL_X8_WR_GBPS, iface_lat_ns=hw.CXL_LAT_NS,
    llc_mb_per_core=1.0, rel_area=1.01, rel_pins=48 * 32 / (12 * 160))

COAXIAL_5X = MemSystem(
    "coaxial-5x", dram_channels=5, links=5, link_rd_gbps=hw.CXL_X8_RD_GBPS,
    link_wr_gbps=hw.CXL_X8_WR_GBPS, iface_lat_ns=hw.CXL_LAT_NS,
    llc_mb_per_core=2.0, rel_area=1.17, rel_pins=1.0)

#: 4 CXL-asym links, each feeding TWO DDR controllers on the type-3 device
#: (§4.3): 8 DRAM channels' worth of banks behind 4 asymmetric links.
COAXIAL_ASYM = MemSystem(
    "coaxial-asym", dram_channels=8, links=4,
    link_rd_gbps=hw.CXL_ASYM_RD_GBPS, link_wr_gbps=hw.CXL_ASYM_WR_GBPS,
    iface_lat_ns=hw.CXL_LAT_NS, llc_mb_per_core=1.0,
    rel_area=1.01, rel_pins=48 * 32 / (12 * 160))

DESIGNS = (DDR_BASELINE, COAXIAL_2X, COAXIAL_4X, COAXIAL_5X, COAXIAL_ASYM)


# ---------------------------------------------------------------------------
# Fig 3: variance-only experiment (bimodal latency, constant 150ns average).
# ---------------------------------------------------------------------------

#: The five Fig-3 workloads, in decreasing memory-bandwidth intensity.
FIG3_WORKLOADS = ("pagerank", "components", "masstree", "omnetpp", "raytrace")
FIG3_MEAN_NS = 150.0
#: (low, high) bimodal points with 4:1 ratio -> stdev 100/150/200 ns.
FIG3_DISTS = ((100.0, 350.0), (75.0, 450.0), (50.0, 550.0))


def variance_experiment(workload_names=FIG3_WORKLOADS, dists=FIG3_DISTS):
    """Relative performance under bimodal latency vs fixed 150ns (Fig 3)."""
    wls = [w for n in workload_names for w in WORKLOADS if w.name == n]
    wl = _to_jnp(as_arrays(wls))
    cpi_exec, mlp_cal = calibrate(wl, DDR_BASELINE)
    # The toy system of Fig 3 is unloaded (fixed-latency memory).
    mlp = _mlp_eff(wl, mlp_cal, jnp.zeros_like(wl.ipc))

    def perf(sigma_ns):
        l_eff = (FIG3_MEAN_NS + wl.gamma * sigma_ns) * hw.CORE_CLK_GHZ
        cpi = cpi_exec + (wl.mpki / 1000.0) * l_eff / mlp
        l_fix = FIG3_MEAN_NS * hw.CORE_CLK_GHZ
        cpi_fix = cpi_exec + (wl.mpki / 1000.0) * l_fix / mlp
        return np.asarray(cpi_fix / cpi, np.float64)

    out = {}
    for lo, hi in dists:
        sigma = float(np.sqrt(0.8 * (FIG3_MEAN_NS - lo) ** 2 +
                              0.2 * (hi - FIG3_MEAN_NS) ** 2))
        rel = perf(sigma)
        out[(lo, hi)] = dict(
            stdev_ns=sigma,
            per_workload=dict(zip(wl.name, rel.tolist())),
            geomean=float(np.exp(np.mean(np.log(rel)))))
    return out


def geomean(x) -> float:
    x = np.asarray(x, np.float64)
    return float(np.exp(np.mean(np.log(x))))
