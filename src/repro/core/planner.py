"""Queue-aware channelized-sharding planner: COAXIAL's insight on TPU.

The paper's transferable claim is *not* about DDR pins; it is:

    In a loaded memory system, effective access time = service + queuing;
    queuing dominates; spreading traffic over N channels at a fixed
    interface-latency premium reduces both the mean and the variance of
    access time -- so trade unloaded latency for channel parallelism
    whenever the system is loaded.

On TPU the analogous trade is between *one chip's HBM* (the local "DDR
channel") and *N chips' HBM reached over ICI* (the "CXL channels": more
aggregate bandwidth, plus a fixed per-hop latency premium).  The planner
evaluates that trade for the bandwidth-hot state of an ML system:

  * :func:`plan_decode_kv` -- shard a KV cache over n sequence shards; each
    chip streams 1/n of the KV bytes from local HBM and a combine
    (flash-decode partial-softmax merge) pays the latency premium;
  * :func:`plan_param_channels` -- FSDP parameter all-gather vs keeping
    weights replicated (training-side channelization);
  * :func:`asym_schedule` -- split duplex ICI budget between read-like
    (all-gather) and write-like (reduce-scatter) traffic according to the
    step's R:W byte ratio, the §4.3 CXL-asym idea restated for ICI.

Step-time composition uses the same queueing form as the reproduction: when
several DMA streams share one HBM, the effective memory time is inflated by
an M/G/1-style contention factor -- the TPU version of Fig 2a.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.hw import TPU_V5E, TpuSpec

#: Burstiness of DMA traffic within a step (weights/activations/KV phases
#: overlap imperfectly); mild compared to CPU-world kappa.
DMA_KAPPA = 1.15


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Roofline-style cost of one step under a candidate sharding."""

    name: str
    compute_s: float
    hbm_s: float
    ici_s: float
    hop_lat_s: float

    @property
    def total_s(self) -> float:
        """Bound on step time: overlappable terms take their max; the hop
        latency is serial (it gates the combine)."""
        return max(self.compute_s, self.hbm_s, self.ici_s) + self.hop_lat_s

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.hbm_s,
                 "collective": self.ici_s + self.hop_lat_s}
        return max(terms, key=terms.get)


def contention_factor(rho: float, kappa: float = DMA_KAPPA) -> float:
    """M/G/1-style inflation of memory time when the HBM channel is loaded.

    Same shape as the reproduction's queue model: at utilization rho the
    effective service time is inflated by 1 + kappa^2 * rho / (2*(1-rho)).
    """
    rho = min(max(rho, 0.0), 0.97)
    return 1.0 + kappa**2 * rho / (2.0 * (1.0 - rho))


def effective_hbm_time(bytes_per_chip: float, spec: TpuSpec = TPU_V5E,
                       background_rho: float = 0.0) -> float:
    """Seconds to stream ``bytes_per_chip`` from HBM under contention."""
    base = bytes_per_chip / spec.hbm_bw
    return base * contention_factor(background_rho)


# ---------------------------------------------------------------------------
# Channelized KV-cache decode (the paper's §4 trade, on ICI).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodePlan:
    n_channels: int              # sequence shards of the KV cache
    cost: StepCost
    baseline: StepCost           # n = 1 (all KV in one chip's HBM)

    @property
    def speedup(self) -> float:
        return self.baseline.total_s / self.cost.total_s


def decode_step_cost(*, kv_bytes: float, qkv_flops: float,
                     combine_bytes: float, n: int,
                     spec: TpuSpec = TPU_V5E,
                     background_rho: float = 0.0) -> StepCost:
    """Cost of one decode step with the KV cache spread over n chips.

    kv_bytes      total KV bytes read per step (all layers);
    qkv_flops     attention flops per step (scales 1/n per chip);
    combine_bytes bytes exchanged to merge partial attention outputs
                  (per merge stage; log2(n) tree stages).
    """
    stages = math.ceil(math.log2(n)) if n > 1 else 0
    hbm = effective_hbm_time(kv_bytes / n, spec, background_rho)
    ici = stages * combine_bytes / spec.ici_bw if n > 1 else 0.0
    hop = stages * spec.ici_hop_s
    return StepCost(name=f"kv-channels={n}", compute_s=qkv_flops / n /
                    spec.peak_flops, hbm_s=hbm, ici_s=ici, hop_lat_s=hop)


def plan_decode_kv(*, kv_bytes: float, qkv_flops: float,
                   combine_bytes: float, max_channels: int = 16,
                   spec: TpuSpec = TPU_V5E,
                   background_rho: float = 0.0) -> DecodePlan:
    """Pick the KV channel count minimizing decode step time.

    This is COAXIAL's Fig 2a argument verbatim: more channels cut the
    memory term ~1/n while adding a fixed per-stage latency premium; the
    optimum moves to larger n exactly when the memory system is loaded
    (large kv_bytes or high background utilization).
    """
    candidates = [1]
    while candidates[-1] * 2 <= max_channels:
        candidates.append(candidates[-1] * 2)
    costs = [decode_step_cost(kv_bytes=kv_bytes, qkv_flops=qkv_flops,
                              combine_bytes=combine_bytes, n=n, spec=spec,
                              background_rho=background_rho)
             for n in candidates]
    best = min(range(len(costs)), key=lambda i: costs[i].total_s)
    return DecodePlan(n_channels=candidates[best], cost=costs[best],
                      baseline=costs[0])


# ---------------------------------------------------------------------------
# Training-side: FSDP parameter channels.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamPlan:
    shards: int
    cost: StepCost
    baseline: StepCost

    @property
    def speedup(self) -> float:
        return self.baseline.total_s / self.cost.total_s


def plan_param_channels(*, param_bytes: float, step_flops_per_chip: float,
                        layers: int, shard_candidates=(1, 2, 4, 8, 16),
                        state_bytes_factor: float = 7.0,
                        hbm_budget_bytes: float | None = None,
                        spec: TpuSpec = TPU_V5E) -> ParamPlan:
    """Replicated weights (1 channel) vs FSDP-sharded over n chips.

    Replicated: every chip streams the full param_bytes from local HBM each
    step.  Sharded over n: each chip stores 1/n, and an all-gather streams
    the same bytes over ICI (overlapped per layer).

    Unlike the KV-cache case, *every* chip consumes every parameter, so
    channelizing cannot multiply the usable bandwidth -- ICI (~200 GB/s) is
    slower than local HBM (819 GB/s) and replication wins on pure time.
    FSDP is a CAPACITY play: a candidate is infeasible when its resident
    bytes (params + optimizer states, ``state_bytes_factor`` x params in
    fp32 master/mu/nu terms) exceed the HBM budget.  The planner encodes
    both sides of the trade; the COAXIAL bandwidth argument applies to
    state that *stays local after sharding* (KV, experts), not to
    broadcast-consumed state.
    """
    budget = hbm_budget_bytes if hbm_budget_bytes is not None \
        else 0.8 * spec.hbm_bytes
    costs = []
    feasible = []
    for n in shard_candidates:
        resident = param_bytes * (1.0 + state_bytes_factor) / n
        if n == 1:
            hbm = effective_hbm_time(param_bytes, spec)
            c = StepCost("replicated", step_flops_per_chip /
                         spec.peak_flops, hbm, 0.0, 0.0)
        else:
            hbm = effective_hbm_time(param_bytes / n, spec)
            ici = param_bytes * (n - 1) / n / spec.ici_bw
            hop = layers * spec.ici_hop_s
            c = StepCost(f"fsdp={n}", step_flops_per_chip /
                         spec.peak_flops, hbm, ici, hop)
        costs.append(c)
        feasible.append(resident <= budget)
    idx = [i for i in range(len(costs)) if feasible[i]]
    if not idx:
        idx = [len(costs) - 1]      # largest sharding is the last resort
    best = min(idx, key=lambda i: costs[i].total_s)
    return ParamPlan(shards=shard_candidates[best], cost=costs[best],
                     baseline=costs[0])


# ---------------------------------------------------------------------------
# Asymmetric collective schedule (CXL-asym, §4.3, restated for duplex ICI).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AsymSchedule:
    read_fraction: float        # share of overlap window given to all-gather
    write_fraction: float       # share given to reduce-scatter
    read_bytes: float
    write_bytes: float

    @property
    def rw_ratio(self) -> float:
        return self.read_bytes / max(self.write_bytes, 1.0)


def asym_schedule(read_bytes: float, write_bytes: float) -> AsymSchedule:
    """Split the duplex-ICI overlap budget by the step's R:W byte ratio.

    PCIe mandates 1:1 RX/TX lanes; the paper shows memory traffic is 2:1 to
    3:1 R:W and gains 15% from asymmetric provisioning.  ICI links are
    duplex, but the *scheduling window* (how early the next layer's
    parameter all-gather is prefetched vs how late the gradient
    reduce-scatter is drained) is the software analogue: we provision the
    overlap budget proportionally to demand instead of 1:1.
    """
    total = read_bytes + write_bytes
    if total <= 0:
        return AsymSchedule(0.5, 0.5, read_bytes, write_bytes)
    rf = read_bytes / total
    return AsymSchedule(read_fraction=rf, write_fraction=1.0 - rf,
                        read_bytes=read_bytes, write_bytes=write_bytes)


# ---------------------------------------------------------------------------
# Roofline terms (shared by launch/dryrun.py and benchmarks/roofline.py).
# ---------------------------------------------------------------------------

def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, chips: int,
                   spec: TpuSpec = TPU_V5E) -> dict:
    """The three §Roofline terms, in seconds (whole-step, per the spec)."""
    compute_s = hlo_flops / (chips * spec.peak_flops)
    memory_s = hlo_bytes / (chips * spec.hbm_bw)
    collective_s = collective_bytes / (chips * spec.ici_bw_per_link)
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    terms["bound_s"] = max(compute_s, memory_s, collective_s)
    return terms
