"""Mechanistic discrete-event simulator of a channelized memory system.

This is the DRAMsim-ish half of the reproduction: where ``queueing.py`` is a
*calibrated closed form*, memsim is an *independent mechanism* -- a
time-stepped (1 ns) simulation of request arrivals, FIFO bus queues, DRAM
service and CXL interface delays -- implemented as one ``jax.lax.scan`` and
``vmap``-ed over an arbitrary batch of channel configurations.  It produces
full latency *distributions* (mean / p50 / p90 / p99 / stdev / CDF), which
back Fig 2a's load-latency curve and Fig 6b's CDF comparison.

Model per channel:
  * arrivals: two-state MMPP (burst/idle) Bernoulli process per ns; the
    burst-state rate is ``kappa`` times the average, idle fills the rest;
  * service: the channel serializes one 64B line per ``t_xfer`` ns *on
    average* (38.4 GB/s -> 1.67 ns), but the effective per-request service
    is heavy-tailed: with small probability the controller blocks for a long
    time (refresh, tFAW windows, read/write turnaround trains).  The
    two-point service distribution is calibrated so the M/G/1 mean wait
    lambda*E[S^2] / (2*(1-rho)) reproduces the paper's Fig 2a anchor
    W(0.5) ~= 80 ns while keeping E[S] = t_xfer (so rho keeps its meaning
    as bus utilization);
  * DRAM access: base latency plus uniform bank/row-state jitter;
  * CXL: a fixed interface premium plus the link-traversal time.

All randomness is threefry-derived from an explicit seed: runs are exactly
reproducible.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw

#: Histogram binning for latency distributions.
BIN_NS = 4.0
N_BINS = 640          # covers 0 .. 2560 ns

#: DRAM access latency jitter (bank/row-buffer state), uniform half-width.
SERVICE_JITTER_NS = 14.0
#: Fraction of time the MMPP spends in the burst state.
BURST_DUTY = 0.3
#: Mean sojourn time in each MMPP state (ns).
BURST_SOJOURN_NS = 2000.0
#: Heavy-tail service events: probability and duration (ns).  With
#: E[S] = 1.667 ns these give E[S^2] ~= 265 ns^2, hence an M/G/1 wait of
#: ~80 ns at 50% utilization -- the paper's calibration anchor.
STALL_PROB = 0.0097
STALL_NS = 165.0


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """One simulated memory channel configuration."""

    rho: float                  # target bus utilization, 0..~0.95
    kappa: float = 1.0          # burst peak-to-mean arrival ratio
    t_xfer_ns: float = hw.CACHE_LINE_B / hw.DDR5_CH_BW_GBPS
    service_ns: float = hw.DRAM_SERVICE_NS - 2.0   # pipelined access part
    cxl_lat_ns: float = 0.0     # CXL interface premium (0 => direct DDR)


def _config_arrays(configs):
    f = lambda a: jnp.asarray([getattr(c, a) for c in configs], jnp.float32)
    return (f("rho"), f("kappa"), f("t_xfer_ns"), f("service_ns"),
            f("cxl_lat_ns"))


@functools.partial(jax.jit, static_argnames=("steps",))
def _simulate(rho, kappa, t_xfer, service, cxl_lat, seed, steps: int):
    """Run ``steps`` ns for a batch of channels; return latency histograms."""
    n = rho.shape[0]
    rate_avg = rho / t_xfer                      # arrivals per ns
    rate_hi = jnp.minimum(kappa * rate_avg, 0.98)
    # Rate in the idle state so the duty-weighted mean matches rate_avg.
    rate_lo = jnp.maximum(
        (rate_avg - BURST_DUTY * rate_hi) / (1.0 - BURST_DUTY), 0.0)
    p_leave = 1.0 / BURST_SOJOURN_NS             # state-switch prob per ns
    # Duty-correct entry prob: stationary P(burst) = BURST_DUTY.
    p_enter = p_leave * BURST_DUTY / (1.0 - BURST_DUTY)

    # Two-point effective service distribution with mean exactly t_xfer.
    s_small = (t_xfer - STALL_PROB * STALL_NS) / (1.0 - STALL_PROB)
    s_small = jnp.maximum(s_small, 0.05)

    def step(carry, key):
        backlog, in_burst, hist = carry
        k1, k2, k3, k4 = jax.random.split(key, 4)
        switch_u = jax.random.uniform(k1, (n,))
        in_burst = jnp.where(
            in_burst > 0.5,
            jnp.where(switch_u < p_leave, 0.0, 1.0),
            jnp.where(switch_u < p_enter, 1.0, 0.0))
        rate = jnp.where(in_burst > 0.5, rate_hi, rate_lo)
        arrive = (jax.random.uniform(k2, (n,)) < rate).astype(jnp.float32)
        jitter = jax.random.uniform(
            k3, (n,), minval=-SERVICE_JITTER_NS, maxval=SERVICE_JITTER_NS)
        latency = backlog + service + 2.0 + jitter + cxl_lat
        bin_idx = jnp.clip((latency / BIN_NS).astype(jnp.int32), 0, N_BINS - 1)
        hist = hist.at[jnp.arange(n), bin_idx].add(arrive)
        stall = jax.random.uniform(k4, (n,)) < STALL_PROB
        svc = jnp.where(stall, STALL_NS, s_small)
        backlog = jnp.maximum(backlog + arrive * svc - 1.0, 0.0)
        return (backlog, in_burst, hist), None

    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    init = (jnp.zeros(n), jnp.ones(n), jnp.zeros((n, N_BINS)))
    (backlog, _, hist), _ = jax.lax.scan(step, init, keys)
    return hist


@dataclasses.dataclass
class LatencyStats:
    mean_ns: np.ndarray
    stdev_ns: np.ndarray
    p50_ns: np.ndarray
    p90_ns: np.ndarray
    p99_ns: np.ndarray
    hist: np.ndarray            # (configs, N_BINS) counts
    bin_ns: float = BIN_NS

    def cdf(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(latency_ns, cdf) arrays for config ``i`` (Fig 6b)."""
        h = self.hist[i]
        c = np.cumsum(h) / max(h.sum(), 1.0)
        x = (np.arange(N_BINS) + 0.5) * self.bin_ns
        return x, c


def simulate(configs, steps: int = 200_000, seed: int = 0) -> LatencyStats:
    """Simulate a batch of :class:`ChannelConfig` and return stats."""
    arrays = _config_arrays(configs)
    hist = np.asarray(_simulate(*arrays, seed, steps), np.float64)
    centers = (np.arange(N_BINS) + 0.5) * BIN_NS
    total = hist.sum(axis=1, keepdims=True)
    total = np.maximum(total, 1.0)
    p = hist / total
    mean = (p * centers).sum(axis=1)
    var = (p * (centers[None, :] - mean[:, None]) ** 2).sum(axis=1)
    cum = np.cumsum(p, axis=1)

    def quantile(q):
        idx = np.argmax(cum >= q, axis=1)
        return (idx + 0.5) * BIN_NS

    return LatencyStats(
        mean_ns=mean, stdev_ns=np.sqrt(var), p50_ns=quantile(0.5),
        p90_ns=quantile(0.9), p99_ns=quantile(0.99), hist=hist)


def load_latency_curve(rhos=None, kappa: float = 1.0, cxl_lat_ns: float = 0.0,
                       steps: int = 200_000, seed: int = 0) -> dict:
    """Fig 2a: mean/p90 latency vs bus utilization for one channel type."""
    if rhos is None:
        rhos = np.linspace(0.05, 0.95, 19)
    configs = [ChannelConfig(rho=float(r), kappa=kappa,
                             cxl_lat_ns=cxl_lat_ns) for r in rhos]
    stats = simulate(configs, steps=steps, seed=seed)
    return dict(rho=np.asarray(rhos), mean_ns=stats.mean_ns,
                p90_ns=stats.p90_ns, p99_ns=stats.p99_ns,
                stdev_ns=stats.stdev_ns)
