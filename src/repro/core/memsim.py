"""Mechanistic discrete-event simulator of a channelized memory system.

This is the DRAMsim-ish half of the reproduction: where ``queueing.py`` is a
*calibrated closed form*, memsim is an *independent mechanism* -- a
simulation of request arrivals, FIFO bus queues, DRAM service and CXL
interface delays, implemented as jitted ``jax.lax.scan`` loops over an
arbitrary batch of channel configurations.  It produces full latency
*distributions* (mean / p50 / p90 / p99 / stdev / CDF), which back Fig 2a's
load-latency curve and Fig 6b's CDF comparison.

TWO ENGINES share one mechanism (same arrival, service and admission laws):

  * ``engine="timestep"`` (the reference): a 1-ns time-stepped scan.  Per
    nanosecond it advances the two-state MMPP, flips a Bernoulli arrival
    coin, and drains the backlog by 1 ns.  Each emission chunk's five
    uniforms per step come from ONE threefry stream per lane, keyed by
    the logical lane index (``fold_in(chunk_key, lane)``) and generated
    up front outside the scan -- the lane-keyed stream contract (below)
    that makes every lane's draws independent of batch width, padding
    and device count.
  * ``engine="event"`` (the fast engine): one scan iteration per
    **request** -- the Lindley recursion ``W_{k+1} = max(W_k + S_k - A_k,
    0)`` over per-request inter-arrival gaps and service draws, roughly
    ``t_xfer / rho`` fewer iterations than the per-nanosecond loop and no
    idle steps at low utilization.  Inter-arrival gaps are sampled from
    the SAME two-state MMPP, with in-gap phase switching handled exactly:
    the modulating chain is simulated once per call (alternating
    exponential sojourns), and arrival times come from inverting the
    piecewise-linear cumulative intensity at unit-exponential increments
    (the Cox-process construction -- the vectorized equivalent of
    phase-type gap sampling).  Service uses the same two-slope
    truncated-Pareto law, the closed-loop ``outstanding`` bound gates the
    same backlog quantity, and per-request latencies are emitted as scan
    outputs and histogrammed once post-scan.  The uniform DRAM jitter is
    additive observation noise (it never feeds the queue), so the event
    engine convolves its exact distribution into the histogram instead of
    sampling it -- one fewer uniform per request and strictly lower
    variance.  The engines agree statistically, not bitwise;
    ``coaxial.crosscheck_engines`` gates mean/p90 agreement at the
    closed-form rho anchors.

Model per channel (both engines):
  * arrivals: two-state MMPP (burst/idle); the burst-state rate is
    ``kappa`` times the average, idle fills the rest;
  * closed loop: a finite in-flight population ``outstanding`` (MSHR/ROB
    bound per channel) gates ADMISSION -- while the backlog exceeds
    ``outstanding * t_xfer_ns`` of queued work the cores' miss buffers
    are full, so no new request enters the queue (the core stalls
    instead).  The default is unbounded (``inf``), the open loop;
    ``core/queuelut.py`` sweeps this axis to build the closed-loop wait
    surface ``cpu_model`` consumes;
  * service: the channel serializes one 64B line per ``t_xfer`` ns *on
    average* (38.4 GB/s -> 1.67 ns), but with small probability the
    controller blocks for a two-slope power-law (truncated-Pareto)
    duration spanning the bank-conflict scale through tFAW windows up to
    refresh.  The blocking-size law is what the paper's own Fig-2a closed
    forms demand: inverting mean and p90 through Pollaczek-Khinchine
    yields a service-excess tail P(S > w) ~ w**-1.8.  Calibration keeps
    E[S] = t_xfer (so rho keeps its meaning as bus utilization) and
    matches the M/G/1 mean-wait anchor W(0.5) ~= 80 ns
    (``coaxial.validate_calibration`` checks mean AND p90 per anchor,
    for either engine);
  * DRAM access: base latency plus uniform bank/row-state jitter;
  * CXL: a fixed interface premium plus the link-traversal time;
  * harvesting (arXiv 2511.12349): for a fraction ``harvest_duty`` of
    the time the channel borrows an idle CXL I/O link and transfers at
    ``base + harvest_bw_gbps``.  Lent/reclaimed windows alternate
    through a second two-state modulating chain sharing the MMPP's 1-ns
    lattice (mean window ``harvest_sojourn_ns``); a request admitted
    during a lent window enqueues its work scaled by ``base_bw /
    (base_bw + harvest_bw)``.  The chain's randomness comes from a
    SEPARATE salted stream per lane, so ``harvest_duty = 0`` (the
    default) is bit-identical to the unharvested simulator on both
    engines -- the arrival/service streams never shift.

Every calibration constant is also a per-channel *field* of
:class:`ChannelConfig` / :class:`ChannelArrays` (the module-level constants
are just the defaults), so any of them can be a named sweep axis:
``sweepspec.distribution_spec(rho=..., kappa=..., stall_ns=...)`` lowers to
ONE jitted simulation over the flattened cell batch, with NaN-masked
overrides applied branch-free in-trace exactly like ``cpu_model``'s design
overrides.

The first ``warmup`` ns of simulated time (default ``steps // 10``) are
excluded from the histogram: the simulation starts with an empty queue, so
without a warmup window the cold-start transient biases means and low-rho
quantiles down.

Budgets are engine-neutral: ``steps`` is the simulated-time budget in ns.
The event engine converts it to a request budget with
:func:`events_for_steps` (``EVENTS_PER_NS`` requests per ns -- the arrival
rate of the rho = 0.5 reference channel, the repo's calibration anchor),
so one knob -- and one ``REPRO_DES_STEPS`` cap -- throttles both engines
coherently.

All randomness is threefry-derived from an explicit seed, with one stream
per LANE keyed by the logical lane index: ``fold_in(chunk_key, lane)``
where the chunk keys are split from the seed.  Runs are exactly
reproducible per engine (the two engines draw different streams), and --
because no draw ever depends on the batch width or the device layout --
a lane simulates identically whether it runs alone, inside a wider batch
(at equal chunk schedule), on one device or on many.

DEVICE PARALLELISM: lanes are independent chains, so both engines
optionally shard the lane axis across host devices via
:mod:`repro.core.shardsim` (``devices=`` on every entry point, or the
``REPRO_DES_DEVICES`` env knob; ``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` splits one CPU into N
devices).  The batch is NaN-padded to a multiple of the device count and
the SAME chunk kernels run per shard; the lane-keyed streams plus
global-lane histogram indices make the sharded result bit-identical to
the unsharded one -- ``devices`` changes wall-clock, never a single
count.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw, shardsim

#: Histogram binning for latency distributions.
BIN_NS = 4.0
N_BINS = 1024         # covers 0 .. 4096 ns

#: DRAM access latency jitter (bank/row-buffer state), uniform half-width.
SERVICE_JITTER_NS = 13.5
#: Fraction of time the MMPP spends in the burst state.
BURST_DUTY = 0.3
#: Mean sojourn time in each MMPP state (ns).
BURST_SOJOURN_NS = 2000.0
#: Controller blocking episodes (the heavy service tail): with probability
#: ``STALL_PROB`` per request the controller blocks for a two-slope
#: power-law (Pareto) duration -- slope ``STALL_ALPHA`` from the
#: bank-conflict scale (``STALL_NS``) to the tFAW / turnaround-train
#: scale (``STALL_BREAK_NS``), rolling off at slope ``STALL_ALPHA2`` out
#: to the refresh/tRFC scale, capped at ``STALL_MAX_NS``.  The power law
#: is not a modeling whim: inverting the paper's two Fig-2a closed forms
#: (mean 40 + 80x, p90 40 + 148*x**1.232, x = rho/(1-rho)) through the
#: M/G/1 Pollaczek-Khinchine relation forces the service-excess tail to
#: follow P(S > w) ~ w**-1.8 across the whole 30..800 ns range -- a
#: straight line in log-log that no small discrete mixture can track --
#: and the exact stationary solve of the simulator's own Lindley chain
#: fixes the two slopes so that multi-event compounding lands the DES on
#: BOTH closed forms at every load anchor (mean within ~9%, p90 within
#: ~12%, for rho in [0.1, 0.8]).  E[S] stays exactly t_xfer (so rho
#: keeps its meaning as bus utilization) and E[S^2] ~= 267 ns^2 keeps
#: the mean-wait anchor W(0.5) ~= 80 ns;
#: ``coaxial.validate_calibration`` pins mean AND p90 per anchor.
STALL_PROB = 0.01923
STALL_NS = 37.0
STALL_ALPHA = 2.138
STALL_BREAK_NS = 353.6
STALL_ALPHA2 = 1.3495
STALL_MAX_NS = 1903.7
#: Floor on the non-penalized per-request service time (ns).
MIN_SERVICE_NS = 0.05

#: Idle-I/O bandwidth harvesting (arXiv 2511.12349): mean sojourn of
#: each lent / reclaimed window of the harvest modulating chain (ns).
#: I/O idleness varies on the same microsecond scale as the MMPP burst
#: envelope, so the default matches ``BURST_SOJOURN_NS``.
HARVEST_SOJOURN_NS = 2000.0
#: Threefry salt deriving the harvest chain's streams from each chunk /
#: phase key (``fold_in(key, salt)``); far above any lane index, so the
#: harvest draws can never collide with -- or shift -- the arrival and
#: service streams (the ``harvest_duty = 0`` bit-identity contract).
_HARVEST_SALT = 0x48415256

#: Default warmup fraction: the leading ``steps // WARMUP_DIV`` ns of
#: simulated time are simulated but not recorded (both engines).
WARMUP_DIV = 10

#: The two simulation engines (see module docstring).
ENGINES = ("timestep", "event")

#: Event-engine candidate budget per simulated ns: the candidate-arrival
#: intensity of the rho = 0.5 reference channel (the repo's W(0.5)
#: calibration anchor).  The event engine samples arrivals on the SAME
#: 1-ns lattice as the timestep engine -- a Bernoulli(p) lattice equals a
#: Poisson stream of intensity ``-ln(1-p)`` with same-cell arrivals
#: merged -- so the candidate intensity at the p = 0.3 anchor is
#: ``-ln(0.7)``.  ``steps`` ns of timestep budget and ``steps *
#: EVENTS_PER_NS`` candidates of event budget record the same number of
#: samples over the same simulated horizon at that anchor.
EVENTS_PER_NS = 0.35667

#: Steps per emission chunk of the timestep engine: the scan emits
#: ``(latency, mask)`` per step (no in-loop histogram scatter); chunking
#: bounds both the emission buffer and the chunk's precomputed per-lane
#: uniform block (``chunk x 5 x lanes`` f32) -- adaptive like the event
#: engine's, and derived from the UNPADDED batch width so the chunk
#: schedule (part of the stream contract) never depends on device count.
_TS_CHUNK_ELEMS = 24_000_000
_TS_CHUNK_MIN, _TS_CHUNK_MAX = 1024, 8192
#: Requests per chunk of the event engine: adaptive so the chunk's
#: working set (~a dozen ``chunk x cells`` f32 arrays) stays cache-sized
#: at any batch width -- wide LUT-build batches take smaller chunks,
#: narrow test batches take larger ones.
_EV_CHUNK_ELEMS = 5_000_000
_EV_CHUNK_MIN, _EV_CHUNK_MAX = 1024, 16384


def _ts_chunk_len(n: int) -> int:
    c = _TS_CHUNK_MIN
    while c < _TS_CHUNK_MAX and c * 2 * 5 * n <= _TS_CHUNK_ELEMS:
        c *= 2
    return c


def _event_chunk_len(n: int) -> int:
    c = _EV_CHUNK_MIN
    while c < _EV_CHUNK_MAX and c * 2 * n <= _EV_CHUNK_ELEMS:
        c *= 2
    return c


def canonical_chunk(engine: str) -> int:
    """The width-independent chunk length of the canonical stream contract.

    The adaptive chunk schedule (:func:`_ts_chunk_len` /
    :func:`_event_chunk_len`) keys the draw sequences on the batch width,
    so two batches of different widths never share streams even when
    their lanes share ``stream_ids``.  Callers that need a lane's result
    to be REPRODUCIBLE AT ANY BATCH WIDTH (the QueueLUT store's
    incremental builds: a cell simulated alone must equal the same cell
    inside the full-grid batch, bit for bit) pin
    ``chunk=canonical_chunk(engine)`` -- each engine's minimum, which is
    also what the adaptive heuristic picks at full LUT-grid widths, so
    pinning costs nothing where it matters and only adds dispatches on
    small probe batches.
    """
    _check_engine(engine)
    return _TS_CHUNK_MIN if engine == "timestep" else _EV_CHUNK_MIN


#: Odd (golden-ratio) constant mixing the replica index into a cell's
#: 32-bit stream id: ``lane_stream = (stream_ids[cell] + rep * MIX) mod
#: 2**32`` -- a bijection of the id space per replica, so replicas of one
#: cell draw independent streams and the mapping needs no second key.
_STREAM_REP_MIX = 0x9E3779B9


def _lane_streams(n: int, reps: int, stream_ids):
    """Per-lane stream indices for the flattened ``(reps x n)`` batch.

    ``stream_ids=None`` keeps the positional contract (global lane index
    ``rep * n + cell``); an explicit ``(n,)`` uint32 array keys each
    lane's threefry streams by the CALLER'S id instead -- the content
    half of the canonical stream contract (see :func:`canonical_chunk`
    for the schedule half).
    """
    if stream_ids is None:
        return jnp.arange(n * reps, dtype=jnp.int32)
    sid = np.asarray(stream_ids)
    if sid.shape != (n,):
        raise ValueError(f"stream_ids must have shape ({n},) -- one id "
                         f"per cell; got {sid.shape}")
    sid = sid.astype(np.uint64)
    rep = np.repeat(np.arange(reps, dtype=np.uint64), n)
    mixed = (np.tile(sid, reps) + rep * _STREAM_REP_MIX) & 0xFFFFFFFF
    return jnp.asarray(mixed.astype(np.uint32))
#: Event engine: one MMPP sojourn is simulated per this many candidates
#: (the modulating chain is ~100x slower than arrivals, so the chain
#: stays a rounding error of the candidate budget, and sizing it from
#: the budget alone keeps the kernel's trace independent of the axis
#: VALUES -- the one-trace-per-grid invariant).  Past the sampled chain
#: -- only reachable below rho ~0.05 at default budgets -- the appended
#: tail segment carries the average rate.
_SOJOURN_DIV = 48


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """One simulated memory channel configuration.

    Every field -- the operating point AND the calibration constants -- is
    sweepable; the module-level constants are only defaults.
    """

    rho: float                  # target bus utilization, 0..~0.95
    kappa: float = 1.0          # burst peak-to-mean arrival ratio
    #: In-flight request population per channel (MSHR/ROB bound); arrivals
    #: are blocked while the backlog holds more than
    #: ``outstanding * t_xfer_ns`` of queued work.  ``inf`` = open loop.
    outstanding: float = float("inf")
    #: Queue-exposure factor (``cpu_model``'s per-workload MLP/overlap
    #: knob): scales the per-request probability of a controller blocking
    #: episode, ``p_eff = eta * stall_prob``, while the small-service
    #: level re-absorbs the difference so E[S] stays exactly ``t_xfer``
    #: (rho keeps its meaning).  Since the M/G/1 wait is dominated by the
    #: blocking tail's E[S^2], the mean wait scales ~linearly in eta --
    #: the mechanistic counterpart of the old ``eta * W`` multiplier,
    #: but with the variance and quantiles simulated, not scaled.
    #: ``1.0`` (the default) is bit-identical to the pre-eta simulator.
    eta: float = 1.0
    t_xfer_ns: float = hw.CACHE_LINE_B / hw.DDR5_CH_BW_GBPS
    service_ns: float = hw.DRAM_SERVICE_NS - 2.0   # pipelined access part
    cxl_lat_ns: float = 0.0     # CXL interface premium (0 => direct DDR)
    burst_duty: float = BURST_DUTY
    burst_sojourn_ns: float = BURST_SOJOURN_NS
    stall_prob: float = STALL_PROB
    stall_ns: float = STALL_NS
    stall_alpha: float = STALL_ALPHA
    stall_break_ns: float = STALL_BREAK_NS
    stall_alpha2: float = STALL_ALPHA2
    stall_max_ns: float = STALL_MAX_NS
    service_jitter_ns: float = SERVICE_JITTER_NS
    #: Idle-I/O harvesting: fraction of time (in [0, 1)) an idle I/O
    #: link is lent to this channel, and the extra bandwidth it brings.
    #: While lent, a request's enqueued work shrinks by
    #: ``base_bw / (base_bw + harvest_bw_gbps)``.  ``harvest_duty = 0``
    #: (the default) is bit-identical to the unharvested simulator.
    harvest_duty: float = 0.0
    harvest_bw_gbps: float = 0.0
    harvest_sojourn_ns: float = HARVEST_SOJOURN_NS


class ChannelArrays(NamedTuple):
    """Pytree of per-channel simulation parameters, ``(N,)`` float leaves.

    Mirrors :class:`cpu_model.MemSystemArrays`: :class:`ChannelConfig` is
    the frozen-dataclass façade for humans, this is what the jitted
    simulation consumes -- one leading cell axis shared by every leaf, so
    any named-axis grid flattens to one batch.
    """

    rho: jnp.ndarray
    kappa: jnp.ndarray
    outstanding: jnp.ndarray
    eta: jnp.ndarray
    t_xfer_ns: jnp.ndarray
    service_ns: jnp.ndarray
    cxl_lat_ns: jnp.ndarray
    burst_duty: jnp.ndarray
    burst_sojourn_ns: jnp.ndarray
    stall_prob: jnp.ndarray
    stall_ns: jnp.ndarray
    stall_alpha: jnp.ndarray
    stall_break_ns: jnp.ndarray
    stall_alpha2: jnp.ndarray
    stall_max_ns: jnp.ndarray
    service_jitter_ns: jnp.ndarray
    harvest_duty: jnp.ndarray
    harvest_bw_gbps: jnp.ndarray
    harvest_sojourn_ns: jnp.ndarray


#: Channel fields a distribution-sweep axis may bind (all of them).
CHANNEL_FIELDS = ChannelArrays._fields


def stack_channels(configs) -> ChannelArrays:
    """Stack :class:`ChannelConfig` façades into one ``(N,)``-leaved pytree."""
    return ChannelArrays(*(
        jnp.asarray([float(getattr(c, f)) for c in configs], jnp.float32)
        for f in CHANNEL_FIELDS))


def _apply_channel_overrides(cha: ChannelArrays, ov) -> ChannelArrays:
    """NaN-masked per-field substitution, applied branch-free in-trace."""
    return cha._replace(**{
        f: jnp.where(jnp.isnan(v), getattr(cha, f), v)
        for f, v in ov.items()})


#: Number of times each engine's jitted chunk kernel has been TRACED (not
#: called).  A trace only happens on a new (flattened cell count, device
#: count) pair -- chunk lengths derive from the unpadded batch width and
#: the event engine's sojourn count from the request budget, never from
#: axis values -- so a whole named-axis distribution grid bumps its
#: engine's counter by exactly one, sharded or not; tests pin that.
_TRACE_COUNT = {"timestep": 0, "event": 0}


def sim_trace_count(engine: str | None = None) -> int:
    """Trace count for one engine, or the sum over both when ``engine``
    is omitted."""
    if engine is None:
        return sum(_TRACE_COUNT.values())
    _check_engine(engine)
    return _TRACE_COUNT[engine]


def _check_engine(engine: str) -> str:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    return engine


def _pareto_seg(ratio, a):
    """Per-unit-survival mean of one power-law segment.

    ``integral of (x0/x)**a from x0 to x1, divided by x0`` with
    ``ratio = x0/x1``: ``(1 - ratio**(a-1)) / (a-1)``, whose ``a -> 1``
    limit is ``-log(ratio)``.  Branch-free so a ``stall_alpha`` axis may
    sweep through 1.0 without the 0/0 turning the cell into silent NaN
    garbage.
    """
    d = a - 1.0
    near_one = jnp.abs(d) < 1e-4
    safe = jnp.where(near_one, 1.0, d)
    return jnp.where(near_one, -jnp.log(ratio), (1.0 - ratio ** safe) / safe)


def _channel_terms(c: ChannelArrays) -> dict:
    """Derived per-channel quantities shared by both engines.

    The SAME laws feed both engines: MMPP rates and switching
    probabilities, the two-slope blocking tail, and the small-service
    level that keeps E[S] exactly ``t_xfer``.
    """
    rate_avg = c.rho / c.t_xfer_ns               # arrivals per ns
    rate_hi = jnp.minimum(c.kappa * rate_avg, 0.98)
    # Rate in the idle state so the duty-weighted mean matches rate_avg.
    rate_lo = jnp.maximum(
        (rate_avg - c.burst_duty * rate_hi) / (1.0 - c.burst_duty), 0.0)
    p_leave = 1.0 / c.burst_sojourn_ns           # state-switch prob per ns
    # Duty-correct entry prob: stationary P(burst) = burst_duty.
    p_enter = p_leave * c.burst_duty / (1.0 - c.burst_duty)
    # Two-slope truncated-Pareto blocking durations.  Survival:
    # (sn/x)**a1 up to the break, then q_b * (xb/x)**a2, capped at the
    # max.  The capped mean (closed form, computed in-trace) lets s_small
    # absorb the blocking work so E[S] stays exactly t_xfer.
    sn, xb = c.stall_ns, c.stall_break_ns
    a1, a2, cap = c.stall_alpha, c.stall_alpha2, c.stall_max_ns
    q_b = (sn / xb) ** a1                    # survival at the break
    stall_mean = (sn + sn * _pareto_seg(sn / xb, a1) +
                  q_b * xb * _pareto_seg(xb / cap, a2))
    # Effective blocking probability: ``eta`` scales how often a request
    # triggers a blocking episode (eta = 1 reproduces stall_prob exactly,
    # bit for bit -- x * 1.0 is exact in f32).  s_small re-absorbs the
    # blocking work either way, so E[S] stays t_xfer at every eta.
    p_stall = jnp.clip(c.stall_prob * c.eta, 0.0, 0.999)
    s_small = ((c.t_xfer_ns - p_stall * stall_mean) /
               (1.0 - p_stall))
    s_small = jnp.maximum(s_small, MIN_SERVICE_NS)
    # Lattice candidate intensities for the event engine: a Bernoulli(p)
    # per-ns arrival process equals a Poisson stream of intensity
    # -ln(1-p) whose same-cell arrivals are merged, so both engines draw
    # from the SAME per-ns gap law.
    lam_hi = -jnp.log1p(-rate_hi)
    lam_lo = -jnp.log1p(-rate_lo)
    lam_avg = -jnp.log1p(-jnp.minimum(rate_avg, 0.98))
    return dict(rate_avg=rate_avg, rate_hi=rate_hi, rate_lo=rate_lo,
                p_leave=p_leave, p_enter=p_enter, q_b=q_b,
                p_stall=p_stall, s_small=s_small, lam_hi=lam_hi,
                lam_lo=lam_lo, lam_avg=lam_avg)


def _harvest_terms(c: ChannelArrays) -> dict:
    """Derived harvest-chain quantities shared by both engines.

    Deliberately NOT folded into :func:`_channel_terms`: the harvest
    terms are consumed only by the (separately jitted) harvest entry
    points, so the pre-harvest stage A executables stay byte-identical
    and the ``harvest_duty = 0`` histograms cannot shift.

    The lent/reclaimed chain mirrors the MMPP burst chain: per-ns leave
    probability ``1 / harvest_sojourn_ns`` and a duty-correct entry
    probability so the stationary lent fraction is ``harvest_duty``
    (exactly 0.0 at duty = 0 -- the chain never leaves the reclaimed
    state).  ``h_scale`` is the work shrink while lent,
    ``base_bw / (base_bw + harvest_bw)`` with the channel's own base
    bandwidth ``CACHE_LINE_B / t_xfer_ns`` -- exactly 1.0 at
    ``harvest_bw_gbps = 0``.
    """
    h_leave = 1.0 / c.harvest_sojourn_ns
    h_enter = h_leave * c.harvest_duty / (1.0 - c.harvest_duty)
    h_scale = 1.0 / (1.0 + c.harvest_bw_gbps * c.t_xfer_ns /
                     hw.CACHE_LINE_B)
    return dict(h_leave=h_leave, h_enter=h_enter, h_scale=h_scale)


def _harvest_scan_terms(cha: ChannelArrays, ov):
    return _harvest_terms(_apply_channel_overrides(cha, ov))


_harvest_scan_terms_jit = jax.jit(_harvest_scan_terms)


def _harvest_active(cha: ChannelArrays, ov) -> bool:
    """Host-side fast path: True iff any lane has an effective
    ``harvest_duty > 0`` AND ``harvest_bw_gbps > 0``.

    Inactive batches skip the harvest draws / window tables entirely --
    the chain is a provable no-op there (``h_enter = 0`` or ``h_scale =
    1``), so the skip is value-identical and the unharvested path keeps
    its pre-harvest wall-clock.  A value peek in the driver, not a
    trace-cache key: the same stage B kernel runs either way, so the
    one-trace-per-grid invariant is untouched.
    """
    def eff(field):
        own = np.asarray(getattr(cha, field), np.float64)
        o = np.asarray(ov[field], np.float64)
        return np.where(np.isnan(o), own, o)
    return bool(np.any((eff("harvest_duty") > 0.0)
                       & (eff("harvest_bw_gbps") > 0.0)))


# ---------------------------------------------------------------------------
# Two-stage kernels: width-pinned randomness, shardable recursion.
#
# Bit-identity across device counts cannot survive recompiling
# transcendental math at different widths: XLA fuses ``log``/``exp``/
# ``pow`` into whatever surrounds them, and two fusions may round a
# result 1 ulp apart -- enough to flip a ``ceil`` or a bin boundary.
# So each engine is split in two:
#
#   * STAGE A (draws + transcendentals + MMPP/service law): ALWAYS
#     compiled at the UNPADDED batch width, whatever ``devices`` is.
#     Same executable + same inputs = bitwise-identical outputs -- the
#     only cross-run invariant XLA actually guarantees.
#   * STAGE B (the sequential recursion: Lindley / backlog scan, plus
#     binning): compiled per (device count, padded width) and run under
#     ``shard_map``.  Its ops are restricted to correctly-rounded
#     elementwise arithmetic (add/sub/mul/div/min/max/where/compare) and
#     integer work, each deterministic at ANY width; the one ``a*b + c``
#     pattern multiplies by an exact 0/1 indicator, so FMA contraction
#     cannot change it.  That restriction -- no transcendentals, no
#     reductions -- is what makes the per-shard recompile exact, and it
#     is also why the split helps wall-clock: the embarrassingly
#     parallel stage A runs once, and only the sequential scan (the part
#     that cannot vectorize across time) is sharded across devices.
# ---------------------------------------------------------------------------

def _lane_uniforms(key, lane_idx, shape, **kw):
    """Per-lane uniforms from lane-keyed threefry streams.

    One stream per lane, keyed by the GLOBAL lane index
    (``fold_in(chunk_key, lane)``): lane ``i`` draws the same values at
    any batch width or device layout -- the stream half of the
    determinism contract (stage A's fixed-width compile is the other
    half).  Returns ``shape + (n,)``.
    """
    lane_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, lane_idx)
    u = jax.vmap(lambda k: jax.random.uniform(k, shape, **kw))(lane_keys)
    return jnp.moveaxis(u, 0, -1)


def _flat_bins(lat, rec, lane_idx, n_total: int):
    """Post-scan vectorized histogram indices for one chunk.

    ``lat``/``rec`` are ``(C, n)``; returns flattened ``lane * N_BINS +
    bin`` int32 indices with unrecorded entries parked in one overflow
    slot (``n_total * N_BINS``).  The lane offsets use the GLOBAL lane
    ids (``lane_idx``) and the overflow slot the GLOBAL padded width, so
    per-shard emissions live in one shared index space and the host's
    single ``bincount`` merges shards exactly; it drops the overflow
    slot, so no boolean compaction is needed on either side.
    """
    bins = jnp.clip((lat * (1.0 / BIN_NS)).astype(jnp.int32), 0, N_BINS - 1)
    off = (lane_idx.astype(jnp.int32) * N_BINS)[None, :]
    return jnp.where(rec, bins + off, n_total * N_BINS)


def _pad_cols(x, pad: int, value: float):
    """Append ``pad`` constant lanes to the trailing axis -- pure data
    movement (bit-exact under any compile), done INSIDE the stage B jit
    so stage A shapes never see the device count."""
    if pad == 0:
        return x
    shape = x.shape[:-1] + (pad,)
    return jnp.concatenate([x, jnp.full(shape, value, x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Timestep engine: the 1-ns reference.
# ---------------------------------------------------------------------------

def _ts_draws(cha: ChannelArrays, ov, lane_idx, key, chunk: int):
    """Stage A of the timestep engine: one chunk of per-lane randomness.

    Draws the five per-step uniforms per lane (switch / arrival / jitter
    / blocking-or-not / blocking size) from the lane-keyed streams and
    finishes every law that needs transcendental math: the jitter offset
    and the full two-slope service draw.  Returns ``(chunk, n)`` arrays
    ``(switch_u, arrive_u, jitter, svc)`` -- everything stage B's scan
    consumes, computed at the unpadded width.
    """
    c = _apply_channel_overrides(cha, ov)
    t = _channel_terms(c)
    q_b, s_small, p_stall = t["q_b"], t["s_small"], t["p_stall"]
    sn, xb = c.stall_ns, c.stall_break_ns
    a1, a2, cap = c.stall_alpha, c.stall_alpha2, c.stall_max_ns
    u5 = _lane_uniforms(key, lane_idx, (chunk, 5))    # (chunk, 5, n)
    switch_u, arrive_u, jitter_u, svc_u, size_u = jnp.moveaxis(u5, 1, 0)
    jitter = (jitter_u * 2.0 - 1.0) * c.service_jitter_ns
    # Inverse-CDF sample of the two-slope law: the uniform IS the
    # survival value -- above q_b the first slope applies, below it the
    # far tail, capped at the max.
    u = jnp.maximum(size_u, 1e-7)
    stall = jnp.where(u > q_b, sn * u ** (-1.0 / a1),
                      xb * (q_b / u) ** (1.0 / a2))
    stall = jnp.minimum(stall, cap)
    svc = jnp.where(svc_u < p_stall, stall, s_small)
    return switch_u, arrive_u, jitter, svc


_ts_draws_jit = jax.jit(_ts_draws, static_argnames=("chunk",))


def _ts_harvest_u(lane_idx, key, chunk: int):
    """Harvest half of timestep stage A: one chunk of per-lane switch
    uniforms for the lent/reclaimed chain, drawn from a SEPARATE salted
    lane-keyed stream (``fold_in(key, _HARVEST_SALT)``) so the five
    arrival/service uniforms of :func:`_ts_draws` -- and with them the
    ``harvest_duty = 0`` histograms -- never shift.  A separate jitted
    executable for the same reason."""
    return _lane_uniforms(jax.random.fold_in(key, _HARVEST_SALT),
                          lane_idx, (chunk,))


_ts_harvest_u_jit = jax.jit(_ts_harvest_u, static_argnames=("chunk",))


def _scan_terms(cha: ChannelArrays, ov):
    """Per-run channel constants consumed by the stage B scans (computed
    once at the unpadded width, like stage A): MMPP switch/rate terms,
    the admission bound, and the deterministic access latency
    ``lat0 = service + pipeline + CXL``."""
    c = _apply_channel_overrides(cha, ov)
    t = _channel_terms(c)
    return dict(p_leave=t["p_leave"], p_enter=t["p_enter"],
                rate_hi=t["rate_hi"], rate_lo=t["rate_lo"],
                bound=c.outstanding * c.t_xfer_ns,
                lat0=c.service_ns + 2.0 + c.cxl_lat_ns)


_scan_terms_jit = jax.jit(_scan_terms)


def _ts_chunk_core(terms, state, lane_idx, switch_u, arrive_u, jitter, svc,
                   harvest_u, record, n_total: int):
    """Stage B of the timestep engine: one chunk of the backlog scan.

    The per-nanosecond recursion over stage A's precomputed draws.
    Instead of scatter-updating a histogram carried through the scan,
    the body EMITS ``(latency, arrive * record)`` and the histogram
    indices are produced post-scan, vectorized over the whole chunk (the
    host accumulates them with one ``bincount``).  Counts are small
    integers, exact in either accumulation order, so the emission
    micro-opt and the per-shard merge are both exact.
    """
    _TRACE_COUNT["timestep"] += 1  # side effect runs at trace time only
    n = lane_idx.shape[0]
    p_leave, p_enter = terms["p_leave"], terms["p_enter"]
    rate_hi, rate_lo = terms["rate_hi"], terms["rate_lo"]
    bound, lat0 = terms["bound"], terms["lat0"]
    h_leave, h_enter = terms["h_leave"], terms["h_enter"]
    h_scale = terms["h_scale"]

    # Strong-typed 0/1 so the carry dtype is stable across chunk calls
    # (a weak-typed literal would force a second trace of the kernel).
    zero, one = jnp.zeros(n, jnp.float32), jnp.ones(n, jnp.float32)

    def step(carry, xs):
        sw, au, jit_ns, s, hu, rec = xs
        backlog, in_burst, lent = carry
        in_burst = jnp.where(
            in_burst > 0.5,
            jnp.where(sw < p_leave, zero, one),
            jnp.where(sw < p_enter, one, zero))
        # Harvest lent/reclaimed chain: the same two-state construction
        # as the MMPP burst chain, on the same lattice.  ``h_enter`` is
        # exactly 0.0 at duty = 0, so the chain never leaves the
        # reclaimed state and ``s_eff`` is exactly ``s`` -- the
        # unharvested backlog path, bit for bit.
        lent = jnp.where(
            lent > 0.5,
            jnp.where(hu < h_leave, zero, one),
            jnp.where(hu < h_enter, one, zero))
        rate = jnp.where(in_burst > 0.5, rate_hi, rate_lo)
        arrive = (au < rate).astype(jnp.float32)
        # Closed-loop population bound: while the backlog holds more than
        # ``outstanding`` requests' worth of work the MSHRs are full and
        # the core stalls instead of issuing -- the arrival is blocked,
        # not queued.  inf (the default) admits everything: open loop.
        arrive = arrive * (backlog <= bound).astype(jnp.float32)
        latency = backlog + lat0 + jit_ns
        s_eff = jnp.where(lent > 0.5, s * h_scale, s)
        # arrive is an exact 0/1, so ``backlog + arrive * s`` cannot be
        # perturbed by FMA contraction -- stage B stays compile-exact.
        backlog = jnp.maximum(backlog + arrive * s_eff - 1.0, 0.0)
        return (backlog, in_burst, lent), (latency, arrive * rec)

    state, (lat, mask) = jax.lax.scan(
        step, state, (switch_u, arrive_u, jitter, svc, harvest_u, record))
    return state, _flat_bins(lat, mask > 0.0, lane_idx, n_total)


@functools.lru_cache(maxsize=None)
def _ts_kernel(ndev: int, n_total: int, n_real: int):
    """The jitted (and, for ``ndev > 1``, lane-sharded) stage B timestep
    kernel.  Pads stage A's unpadded outputs to the device multiple
    in-jit (pure data movement), then runs the scan per lane shard."""
    pad = n_total - n_real
    lane_idx = jnp.arange(n_total, dtype=jnp.int32)

    def body(terms, state, lanes, switch_u, arrive_u, jitter, svc,
             harvest_u, record):
        return _ts_chunk_core(terms, state, lanes, switch_u, arrive_u,
                              jitter, svc, harvest_u, record, n_total)

    L, R = shardsim.lanes(), shardsim.replicated()
    L1 = shardsim.lanes(1)
    fn = shardsim.jit_lanes(
        body, ndev,
        in_specs=(L, L, L, L1, L1, L1, L1, L1, R),
        out_specs=(L, L1))

    def run(terms, state, switch_u, arrive_u, jitter, svc, harvest_u,
            record):
        # NaN terms / zeroed draws on padding lanes: they never arrive,
        # never record, and park all mass in the overflow slot.
        terms = {k: _pad_cols(v, pad, np.nan) for k, v in terms.items()}
        return fn(terms, state, lane_idx,
                  _pad_cols(switch_u, pad, 0.0), _pad_cols(arrive_u, pad, 0.0),
                  _pad_cols(jitter, pad, 0.0), _pad_cols(svc, pad, 0.0),
                  _pad_cols(harvest_u, pad, 0.0), record)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# Event engine: per-request Lindley scan.
# ---------------------------------------------------------------------------

def _event_tables(cha: ChannelArrays, ov, lane_idx, key, n_sojourns: int):
    """Simulate the MMPP modulating chain once per call (per lane).

    Alternating exponential sojourns starting in the burst state (drawn
    from the lane-keyed streams, :func:`_lane_uniforms`); returns
    per-lane ``(M+1,)`` rows of cumulative intensity ``L``, boundary time
    ``T`` and segment rate -- the piecewise-linear cumulative-intensity
    table the chunk kernel inverts.  The appended final segment extends
    to infinity at the average rate, so lanes whose horizon outruns the
    sampled chain degrade to uncorrelated (but rate-exact) arrivals
    instead of running dry.
    """
    c = _apply_channel_overrides(cha, ov)
    n = c.rho.shape[0]
    t = _channel_terms(c)
    su = _lane_uniforms(key, lane_idx, (n_sojourns,), minval=1e-12)
    burst = (jnp.arange(n_sojourns) % 2 == 0)[:, None]
    soj = -jnp.log(su) * jnp.where(burst, 1.0 / t["p_leave"],
                                   1.0 / t["p_enter"])
    rate_m = jnp.where(burst, t["lam_hi"], t["lam_lo"])
    T0 = jnp.concatenate([jnp.zeros((1, n)), jnp.cumsum(soj, axis=0)])
    L0 = jnp.concatenate([jnp.zeros((1, n)),
                          jnp.cumsum(rate_m * soj, axis=0)])
    rate_seg = jnp.concatenate(
        [rate_m, jnp.maximum(t["lam_avg"], 1e-9)[None]])
    # (n, M+1) intensity rows for searchsorted + one packed gather table.
    return L0.T, jnp.stack([T0.T, L0.T, rate_seg.T], axis=-1)


_event_tables_jit = jax.jit(_event_tables, static_argnames=("n_sojourns",))


def _event_arrivals(cha: ChannelArrays, ov, state, lane_idx, key, tabs,
                    warmup_ns, chunk: int):
    """Stage A of the event engine: one chunk of arrivals + services.

    Per candidate request, in vectorized passes: a unit-exponential
    increment of cumulative intensity, inverted through the MMPP's
    piecewise-linear intensity table to a continuous arrival time (exact
    in-gap phase switching -- the Cox construction), then CEILED onto the
    timestep engine's 1-ns lattice (candidates sharing a cell merge into
    one arrival, which is exactly the Bernoulli-per-ns arrival law, gap
    by gap); a service draw from the shared two-slope law (selection and
    size from ONE uniform: conditioned on ``u < stall_prob``,
    ``u / stall_prob`` is again uniform).  Runs at the unpadded width
    (its logs/exps must not recompile with the device count); stage B
    gets ``(gaps, svc, rec_time)`` plus this stage's own
    ``(u_last, t_last)`` carry.
    """
    c = _apply_channel_overrides(cha, ov)
    n = c.rho.shape[0]
    t = _channel_terms(c)
    q_b, s_small, p_stall = t["q_b"], t["s_small"], t["p_stall"]
    sn, xb = c.stall_ns, c.stall_break_ns
    a1, a2, cap = c.stall_alpha, c.stall_alpha2, c.stall_max_ns
    log_qb = jnp.log(q_b)
    Lt, packed = tabs
    m = Lt.shape[1] - 1

    u_last, t_last = state
    u = _lane_uniforms(key, lane_idx, (2, chunk), minval=1e-12)
    lg = jnp.log(u)                       # one fused pass for both rows
    # Arrival times: unit-exponential increments of cumulative intensity,
    # inverted through the per-lane piecewise-linear table.  The queries
    # are SORTED along the chunk, so instead of a per-request binary
    # search the few boundaries are positioned among the many requests
    # (one small searchsorted per lane) and the per-request segment index
    # is recovered as a scatter + cumulative count -- the segment of
    # request k is #{j : L0[j] < U_k} - 1, a staircase in k.
    upos = u_last[None, :] + jnp.cumsum(-lg[0], axis=0)           # (C, n)
    ut = upos.T                                                   # (n, C)
    pos = jax.vmap(lambda q, l: jnp.searchsorted(q, l, side="right")
                   )(ut, Lt)                                      # (n, M+1)
    cnt = jnp.zeros((n, chunk + 1), jnp.int32)
    cnt = cnt.at[jnp.arange(n)[:, None], pos].add(1)
    seg = jnp.clip(jnp.cumsum(cnt[:, :chunk], axis=1) - 1, 0, m)
    tab = jnp.take_along_axis(packed, seg[..., None], axis=1)     # (n, C, 3)
    arr_t = jnp.ceil((tab[..., 0] + (ut - tab[..., 1]) /
                      jnp.maximum(tab[..., 2], 1e-12)).T)  # lattice cell
    gaps = jnp.diff(jnp.concatenate([t_last[None, :], arr_t], axis=0),
                    axis=0)
    real = gaps > 0.5                  # same-cell candidates merge
    # Service: one uniform for selection AND size (conditioned on
    # ``u < stall_prob``, ``u / stall_prob`` is again uniform), one log +
    # one exp for the whole two-slope inverse CDF (the slope pick happens
    # in log space).
    us = u[1]
    lu = lg[1] - jnp.log(p_stall)
    log_stall = jnp.where(us > q_b * p_stall,
                          jnp.log(sn) - lu / a1,
                          jnp.log(xb) + (log_qb - lu) / a2)
    svc = jnp.where(us < p_stall,
                    jnp.minimum(jnp.exp(log_stall), cap), s_small)
    svc = jnp.where(real, svc, 0.0)    # phantoms add no work
    # Lattice cell k is recorded iff the timestep engine would record
    # step k-1, i.e. past the warmup window (stage B adds the admission
    # test, whose witness is the emitted wait itself).
    rec_time = real & (arr_t > warmup_ns + 0.5)
    return (upos[-1], arr_t[-1]), gaps, svc, rec_time


_event_arrivals_jit = jax.jit(_event_arrivals, static_argnames=("chunk",))


def _event_harvest_tabs(cha: ChannelArrays, ov, lane_idx, key,
                        n_windows: int):
    """Simulate the harvest lent/reclaimed chain once per call (per lane).

    Alternating exponential sojourns starting in the RECLAIMED state,
    drawn from the salted lane-keyed streams (the event-engine mirror of
    :func:`_event_tables`'s MMPP sojourns -- same window count, sized
    from the request budget alone so the trace stays value-independent).
    Returns per-lane ``(M,)`` cumulative boundary times; the interval an
    arrival lands in (``searchsorted``) is lent iff its index is odd.
    At ``duty = 0`` the first reclaimed sojourn is infinite
    (``1 / h_enter``), so every arrival lands in interval 0.
    """
    c = _apply_channel_overrides(cha, ov)
    t = _harvest_terms(c)
    su = _lane_uniforms(jax.random.fold_in(key, _HARVEST_SALT),
                        lane_idx, (n_windows,), minval=1e-12)
    lent = (jnp.arange(n_windows) % 2 == 1)[:, None]
    soj = -jnp.log(su) * jnp.where(lent, 1.0 / t["h_leave"],
                                   1.0 / t["h_enter"])
    return jnp.cumsum(soj, axis=0).T                      # (n, M)


_event_harvest_tabs_jit = jax.jit(_event_harvest_tabs,
                                  static_argnames=("n_windows",))


def _event_harvest_scale(svc, gaps, t0, bounds, h_scale):
    """Scale the services that arrive inside lent windows (event engine).

    A separate executable BETWEEN stage A and stage B: the arrival /
    service draws upstream (:func:`_event_arrivals`) and the Lindley
    kernel downstream are the exact same executables as the unharvested
    path -- this pass is simply skipped when harvesting is inactive, so
    ``duty = 0`` stays bit-identical by construction.  Arrival times are
    rebuilt from the gaps: lattice cells are whole f32 integers, so the
    cumulative sum reproduces them exactly (below 2**24 ns of simulated
    horizon, far beyond any realistic budget).
    """
    arr_t = t0[None, :] + jnp.cumsum(gaps, axis=0)        # (C, n)
    idx = jax.vmap(jnp.searchsorted, in_axes=(0, 0))(bounds, arr_t.T)
    lent = (idx % 2 == 1).T
    return jnp.where(lent, svc * h_scale[None, :], svc)


_event_harvest_scale_jit = jax.jit(_event_harvest_scale)


def _event_chunk_core(terms, W, lane_idx, gaps, svc, rec_time,
                      n_total: int):
    """Stage B of the event engine: one chunk of the Lindley recursion.

    The only sequential part of the engine -- a four-op scan body:

        W <- max(W - A_k, 0);  admit = W <= outstanding * t_xfer;
        emit W;                W <- W + admit * S_k

    (phantom same-cell candidates carry ``A = 0``, ``S = 0`` and are
    masked out of the histogram, so they are invisible to the queue).
    Latencies are ``W`` plus the deterministic access terms; the uniform
    DRAM jitter is convolved into the histogram afterwards (it never
    feeds the queue).
    """
    _TRACE_COUNT["event"] += 1  # side effect runs at trace time only
    bound, lat0 = terms["bound"], terms["lat0"]

    def event(wc, xs):
        gap, s = xs
        wc = jnp.maximum(wc - gap, 0.0)
        return wc + jnp.where(wc <= bound, s, 0.0), wc

    W, wq = jax.lax.scan(event, W, (gaps, svc), unroll=8)
    # The emitted wait IS the admission witness: recompute the bound test
    # vectorized instead of emitting a second buffer from the scan.
    lat = wq + lat0
    rec = rec_time & (wq <= bound)
    return W, _flat_bins(lat, rec, lane_idx, n_total)


@functools.lru_cache(maxsize=None)
def _event_kernel(ndev: int, n_total: int, n_real: int, chunk: int):
    """The jitted (and, for ``ndev > 1``, lane-sharded) stage B event
    kernel.  Pads stage A's unpadded outputs to the device multiple
    in-jit (pure data movement), then runs the scan per lane shard."""
    pad = n_total - n_real
    lane_idx = jnp.arange(n_total, dtype=jnp.int32)

    def body(terms, W, lanes, gaps, svc, rec_time):
        return _event_chunk_core(terms, W, lanes, gaps, svc, rec_time,
                                 n_total)

    L, R = shardsim.lanes(), shardsim.replicated()
    L1 = shardsim.lanes(1)
    fn = shardsim.jit_lanes(
        body, ndev,
        in_specs=(L, L, L, L1, L1, L1),
        out_specs=(L, L1))

    def run(terms, W, gaps, svc, rec_time):
        # Padding lanes: unit gaps, zero service, never recorded and a
        # NaN bound (every comparison false), so their wait stays 0 and
        # all their mass parks in the overflow slot.
        terms = {k: _pad_cols(v, pad, np.nan) for k, v in terms.items()}
        return fn(terms, W, lane_idx,
                  _pad_cols(gaps, pad, 1.0), _pad_cols(svc, pad, 0.0),
                  _pad_cols(rec_time, pad, False))

    return jax.jit(run)


def events_for_steps(steps: int) -> int:
    """Event-engine request budget equivalent to ``steps`` ns of timestep
    budget (see :data:`EVENTS_PER_NS`).  The driver rounds it up to whole
    chunks of the batch's (width-adaptive) chunk length."""
    return max(_EV_CHUNK_MIN, int(round(steps * EVENTS_PER_NS)))


def _jitter_kernel(width: np.ndarray) -> np.ndarray:
    """Per-lane histogram kernel of the uniform(-w, w) DRAM jitter.

    Tap ``k`` holds the overlap of bin offset ``[k*BIN - BIN/2, k*BIN +
    BIN/2)`` with the jitter support, so convolving a histogram with the
    kernel equals sampling the jitter per request, up to half-bin
    quantization.  Zero width degrades to the identity kernel.
    """
    width = np.asarray(width, np.float64)
    taps = int(np.ceil(np.max(width, initial=0.0) / BIN_NS)) + 1
    k = np.arange(-taps, taps + 1, dtype=np.float64)
    wide = width[:, None] >= 1e-9
    w = np.where(wide, width[:, None], 1.0)
    lo = np.maximum(k[None, :] * BIN_NS - BIN_NS / 2, -w)
    hi = np.minimum(k[None, :] * BIN_NS + BIN_NS / 2, w)
    kern = np.maximum(hi - lo, 0.0) / (2.0 * w)
    kern = np.where(wide, kern, (k == 0.0)[None, :])
    return kern


def _convolve_jitter(hist: np.ndarray, width: np.ndarray) -> np.ndarray:
    """Convolve per-lane histograms with their jitter kernels, clamping
    shifted-out mass into the edge bins (mass is conserved exactly)."""
    kern = _jitter_kernel(width)
    taps = (kern.shape[1] - 1) // 2
    out = np.zeros_like(hist, np.float64)
    nb = hist.shape[-1]
    for i, kk in enumerate(range(-taps, taps + 1)):
        w = kern[:, i][:, None]
        if not np.any(w > 0):
            continue
        if kk >= nb:               # shift beyond the span: all mass clamps
            out[:, -1:] += hist.sum(axis=1, keepdims=True) * w
        elif kk <= -nb:
            out[:, :1] += hist.sum(axis=1, keepdims=True) * w
        elif kk >= 0:
            out[:, kk:] += hist[:, :nb - kk] * w
            if kk > 0:
                out[:, -1:] += hist[:, nb - kk:].sum(axis=1, keepdims=True) * w
        else:
            out[:, :kk] += hist[:, -kk:] * w
            out[:, :1] += hist[:, :-kk].sum(axis=1, keepdims=True) * w
    return out


# ---------------------------------------------------------------------------
# Shared driver + statistics.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LatencyStats:
    """Latency-distribution summary; leaves share any leading cell/grid
    shape, with ``hist`` carrying one trailing bin axis."""

    mean_ns: np.ndarray
    stdev_ns: np.ndarray
    p50_ns: np.ndarray
    p90_ns: np.ndarray
    p99_ns: np.ndarray
    hist: np.ndarray            # (..., N_BINS) counts
    bin_ns: float = BIN_NS

    _ARRAY_FIELDS = ("mean_ns", "stdev_ns", "p50_ns", "p90_ns", "p99_ns",
                     "hist")

    def __getitem__(self, idx) -> "LatencyStats":
        """Slice the leading (cell/grid) axes of every leaf identically."""
        return LatencyStats(**{f: getattr(self, f)[idx]
                               for f in self._ARRAY_FIELDS},
                            bin_ns=self.bin_ns)

    def reshape(self, *grid_shape) -> "LatencyStats":
        """Reshape the leading axes; the histogram bin axis stays last."""
        shaped = {f: getattr(self, f).reshape(grid_shape)
                  for f in self._ARRAY_FIELDS if f != "hist"}
        shaped["hist"] = self.hist.reshape(tuple(grid_shape) +
                                           self.hist.shape[-1:])
        return LatencyStats(**shaped, bin_ns=self.bin_ns)

    def cdf(self, i=None) -> tuple[np.ndarray, np.ndarray]:
        """(latency_ns, cdf) arrays for cell ``i`` (Fig 6b).

        ``i`` may be omitted when the stats hold a single cell (``hist``
        is one-dimensional), e.g. after a fully pinned
        ``DistributionSweepResult.sel``.
        """
        h = self.hist if i is None else self.hist[i]
        if h.ndim != 1:
            raise ValueError(
                f"cdf() needs one cell; hist has shape {h.shape} -- "
                f"index a cell or sel() down to one")
        c = np.cumsum(h) / max(h.sum(), 1.0)
        x = (np.arange(h.shape[-1]) + 0.5) * self.bin_ns
        return x, c


def _stats_from_hist(hist: np.ndarray) -> LatencyStats:
    centers = (np.arange(hist.shape[-1]) + 0.5) * BIN_NS
    total = np.maximum(hist.sum(axis=-1, keepdims=True), 1.0)
    p = hist / total
    mean = (p * centers).sum(axis=-1)
    var = (p * (centers - mean[..., None]) ** 2).sum(axis=-1)
    cum = np.cumsum(p, axis=-1)

    def quantile(q):
        idx = np.argmax(cum >= q, axis=-1)
        return (idx + 0.5) * BIN_NS

    return LatencyStats(
        mean_ns=mean, stdev_ns=np.sqrt(var), p50_ns=quantile(0.5),
        p90_ns=quantile(0.9), p99_ns=quantile(0.99), hist=hist)


def default_warmup(steps: int) -> int:
    return steps // WARMUP_DIV


def _nan_overrides(n: int) -> dict:
    # Explicit dtype => strong-typed leaves, so the jit signature doesn't
    # depend on WHICH fields an axis binds (bound overrides are strong
    # float32 too) -- any axis combination of one size shares a compile.
    nans = jnp.full((n,), jnp.nan, jnp.float32)
    return {f: nans for f in CHANNEL_FIELDS}


def _accumulate_chunks(dispatch, n_chunks: int, n: int) -> np.ndarray:
    """Drive the per-chunk kernel and histogram its emissions.

    ``dispatch(k)`` runs chunk ``k`` and returns the flattened histogram
    indices (asynchronously); the host folds each chunk into the counts
    with one integer ``bincount`` while the next chunk computes, then
    drops the overflow slot.  Counts are exact integers, so accumulation
    order cannot perturb them.
    """
    hist = np.zeros(n * N_BINS + 1, np.int64)
    pending = dispatch(0)
    for k in range(1, n_chunks):
        nxt = dispatch(k)           # async: overlaps the bincount below
        hist += np.bincount(np.asarray(pending).reshape(-1),
                            minlength=n * N_BINS + 1)
        pending = nxt
    hist += np.bincount(np.asarray(pending).reshape(-1),
                        minlength=n * N_BINS + 1)
    return hist[:-1].reshape(n, N_BINS).astype(np.float64)


def _run_timestep(cha, ov, steps, seed, warmup, ndev, n_real, pad,
                  lane_r, chunk=None):
    n_tot = n_real + pad
    # Chunk length derives from the UNPADDED width: the chunk schedule is
    # part of the stream contract, padding is a device-count artifact.
    # An explicit ``chunk`` pins the schedule width-independently (the
    # canonical stream contract, see :func:`canonical_chunk`).
    chunk = _ts_chunk_len(n_real) if chunk is None else int(chunk)
    n_chunks = -(-steps // chunk)
    ckeys = np.asarray(jax.random.split(jax.random.PRNGKey(seed), n_chunks))
    record = np.zeros(n_chunks * chunk, np.float32)
    record[warmup:steps] = 1.0
    terms = {**_scan_terms_jit(cha, ov), **_harvest_scan_terms_jit(cha, ov)}
    state = (jnp.zeros(n_tot), jnp.ones(n_tot), jnp.zeros(n_tot))
    fn = _ts_kernel(ndev, n_tot, n_real)
    # Unharvested batches skip the extra per-step uniform: with
    # ``h_enter = 0`` the chain ignores its draws, so constant zeros are
    # value-identical (same kernel, same trace) and cost nothing.
    hactive = _harvest_active(cha, ov)
    hu0 = None if hactive else jnp.zeros((chunk, n_real), jnp.float32)

    def dispatch(k):
        nonlocal state
        sw, au, jit_ns, svc = _ts_draws_jit(cha, ov, lane_r,
                                            jnp.asarray(ckeys[k]),
                                            chunk=chunk)
        hu = (_ts_harvest_u_jit(lane_r, jnp.asarray(ckeys[k]), chunk=chunk)
              if hactive else hu0)
        state, flat = fn(terms, state, sw, au, jit_ns, svc, hu,
                         jnp.asarray(record[k * chunk:(k + 1) * chunk]))
        return flat

    return _accumulate_chunks(dispatch, n_chunks, n_tot)[:n_real]


def _run_event(cha, ov, steps, seed, warmup, events, ndev, n_real, pad,
               lane_r, chunk=None):
    n_tot = n_real + pad
    chunk = _event_chunk_len(n_real) if chunk is None else int(chunk)
    n_chunks = -(-events // chunk)
    n_sojourns = max(64, (n_chunks * chunk) // _SOJOURN_DIV)
    phase_key, chunk_root = jax.random.split(jax.random.PRNGKey(seed))
    keys = jax.random.split(chunk_root, n_chunks)
    tabs = _event_tables_jit(cha, ov, lane_r, phase_key,
                             n_sojourns=n_sojourns)
    terms = _scan_terms_jit(cha, ov)
    state_a = (jnp.zeros(n_real), jnp.zeros(n_real))
    W = jnp.zeros(n_tot)
    warm = jnp.float32(warmup)
    fn = _event_kernel(ndev, n_tot, n_real, chunk)
    # Harvest windows: a second sojourn table from the salted stream and
    # a standalone scaling pass between the stages -- both skipped
    # entirely when harvesting is inactive, so the unharvested event
    # path runs the exact pre-harvest executables.
    hactive = _harvest_active(cha, ov)
    if hactive:
        htabs = _event_harvest_tabs_jit(cha, ov, lane_r, phase_key,
                                        n_windows=n_sojourns)
        h_scale = _harvest_scan_terms_jit(cha, ov)["h_scale"]

    def dispatch(k):
        nonlocal state_a, W
        t_prev = state_a[1]
        state_a, gaps, svc, rec_time = _event_arrivals_jit(
            cha, ov, state_a, lane_r, keys[k], tabs, warm, chunk=chunk)
        if hactive:
            svc = _event_harvest_scale_jit(svc, gaps, t_prev, htabs,
                                           h_scale)
        W, flat = fn(terms, W, gaps, svc, rec_time)
        return flat

    hist = _accumulate_chunks(dispatch, n_chunks, n_tot)[:n_real]
    # Jitter is additive observation noise: convolve its exact uniform
    # distribution into the histogram (per-lane effective width).
    width = np.where(np.isnan(np.asarray(ov["service_jitter_ns"])[:n_real]),
                     np.asarray(cha.service_jitter_ns)[:n_real],
                     np.asarray(ov["service_jitter_ns"])[:n_real])
    return _convolve_jitter(hist, width)


def merge_reps(stats: LatencyStats) -> LatencyStats:
    """Merge a ``keep_reps=True`` result over its leading replica axis.

    Histogram counts are integers, so merging after the fact is exactly
    the ``keep_reps=False`` result.
    """
    return _stats_from_hist(stats.hist.sum(axis=0))


def simulate_cells(cha: ChannelArrays, *, overrides=None,
                   steps: int = 200_000, seed: int = 0,
                   warmup: int | None = None, reps: int = 1,
                   engine: str = "timestep", events: int | None = None,
                   devices=None, keep_reps: bool = False,
                   stream_ids=None, chunk: int | None = None
                   ) -> LatencyStats:
    """Simulate N flattened cells in one jitted batch.

    ``cha`` leaves are ``(N,)``; ``overrides`` maps channel fields to
    ``(N,)`` arrays with NaN meaning "keep the channel's own value".
    Missing override fields are filled with NaN so the jit cache keys on
    the flattened cell count alone -- any axis combination of the same
    flattened size shares one compile per engine (and device count).

    ``steps`` is the simulated-time budget in ns for EITHER engine;
    ``engine="event"`` converts it to a per-request budget
    (:func:`events_for_steps`) unless ``events`` pins one explicitly.
    ``warmup`` ns of simulated time (default ``steps // 10``) are
    excluded from the histograms.  ``reps`` runs that many independent
    replicas of every cell in the same batch and merges their histograms
    -- variance reduction that costs almost nothing next to the per-step
    (or per-request) dispatch; ``keep_reps=True`` skips the merge and
    returns stats with a leading ``(reps,)`` axis instead (per-replica
    batched means, e.g. for standard-error estimates -- see
    :func:`merge_reps`).

    ``devices`` shards the flattened ``(cells x reps)`` lane axis over
    that many host devices (``None`` consults ``$REPRO_DES_DEVICES``,
    default 1; ``"auto"`` uses all local devices).  The batch is
    NaN-padded to a multiple of the device count; lane-keyed streams and
    global-lane histogram slots make the result BIT-IDENTICAL at any
    device count -- the knob trades wall-clock only.

    Results are exactly reproducible per ``(engine, seed, budget, N)``;
    the two engines draw different streams and agree statistically, not
    bitwise.

    ``stream_ids`` (an ``(N,)`` uint32 array) replaces the positional
    lane-stream keying with CALLER-OWNED ids, and ``chunk`` pins the
    chunk schedule independently of the batch width (see
    :func:`canonical_chunk`).  Together they make a cell's histogram a
    pure function of ``(its channel values, its stream id, seed, budget,
    engine)`` -- independent of which OTHER cells share the batch -- the
    contract the QueueLUT store's incremental builds are built on
    (``tests/test_lutstore.py`` pins it bitwise).  Both default to the
    historical positional/adaptive behavior.
    """
    _check_engine(engine)
    n = int(np.shape(cha.rho)[0])
    reps = int(reps)
    if reps < 1:
        raise ValueError(f"reps must be >= 1; got {reps}")
    warmup = default_warmup(steps) if warmup is None else int(warmup)
    if not 0 <= warmup < steps:
        raise ValueError(f"warmup must be in [0, steps); got {warmup} "
                         f"with steps={steps}")
    if events is not None and engine != "event":
        raise ValueError("events is an event-engine budget; use steps "
                         "for the timestep engine")
    ndev = shardsim.resolve_devices(devices)
    n_real = n * reps
    # cha/ov stay at the UNPADDED width: stage A and the per-run terms
    # compile against n_real only, so their executables (and hence every
    # transcendental rounding) are shared across device counts; stage B
    # pads its inputs to the device multiple internally.
    pad = shardsim.pad_width(n_real, ndev)

    def tile(v):
        return jnp.tile(jnp.asarray(np.asarray(v, np.float32)), reps)

    ov = _nan_overrides(n_real)
    ov.update({f: tile(v) for f, v in (overrides or {}).items()})
    cha = ChannelArrays(*(tile(leaf) for leaf in cha))
    lane_r = _lane_streams(n, reps, stream_ids)
    if chunk is not None and int(chunk) < 1:
        raise ValueError(f"chunk must be >= 1; got {chunk}")
    if engine == "timestep":
        hist = _run_timestep(cha, ov, int(steps), seed, warmup,
                             ndev, n_real, pad, lane_r, chunk)
    else:
        events = (events_for_steps(steps) if events is None
                  else max(1, int(events)))
        hist = _run_event(cha, ov, int(steps), seed, warmup, events,
                          ndev, n_real, pad, lane_r, chunk)
    hist = hist.reshape(reps, n, -1)
    if keep_reps:
        return _stats_from_hist(hist)
    return _stats_from_hist(hist.sum(axis=0))


def simulate(configs, steps: int = 200_000, seed: int = 0,
             warmup: int | None = None, reps: int = 1,
             engine: str = "timestep", devices=None) -> LatencyStats:
    """Simulate a batch of :class:`ChannelConfig` and return stats.

    Thin shim over :func:`simulate_cells` -- bit-identical to any
    distribution sweep whose flat cells match ``configs`` in order (same
    engine, seed, steps, warmup and reps => same random streams, at any
    ``devices``).
    """
    return simulate_cells(stack_channels(configs), steps=steps, seed=seed,
                          warmup=warmup, reps=reps, engine=engine,
                          devices=devices)


def load_latency_curve(rhos=None, kappa: float = 1.0, cxl_lat_ns: float = 0.0,
                       steps: int = 200_000, seed: int = 0,
                       warmup: int | None = None, reps: int = 1,
                       engine: str = "timestep", devices=None) -> dict:
    """Fig 2a: mean/p90 latency vs bus utilization for one channel type."""
    if rhos is None:
        rhos = np.linspace(0.05, 0.95, 19)
    configs = [ChannelConfig(rho=float(r), kappa=kappa,
                             cxl_lat_ns=cxl_lat_ns) for r in rhos]
    stats = simulate(configs, steps=steps, seed=seed, warmup=warmup,
                     reps=reps, engine=engine, devices=devices)
    return dict(rho=np.asarray(rhos), mean_ns=stats.mean_ns,
                p90_ns=stats.p90_ns, p99_ns=stats.p99_ns,
                stdev_ns=stats.stdev_ns)
