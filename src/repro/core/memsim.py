"""Mechanistic discrete-event simulator of a channelized memory system.

This is the DRAMsim-ish half of the reproduction: where ``queueing.py`` is a
*calibrated closed form*, memsim is an *independent mechanism* -- a
time-stepped (1 ns) simulation of request arrivals, FIFO bus queues, DRAM
service and CXL interface delays -- implemented as one ``jax.lax.scan`` and
``vmap``-ed over an arbitrary batch of channel configurations.  It produces
full latency *distributions* (mean / p50 / p90 / p99 / stdev / CDF), which
back Fig 2a's load-latency curve and Fig 6b's CDF comparison.

Model per channel:
  * arrivals: two-state MMPP (burst/idle) Bernoulli process per ns; the
    burst-state rate is ``kappa`` times the average, idle fills the rest;
  * closed loop: a finite in-flight population ``outstanding`` (MSHR/ROB
    bound per channel) gates ADMISSION -- while the backlog exceeds
    ``outstanding * t_xfer_ns`` of queued work the cores' miss buffers
    are full, so no new request enters the queue (the core stalls
    instead).  Admitted requests keep their true heavy-tailed waits; what
    the bound removes is exactly the paper's §3.1 closed-loop effect, the
    open-loop hyperbola detaching from what a finite machine can observe.
    The default is unbounded (``inf``), which reproduces the open-loop
    simulator bit for bit; ``core/queuelut.py`` sweeps this axis to build
    the closed-loop wait surface ``cpu_model`` consumes;
  * service: the channel serializes one 64B line per ``t_xfer`` ns *on
    average* (38.4 GB/s -> 1.67 ns), but the effective per-request service
    is heavy-tailed: with small probability the controller blocks for a
    two-slope power-law (truncated-Pareto) duration spanning the
    bank-conflict / turnaround-train scale (tens of ns) through tFAW
    windows up to refresh (tRFC, ~1 us).  The blocking-size law is what
    the paper's own Fig-2a closed forms demand: inverting mean and p90
    through Pollaczek-Khinchine yields a service-excess tail
    P(S > w) ~ w**-1.8.  Calibration keeps E[S] = t_xfer (so rho keeps
    its meaning as bus utilization) and matches the M/G/1 mean-wait
    anchor W(0.5) ~= 80 ns
    (``coaxial.validate_calibration`` checks mean AND p90 per anchor);
  * DRAM access: base latency plus uniform bank/row-state jitter;
  * CXL: a fixed interface premium plus the link-traversal time.

Every calibration constant is also a per-channel *field* of
:class:`ChannelConfig` / :class:`ChannelArrays` (the module-level constants
are just the defaults), so any of them can be a named sweep axis:
``sweepspec.distribution_spec(rho=..., kappa=..., stall_ns=...)`` lowers to
ONE jitted scan over the flattened cell batch, with NaN-masked overrides
applied branch-free in-trace exactly like ``cpu_model``'s design overrides.

The first ``warmup`` ns (default ``steps // 10``) are excluded from the
histogram: the simulation starts with an empty queue, so without a warmup
window the cold-start transient biases means and low-rho quantiles down.

All randomness is threefry-derived from an explicit seed: runs are exactly
reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw

#: Histogram binning for latency distributions.
BIN_NS = 4.0
N_BINS = 1024         # covers 0 .. 4096 ns

#: DRAM access latency jitter (bank/row-buffer state), uniform half-width.
SERVICE_JITTER_NS = 13.5
#: Fraction of time the MMPP spends in the burst state.
BURST_DUTY = 0.3
#: Mean sojourn time in each MMPP state (ns).
BURST_SOJOURN_NS = 2000.0
#: Controller blocking episodes (the heavy service tail): with probability
#: ``STALL_PROB`` per request the controller blocks for a two-slope
#: power-law (Pareto) duration -- slope ``STALL_ALPHA`` from the
#: bank-conflict scale (``STALL_NS``) to the tFAW / turnaround-train
#: scale (``STALL_BREAK_NS``), rolling off at slope ``STALL_ALPHA2`` out
#: to the refresh/tRFC scale, capped at ``STALL_MAX_NS``.  The power law
#: is not a modeling whim: inverting the paper's two Fig-2a closed forms
#: (mean 40 + 80x, p90 40 + 148*x**1.232, x = rho/(1-rho)) through the
#: M/G/1 Pollaczek-Khinchine relation forces the service-excess tail to
#: follow P(S > w) ~ w**-1.8 across the whole 30..800 ns range -- a
#: straight line in log-log that no small discrete mixture can track --
#: and the exact stationary solve of the simulator's own Lindley chain
#: fixes the two slopes so that multi-event compounding lands the DES on
#: BOTH closed forms at every load anchor (mean within ~9%, p90 within
#: ~12%, for rho in [0.1, 0.8]).  E[S] stays exactly t_xfer (so rho
#: keeps its meaning as bus utilization) and E[S^2] ~= 267 ns^2 keeps
#: the mean-wait anchor W(0.5) ~= 80 ns;
#: ``coaxial.validate_calibration`` pins mean AND p90 per anchor.
STALL_PROB = 0.01923
STALL_NS = 37.0
STALL_ALPHA = 2.138
STALL_BREAK_NS = 353.6
STALL_ALPHA2 = 1.3495
STALL_MAX_NS = 1903.7
#: Floor on the non-penalized per-request service time (ns).
MIN_SERVICE_NS = 0.05

#: Default warmup fraction: the leading ``steps // WARMUP_DIV`` ns are
#: simulated but not recorded.
WARMUP_DIV = 10


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """One simulated memory channel configuration.

    Every field -- the operating point AND the calibration constants -- is
    sweepable; the module-level constants are only defaults.
    """

    rho: float                  # target bus utilization, 0..~0.95
    kappa: float = 1.0          # burst peak-to-mean arrival ratio
    #: In-flight request population per channel (MSHR/ROB bound); arrivals
    #: are blocked while the backlog holds more than
    #: ``outstanding * t_xfer_ns`` of queued work.  ``inf`` = open loop.
    outstanding: float = float("inf")
    t_xfer_ns: float = hw.CACHE_LINE_B / hw.DDR5_CH_BW_GBPS
    service_ns: float = hw.DRAM_SERVICE_NS - 2.0   # pipelined access part
    cxl_lat_ns: float = 0.0     # CXL interface premium (0 => direct DDR)
    burst_duty: float = BURST_DUTY
    burst_sojourn_ns: float = BURST_SOJOURN_NS
    stall_prob: float = STALL_PROB
    stall_ns: float = STALL_NS
    stall_alpha: float = STALL_ALPHA
    stall_break_ns: float = STALL_BREAK_NS
    stall_alpha2: float = STALL_ALPHA2
    stall_max_ns: float = STALL_MAX_NS
    service_jitter_ns: float = SERVICE_JITTER_NS


class ChannelArrays(NamedTuple):
    """Pytree of per-channel simulation parameters, ``(N,)`` float leaves.

    Mirrors :class:`cpu_model.MemSystemArrays`: :class:`ChannelConfig` is
    the frozen-dataclass façade for humans, this is what the jitted scan
    consumes -- one leading cell axis shared by every leaf, so any named-
    axis grid flattens to one batch.
    """

    rho: jnp.ndarray
    kappa: jnp.ndarray
    outstanding: jnp.ndarray
    t_xfer_ns: jnp.ndarray
    service_ns: jnp.ndarray
    cxl_lat_ns: jnp.ndarray
    burst_duty: jnp.ndarray
    burst_sojourn_ns: jnp.ndarray
    stall_prob: jnp.ndarray
    stall_ns: jnp.ndarray
    stall_alpha: jnp.ndarray
    stall_break_ns: jnp.ndarray
    stall_alpha2: jnp.ndarray
    stall_max_ns: jnp.ndarray
    service_jitter_ns: jnp.ndarray


#: Channel fields a distribution-sweep axis may bind (all of them).
CHANNEL_FIELDS = ChannelArrays._fields


def stack_channels(configs) -> ChannelArrays:
    """Stack :class:`ChannelConfig` façades into one ``(N,)``-leaved pytree."""
    return ChannelArrays(*(
        jnp.asarray([float(getattr(c, f)) for c in configs], jnp.float32)
        for f in CHANNEL_FIELDS))


def _apply_channel_overrides(cha: ChannelArrays, ov) -> ChannelArrays:
    """NaN-masked per-field substitution, applied branch-free in-trace."""
    return cha._replace(**{
        f: jnp.where(jnp.isnan(v), getattr(cha, f), v)
        for f, v in ov.items()})


#: Number of times the jitted simulator has been TRACED (not called).  A
#: trace only happens on a new (cell count, steps) pair, so a whole
#: named-axis distribution grid bumps this by exactly one; tests pin that.
_TRACE_COUNT = [0]


def sim_trace_count() -> int:
    return _TRACE_COUNT[0]


def _pareto_seg(ratio, a):
    """Per-unit-survival mean of one power-law segment.

    ``integral of (x0/x)**a from x0 to x1, divided by x0`` with
    ``ratio = x0/x1``: ``(1 - ratio**(a-1)) / (a-1)``, whose ``a -> 1``
    limit is ``-log(ratio)``.  Branch-free so a ``stall_alpha`` axis may
    sweep through 1.0 without the 0/0 turning the cell into silent NaN
    garbage.
    """
    d = a - 1.0
    near_one = jnp.abs(d) < 1e-4
    safe = jnp.where(near_one, 1.0, d)
    return jnp.where(near_one, -jnp.log(ratio), (1.0 - ratio ** safe) / safe)


def _sim_core(cha: ChannelArrays, ov, keys, record):
    """Run ``len(keys)`` ns for a batch of channels; return histograms.

    ``cha`` leaves are ``(N,)``; ``ov`` maps channel fields to ``(N,)``
    NaN-masked overrides (NaN = keep the channel's own value), applied
    inside the trace so the jit cache keys on the flattened cell count and
    step count alone.  ``record`` is a per-step 0/1 mask (the warmup
    window is simulated but not histogrammed).
    """
    _TRACE_COUNT[0] += 1  # side effect runs at trace time only
    c = _apply_channel_overrides(cha, ov)
    n = c.rho.shape[0]
    rate_avg = c.rho / c.t_xfer_ns               # arrivals per ns
    rate_hi = jnp.minimum(c.kappa * rate_avg, 0.98)
    # Rate in the idle state so the duty-weighted mean matches rate_avg.
    rate_lo = jnp.maximum(
        (rate_avg - c.burst_duty * rate_hi) / (1.0 - c.burst_duty), 0.0)
    p_leave = 1.0 / c.burst_sojourn_ns           # state-switch prob per ns
    # Duty-correct entry prob: stationary P(burst) = burst_duty.
    p_enter = p_leave * c.burst_duty / (1.0 - c.burst_duty)

    # Two-slope truncated-Pareto blocking durations.  Survival:
    # (sn/x)**a1 up to the break, then q_b * (xb/x)**a2, capped at the
    # max.  The capped mean (closed form, computed in-trace) lets s_small
    # absorb the blocking work so E[S] stays exactly t_xfer.
    sn, xb = c.stall_ns, c.stall_break_ns
    a1, a2, cap = c.stall_alpha, c.stall_alpha2, c.stall_max_ns
    q_b = (sn / xb) ** a1                    # survival at the break
    stall_mean = (sn + sn * _pareto_seg(sn / xb, a1) +
                  q_b * xb * _pareto_seg(xb / cap, a2))
    s_small = ((c.t_xfer_ns - c.stall_prob * stall_mean) /
               (1.0 - c.stall_prob))
    s_small = jnp.maximum(s_small, MIN_SERVICE_NS)

    def step(carry, xs):
        key, rec = xs
        backlog, in_burst, hist = carry
        # One fused threefry draw per step (fewer key derivations than
        # split-per-stream): rows are switch / arrival / jitter /
        # blocking-or-not / blocking size.
        switch_u, arrive_u, jitter_u, svc_u, size_u = \
            jax.random.uniform(key, (5, n))
        in_burst = jnp.where(
            in_burst > 0.5,
            jnp.where(switch_u < p_leave, 0.0, 1.0),
            jnp.where(switch_u < p_enter, 1.0, 0.0))
        rate = jnp.where(in_burst > 0.5, rate_hi, rate_lo)
        arrive = (arrive_u < rate).astype(jnp.float32)
        # Closed-loop population bound: while the backlog holds more than
        # ``outstanding`` requests' worth of work the MSHRs are full and
        # the core stalls instead of issuing -- the arrival is blocked,
        # not queued.  inf (the default) admits everything: open loop.
        arrive = arrive * (backlog <= c.outstanding * c.t_xfer_ns
                           ).astype(jnp.float32)
        jitter = (jitter_u * 2.0 - 1.0) * c.service_jitter_ns
        latency = backlog + c.service_ns + 2.0 + jitter + c.cxl_lat_ns
        bin_idx = jnp.clip((latency / BIN_NS).astype(jnp.int32), 0, N_BINS - 1)
        hist = hist.at[jnp.arange(n), bin_idx].add(arrive * rec)
        # Inverse-CDF sample of the two-slope law: the uniform IS the
        # survival value -- above q_b the first slope applies, below it
        # the far tail, capped at the max.
        u = jnp.maximum(size_u, 1e-7)
        stall = jnp.where(u > q_b, sn * u ** (-1.0 / a1),
                          xb * (q_b / u) ** (1.0 / a2))
        stall = jnp.minimum(stall, cap)
        svc = jnp.where(svc_u < c.stall_prob, stall, s_small)
        backlog = jnp.maximum(backlog + arrive * svc - 1.0, 0.0)
        return (backlog, in_burst, hist), None

    init = (jnp.zeros(n), jnp.ones(n), jnp.zeros((n, N_BINS)))
    (_, _, hist), _ = jax.lax.scan(step, init, (keys, record))
    return hist


_sim_jit = jax.jit(_sim_core)


@dataclasses.dataclass
class LatencyStats:
    """Latency-distribution summary; leaves share any leading cell/grid
    shape, with ``hist`` carrying one trailing bin axis."""

    mean_ns: np.ndarray
    stdev_ns: np.ndarray
    p50_ns: np.ndarray
    p90_ns: np.ndarray
    p99_ns: np.ndarray
    hist: np.ndarray            # (..., N_BINS) counts
    bin_ns: float = BIN_NS

    _ARRAY_FIELDS = ("mean_ns", "stdev_ns", "p50_ns", "p90_ns", "p99_ns",
                     "hist")

    def __getitem__(self, idx) -> "LatencyStats":
        """Slice the leading (cell/grid) axes of every leaf identically."""
        return LatencyStats(**{f: getattr(self, f)[idx]
                               for f in self._ARRAY_FIELDS},
                            bin_ns=self.bin_ns)

    def reshape(self, *grid_shape) -> "LatencyStats":
        """Reshape the leading axes; the histogram bin axis stays last."""
        shaped = {f: getattr(self, f).reshape(grid_shape)
                  for f in self._ARRAY_FIELDS if f != "hist"}
        shaped["hist"] = self.hist.reshape(tuple(grid_shape) +
                                           self.hist.shape[-1:])
        return LatencyStats(**shaped, bin_ns=self.bin_ns)

    def cdf(self, i=None) -> tuple[np.ndarray, np.ndarray]:
        """(latency_ns, cdf) arrays for cell ``i`` (Fig 6b).

        ``i`` may be omitted when the stats hold a single cell (``hist``
        is one-dimensional), e.g. after a fully pinned
        ``DistributionSweepResult.sel``.
        """
        h = self.hist if i is None else self.hist[i]
        if h.ndim != 1:
            raise ValueError(
                f"cdf() needs one cell; hist has shape {h.shape} -- "
                f"index a cell or sel() down to one")
        c = np.cumsum(h) / max(h.sum(), 1.0)
        x = (np.arange(h.shape[-1]) + 0.5) * self.bin_ns
        return x, c


def _stats_from_hist(hist: np.ndarray) -> LatencyStats:
    centers = (np.arange(hist.shape[-1]) + 0.5) * BIN_NS
    total = np.maximum(hist.sum(axis=-1, keepdims=True), 1.0)
    p = hist / total
    mean = (p * centers).sum(axis=-1)
    var = (p * (centers - mean[..., None]) ** 2).sum(axis=-1)
    cum = np.cumsum(p, axis=-1)

    def quantile(q):
        idx = np.argmax(cum >= q, axis=-1)
        return (idx + 0.5) * BIN_NS

    return LatencyStats(
        mean_ns=mean, stdev_ns=np.sqrt(var), p50_ns=quantile(0.5),
        p90_ns=quantile(0.9), p99_ns=quantile(0.99), hist=hist)


def default_warmup(steps: int) -> int:
    return steps // WARMUP_DIV


def _nan_overrides(n: int) -> dict:
    # Explicit dtype => strong-typed leaves, so the jit signature doesn't
    # depend on WHICH fields an axis binds (bound overrides are strong
    # float32 too) -- any axis combination of one size shares a compile.
    nans = jnp.full((n,), jnp.nan, jnp.float32)
    return {f: nans for f in CHANNEL_FIELDS}


def simulate_cells(cha: ChannelArrays, *, overrides=None,
                   steps: int = 200_000, seed: int = 0,
                   warmup: int | None = None, reps: int = 1) -> LatencyStats:
    """Simulate N flattened cells in one jitted scan.

    ``cha`` leaves are ``(N,)``; ``overrides`` maps channel fields to
    ``(N,)`` arrays with NaN meaning "keep the channel's own value".
    Missing override fields are filled with NaN so the jit cache keys on
    ``(N * reps, steps)`` alone -- any axis combination of the same
    flattened size and step count shares one compile.  ``warmup`` ns
    (default ``steps // 10``) are simulated but excluded from the
    histograms.  ``reps`` runs that many independent replicas of every
    cell in the same batch (the per-step uniforms are independent across
    lanes) and merges their histograms -- variance reduction that costs
    almost nothing, since the scan's step dispatch dominates over lane
    count.
    """
    n = int(np.shape(cha.rho)[0])
    reps = int(reps)
    if reps < 1:
        raise ValueError(f"reps must be >= 1; got {reps}")
    warmup = default_warmup(steps) if warmup is None else int(warmup)
    if not 0 <= warmup < steps:
        raise ValueError(f"warmup must be in [0, steps); got {warmup} "
                         f"with steps={steps}")
    tile = lambda v: jnp.tile(jnp.asarray(np.asarray(v, np.float32)), reps)
    ov = _nan_overrides(n * reps)
    ov.update({f: tile(v) for f, v in (overrides or {}).items()})
    cha = ChannelArrays(*(tile(leaf) for leaf in cha))
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    record = (jnp.arange(steps) >= warmup).astype(jnp.float32)
    hist = _sim_jit(cha, ov, keys, record)
    hist = np.asarray(hist, np.float64).reshape(reps, n, -1).sum(axis=0)
    return _stats_from_hist(hist)


def simulate(configs, steps: int = 200_000, seed: int = 0,
             warmup: int | None = None, reps: int = 1) -> LatencyStats:
    """Simulate a batch of :class:`ChannelConfig` and return stats.

    Thin shim over :func:`simulate_cells` -- bit-identical to any
    distribution sweep whose flat cells match ``configs`` in order (same
    seed, steps, warmup and reps => same threefry streams).
    """
    return simulate_cells(stack_channels(configs), steps=steps, seed=seed,
                          warmup=warmup, reps=reps)


def load_latency_curve(rhos=None, kappa: float = 1.0, cxl_lat_ns: float = 0.0,
                       steps: int = 200_000, seed: int = 0,
                       warmup: int | None = None, reps: int = 1) -> dict:
    """Fig 2a: mean/p90 latency vs bus utilization for one channel type."""
    if rhos is None:
        rhos = np.linspace(0.05, 0.95, 19)
    configs = [ChannelConfig(rho=float(r), kappa=kappa,
                             cxl_lat_ns=cxl_lat_ns) for r in rhos]
    stats = simulate(configs, steps=steps, seed=seed, warmup=warmup,
                     reps=reps)
    return dict(rho=np.asarray(rhos), mean_ns=stats.mean_ns,
                p90_ns=stats.p90_ns, p99_ns=stats.p99_ns,
                stdev_ns=stats.stdev_ns)
