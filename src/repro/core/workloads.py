"""The paper's 35 evaluated workloads (Table 4) + memory-behavior parameters.

Table 4 gives, per workload, the IPC and LLC MPKI measured on the DDR-based
baseline (12 OoO cores @ 2GHz, one DDR5-4800 channel).  Those two columns are
copied verbatim below and are the *calibration anchors* of the reproduction:
the CPU model (cpu_model.py) is constrained to reproduce them exactly on the
baseline configuration.

The remaining columns are behavioral parameters the paper describes
qualitatively (§3.1, §6.1, §6.2) but does not tabulate.  They are set from
suite-level defaults plus per-workload overrides wherever the paper gives
direct evidence:

  wb         write-back traffic per read (R:W ratios are 2:1-3:1 per §4.3;
             stream copy/scale are 1:1; kmeans has "near-zero write traffic").
  kappa      burst peak-to-mean arrival ratio (§6.2: bwaves is "bursty",
             incurring queuing spikes at only 32% average utilization).
  eta        bank/channel balance factor (§6.2: kmeans has an "even
             distribution of accesses over time and across DRAM banks";
             regular-strided workloads queue far less than random traffic).
  exec_frac  fraction of baseline CPI that is non-memory (used to calibrate
             the per-workload effective MLP; streaming kernels are ~all
             memory, pop2/raytrace are mostly compute).
  gamma      sensitivity of the stall per miss to latency *variance* (§3.2);
             high for dependent-access workloads ("heavy dependencies among
             memory accesses" is the paper's stated cause of regressions).
  ws_mb      approximate per-instance working set, for LLC-fit corner cases
             (§6.5: xalancbmk fits in the LLC when one instance runs).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    suite: str
    ipc: float        # Table 4, per-core IPC on the loaded DDR baseline
    mpki: float       # Table 4, LLC misses per kilo-instruction
    wb: float         # write-back bytes per read byte
    kappa: float      # burst peak-to-mean arrival-rate ratio (>= 1)
    eta: float        # bank/channel balance factor (<= 1)
    exec_frac: float  # non-memory share of baseline CPI
    gamma: float      # stall sensitivity to latency stdev
    pf_boost: float   # extra MLP from prefetchers when bandwidth is free
    ws_mb: float      # per-instance working set (MB)


def _w(name, suite, ipc, mpki, *, wb, kappa, eta, exec_frac, gamma,
       pf_boost=0.0, ws_mb=512.0):
    return Workload(name, suite, ipc, mpki, wb=wb, kappa=kappa, eta=eta,
                    exec_frac=exec_frac, gamma=gamma, pf_boost=pf_boost,
                    ws_mb=ws_mb)


# Suite defaults: (wb, kappa, eta, exec_frac, gamma)
_LIGRA = dict(wb=0.30, kappa=1.5, eta=0.85, exec_frac=0.20, gamma=0.35,
              pf_boost=0.8)
_SPEC = dict(wb=0.50, kappa=1.3, eta=0.80, exec_frac=0.45, gamma=0.40,
             pf_boost=1.0)
_PARSEC = dict(wb=0.40, kappa=1.6, eta=0.55, exec_frac=0.60, gamma=0.55,
               pf_boost=0.3)


WORKLOADS: tuple[Workload, ...] = (
    # --- Ligra graph analytics (12) -------------------------------------
    _w("pagerank", "ligra", 0.36, 40, **_LIGRA),
    _w("pagerank-delta", "ligra", 0.31, 27, **_LIGRA),
    _w("components-shortcut", "ligra", 0.34, 48, **_LIGRA),
    _w("components", "ligra", 0.36, 48, **_LIGRA),
    _w("bc", "ligra", 0.33, 34, **_LIGRA),
    _w("radii", "ligra", 0.41, 33, **_LIGRA),
    _w("bfscc", "ligra", 0.68, 17, **{**_LIGRA, "exec_frac": 0.30}),
    _w("bfs", "ligra", 0.69, 15, **{**_LIGRA, "exec_frac": 0.30}),
    _w("bfs-bitvector", "ligra", 0.84, 15, **{**_LIGRA, "exec_frac": 0.30}),
    _w("bellmanford", "ligra", 0.86, 9, **{**_LIGRA, "exec_frac": 0.35}),
    _w("triangle", "ligra", 0.65, 21, **{**_LIGRA, "exec_frac": 0.30}),
    _w("mis", "ligra", 1.37, 8, **{**_LIGRA, "exec_frac": 0.50,
                                   "gamma": 0.25}),
    # --- STREAM (4): independent streaming, MSHRs saturated --------------
    _w("stream-copy", "stream", 0.17, 58, wb=0.40, kappa=1.5, eta=1.0,
       exec_frac=0.05, gamma=0.05, pf_boost=1.5, ws_mb=4096),
    _w("stream-scale", "stream", 0.21, 48, wb=0.40, kappa=1.5, eta=1.0,
       exec_frac=0.05, gamma=0.05, pf_boost=1.5, ws_mb=4096),
    _w("stream-add", "stream", 0.16, 69, wb=0.33, kappa=1.5, eta=1.0,
       exec_frac=0.05, gamma=0.05, pf_boost=1.5, ws_mb=4096),
    _w("stream-triad", "stream", 0.18, 59, wb=0.33, kappa=1.5, eta=1.0,
       exec_frac=0.05, gamma=0.05, pf_boost=1.5, ws_mb=4096),
    # --- SPEC-speed 2017 (12) -------------------------------------------
    # lbm: stream-like, 91% of latency is queuing (paper §3.1/Fig 5).
    _w("lbm", "spec", 0.14, 64, wb=0.5, kappa=1.5, eta=1.0, exec_frac=0.05,
       gamma=0.05, pf_boost=1.5, ws_mb=2048),
    # bwaves: bursty -- ~390ns queuing at only ~32% utilization (§6.2).
    _w("bwaves", "spec", 0.33, 14, wb=0.5, kappa=3.2, eta=1.0,
       exec_frac=0.30, gamma=0.20, pf_boost=1.0),
    _w("cactusbssn", "spec", 0.68, 8, **{**_SPEC, "exec_frac": 0.50,
                                         "gamma": 0.30}),
    _w("fotonik3d", "spec", 0.33, 22, **{**_SPEC, "wb": 0.6, "eta": 0.9,
                                         "exec_frac": 0.25, "gamma": 0.20,
                                         "pf_boost": 1.5}),
    _w("cam4", "spec", 0.87, 6, **{**_SPEC, "exec_frac": 0.60}),
    _w("wrf", "spec", 0.61, 11, **_SPEC),
    # mcf / omnetpp / xalancbmk: pointer-heavy, dependence-dominated.
    _w("mcf", "spec", 0.793, 13, wb=0.3, kappa=1.3, eta=0.7, exec_frac=0.50,
       gamma=0.55, pf_boost=0.0),
    _w("roms", "spec", 0.783, 6, **{**_SPEC, "exec_frac": 0.55}),
    _w("pop2", "spec", 1.55, 3, **{**_SPEC, "exec_frac": 0.70}),
    _w("omnetpp", "spec", 0.51, 10, wb=0.3, kappa=1.3, eta=0.6,
       exec_frac=0.50, gamma=0.60, pf_boost=0.0),
    _w("xalancbmk", "spec", 0.55, 12, wb=0.3, kappa=1.3, eta=0.6,
       exec_frac=0.50, gamma=0.50, pf_boost=0.0, ws_mb=10.0),
    # gcc: low-moderate traffic + heavy dependencies -> worst regression.
    _w("gcc", "spec", 0.31, 19, wb=0.3, kappa=1.0, eta=0.10,
       exec_frac=0.05, gamma=0.65, pf_boost=0.0),
    # --- PARSEC (5) -------------------------------------------------------
    _w("fluidanimate", "parsec", 0.78, 7, **_PARSEC),
    _w("facesim", "parsec", 0.74, 6, **_PARSEC),
    _w("raytrace", "parsec", 1.17, 5, **{**_PARSEC, "exec_frac": 0.65,
                                         "gamma": 0.40}),
    # streamcluster: mean 69ns / stdev 88ns baseline; 76/76 on COAXIAL
    # (§6.2) -- balanced-ish mean but bank-imbalance variance.
    _w("streamcluster", "parsec", 0.99, 14, wb=0.40, kappa=1.0, eta=0.05,
       exec_frac=0.35, gamma=0.80, pf_boost=0.5),
    _w("canneal", "parsec", 0.66, 7, **{**_PARSEC, "eta": 0.6,
                                        "exec_frac": 0.50, "gamma": 0.5}),
    # --- KVS & data analytics (2) ----------------------------------------
    _w("masstree", "kvs", 0.37, 21, wb=0.30, kappa=1.6, eta=0.85,
       exec_frac=0.40, gamma=0.50, pf_boost=0.0),
    # kmeans: highest utilization yet ~50ns queuing; near-zero writes (§6.2).
    _w("kmeans", "kvs", 0.50, 36, wb=0.05, kappa=1.0, eta=0.13,
       exec_frac=0.30, gamma=0.15, pf_boost=1.5, ws_mb=2048),
)


NAMES = tuple(w.name for w in WORKLOADS)
SUITES = tuple(sorted({w.suite for w in WORKLOADS}))

#: Behavioral parameters a sweep axis may bind (every float field of
#: :class:`WorkloadArrays`); ``name`` is identity, not a parameter.
SWEEPABLE_FIELDS = ("ipc", "mpki", "wb", "kappa", "eta", "exec_frac",
                    "gamma", "pf_boost", "ws_mb")

# ---------------------------------------------------------------------------
# Workload registry.  Seeded with the paper's Table-4 workloads; derived
# workloads (e.g. repro.serving's LLM-decode demand vectors) register at
# runtime and flow into every registry-backed sweep, exactly like
# coaxial's design registry.  ``WORKLOADS`` stays the immutable Table-4
# calibration set; ``all_workloads()`` is the live view.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, "Workload"] = {w.name: w for w in WORKLOADS}


def _registry_changed():
    """Invalidate caches keyed on the registry (lazy import: coaxial
    imports this module)."""
    import sys
    coaxial = sys.modules.get("repro.core.coaxial")
    if coaxial is not None:
        coaxial.default_sweep.cache_clear()


def register_workload(w: Workload, *, overwrite: bool = False) -> Workload:
    """Add a workload to the registry (and to every future registry-backed
    sweep).

    Re-registering the SAME workload is an idempotent no-op (the
    existing entry is returned, caches stay warm); a *different*
    workload under an existing name -- Table-4 seeds included -- raises
    unless ``overwrite``.
    """
    prev = _REGISTRY.get(w.name)
    if prev is not None:
        if prev == w:
            return prev
        if not overwrite:
            raise ValueError(f"workload {w.name!r} already registered "
                             f"with different parameters")
    _REGISTRY[w.name] = w
    _registry_changed()
    return w


def unregister_workload(name: str) -> Workload:
    """Remove a registered workload (Table-4 seeds may be removed too;
    re-import the module to restore them)."""
    w = _REGISTRY.pop(name)
    _registry_changed()
    return w


def all_workloads() -> tuple[Workload, ...]:
    """All registered workloads, registration-ordered (Table 4 first)."""
    return tuple(_REGISTRY.values())


def by_name(name: str) -> Workload:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; "
                       f"known: {sorted(_REGISTRY)}") from None


@dataclasses.dataclass(frozen=True)
class WorkloadArrays:
    """Structure-of-arrays view for vectorized evaluation."""

    name: tuple
    ipc: np.ndarray
    mpki: np.ndarray
    wb: np.ndarray
    kappa: np.ndarray
    eta: np.ndarray
    exec_frac: np.ndarray
    gamma: np.ndarray
    pf_boost: np.ndarray
    ws_mb: np.ndarray

    def __len__(self):
        return len(self.name)


jax.tree_util.register_dataclass(
    WorkloadArrays,
    data_fields=["ipc", "mpki", "wb", "kappa", "eta", "exec_frac", "gamma",
                 "pf_boost", "ws_mb"],
    meta_fields=["name"],
)


def as_arrays(workloads=WORKLOADS) -> WorkloadArrays:
    f = lambda attr: np.array([getattr(w, attr) for w in workloads], np.float64)
    return WorkloadArrays(
        name=tuple(w.name for w in workloads),
        ipc=f("ipc"), mpki=f("mpki"), wb=f("wb"), kappa=f("kappa"),
        eta=f("eta"), exec_frac=f("exec_frac"), gamma=f("gamma"),
        pf_boost=f("pf_boost"), ws_mb=f("ws_mb"),
    )
