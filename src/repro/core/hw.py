"""Hardware constants for the COAXIAL reproduction and the TPU adaptation.

Two worlds live here:

1. The paper's world (DDR5 / PCIe5 / CXL server memory systems, §2, §4, §5).
   All numbers are lifted directly from the paper text and its Tables 1-3.

2. The TPU v5e world used by the roofline analysis and the queue-aware
   sharding planner (the paper's insight, transplanted: trade a fixed
   interface-latency premium for channel-level bandwidth parallelism).
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Paper world: DDR5 / CXL (§2, §4.1, §5 "CXL performance modeling")
# ---------------------------------------------------------------------------

#: DDR5-4800 peak channel bandwidth, GB/s (paper §2.3, Table 3).
DDR5_CH_BW_GBPS = 38.4
#: Approximate unloaded DRAM access latency, ns (paper §3.1: "approximated
#: unloaded latency of 40ns").
DRAM_SERVICE_NS = 40.0
#: Cache line size, bytes.
CACHE_LINE_B = 64
#: Simulated core clock, GHz (Table 3).
CORE_CLK_GHZ = 2.0
#: Cores in the scaled-down simulated system (Table 3).
SIM_CORES = 12
#: Per-core MSHR-ish bound on outstanding misses (256-entry ROB, Table 3).
MAX_MLP = 16.0

#: Processor pins per interface (paper §2.3, §4.1).
DDR5_PINS = 160
PCIE_PINS_PER_LANE = 4
PCIE_X8_PINS = 8 * PCIE_PINS_PER_LANE  # 32
#: PCIe 5.0 x8 peak bandwidth PER DIRECTION, GB/s (paper §2.3: the 4x
#: bandwidth-per-pin argument uses this against DDR's combined figure).
PCIE_X8_GBPS_PER_DIR = 32.0

#: Relative silicon area at TSMC 7nm (paper Table 1, rel. to 1MB L3).
AREA_L3_PER_MB = 1.0
AREA_ZEN3_CORE = 6.5
AREA_PCIE_X8 = 5.9
AREA_DDR_CH = 10.8

#: CXL x8 link goodput after PCIe/CXL header overheads (paper §4.1, §5).
CXL_X8_RD_GBPS = 26.0
CXL_X8_WR_GBPS = 13.0
#: CXL-asym (20RX/12TX repurposing of the same 32 pins, §4.3).
CXL_ASYM_RD_GBPS = 32.0
CXL_ASYM_WR_GBPS = 10.0
#: Link traversal latencies, ns (paper §5): x8 is 2.5/5.5 RX/TX,
#: asym is 2/9 RX/TX.  Port adds 12ns per direction.
CXL_PORT_NS_PER_DIR = 12.0
CXL_X8_LINK_RX_NS = 2.5
CXL_X8_LINK_TX_NS = 5.5
CXL_ASYM_LINK_RX_NS = 2.0
CXL_ASYM_LINK_TX_NS = 9.0
#: Default end-to-end CXL interface latency premium, ns (paper §2.4, §5:
#: "minimum latency overhead of about 30ns"), and the pessimistic
#: sensitivity point (§6.4).
CXL_LAT_NS = 30.0
CXL_LAT_PESSIMISTIC_NS = 50.0

#: Power model constants (paper §6.6, Table 5).
PKG_POWER_W = 500.0
DDR_MC_PHY_W_PER_CH = 13.0 / 12.0       # baseline: 13W for 12 channels
PCIE_LANE_POWER_W = 0.2                  # per lane, PCIe 5.0 [4]
#: DIMM power, per DDR5 channel: P = static + dynamic * utilization.  The
#: two coefficients are fitted to the paper's own two anchor points
#: (200W @ 52% util on 12 ch; 551W @ 21% util on 48 ch) -- see DESIGN.md.
DIMM_STATIC_W_PER_CH = 7.97
DIMM_DYN_W_PER_CH = 16.74

# ---------------------------------------------------------------------------
# TPU v5e world (roofline + planner).
# ---------------------------------------------------------------------------

#: Peak bf16 matmul throughput per chip, FLOP/s.
TPU_PEAK_FLOPS = 197e12
#: HBM bandwidth per chip, bytes/s.
TPU_HBM_BW = 819e9
#: ICI bandwidth per link, bytes/s (~50 GB/s/link).
TPU_ICI_BW_PER_LINK = 50e9
#: ICI links per chip on a 2D torus mesh (v5e).
TPU_ICI_LINKS = 4
#: One-hop ICI latency, seconds (the "CXL premium" of the TPU world).
TPU_ICI_HOP_S = 1e-6
#: HBM capacity per chip, bytes (v5e: 16 GiB).
TPU_HBM_BYTES = 16 * 1024**3
#: VMEM per core, bytes (v5e ~128 MiB VMEM across the chip; per-core budget
#: used to size Pallas BlockSpecs conservatively).
TPU_VMEM_BYTES = 64 * 1024**2


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    """Roofline-relevant description of one TPU chip + its mesh links."""

    peak_flops: float = TPU_PEAK_FLOPS
    hbm_bw: float = TPU_HBM_BW
    ici_bw_per_link: float = TPU_ICI_BW_PER_LINK
    ici_links: int = TPU_ICI_LINKS
    ici_hop_s: float = TPU_ICI_HOP_S
    hbm_bytes: int = TPU_HBM_BYTES

    @property
    def ici_bw(self) -> float:
        """Aggregate injection bandwidth of one chip, bytes/s."""
        return self.ici_bw_per_link * self.ici_links


TPU_V5E = TpuSpec()
