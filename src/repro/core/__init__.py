"""COAXIAL core: the paper's contribution as a composable JAX library.

Submodules:
  hw         -- hardware constants (paper world + TPU v5e world)
  queueing   -- calibrated load->latency models (Fig 2a) + closed-form
                anchors for the DES cross-check
  memsim     -- mechanistic discrete-event memory simulator (lax.scan);
                every ChannelConfig field is a named sweep axis
  workloads  -- Table 4's 35 workloads + behavioral parameters
  cpu_model  -- fixed-point loaded-CPU model (the ChampSim stand-in)
  sweepspec  -- named-axis sweep specs (cpu + memsim lowering)
  coaxial    -- design points, evaluation engine, distribution sweeps,
                DES<->closed-form validation, EDP/area reports
  planner    -- the TPU adaptation: queue-aware channelized-sharding planner
"""
