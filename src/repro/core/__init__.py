"""COAXIAL core: the paper's contribution as a composable JAX library.

Submodules:
  hw         -- hardware constants (paper world + TPU v5e world)
  queueing   -- calibrated load->latency models (Fig 2a)
  memsim     -- mechanistic discrete-event memory simulator (lax.scan)
  workloads  -- Table 4's 35 workloads + behavioral parameters
  cpu_model  -- fixed-point loaded-CPU model (the ChampSim stand-in)
  coaxial    -- design points, evaluation engine, EDP/area reports
  planner    -- the TPU adaptation: queue-aware channelized-sharding planner
"""
