"""Measured CXL-device design points (Demystifying CXL Memory, 2303.15375).

The paper's Table-2 designs assume an *idealized* CXL premium: the spec's
~30 ns floor (or the 50 ns pessimistic point of §6.4).  Genuine CXL-ready
devices measured by "Demystifying CXL Memory with Genuine CXL-Ready
Systems and Devices" (arXiv 2303.15375) sit well above that floor:
ASIC-controller type-3 devices add on the order of 70-150 ns end-to-end
over a direct DDR access, FPGA-based prototypes 170-250 ns, and the
sustained per-device bandwidth is bounded by the device controller (low
tens of GB/s), not the x8/x16 link.

This module registers those measured profiles as named design points
*beside* the idealized ones, each in the coaxial-4x topology (4 links,
4 DRAM channels behind them, 1 MB LLC/core) so the only thing that
changes design-to-design is the measured latency/bandwidth profile --
"what does the COAXIAL argument look like on hardware you can buy today",
the ROADMAP 4c question.  The numbers are rounded mid-range anchors of
the paper's measured envelopes, not vendor datasheet values:

  ``cxl-dev-a``  ASIC controller + DDR5 back end: +85 ns premium,
                 26/13 GB/s per-link read/write goodput (link-class,
                 controller keeps up).
  ``cxl-dev-b``  ASIC controller + DDR4 back end: +135 ns premium,
                 21/10.5 GB/s (controller-bound below the link).
  ``cxl-dev-c``  FPGA-based prototype: +170 ns premium, 13/6.5 GB/s
                 (soft-logic controller dominates).

Registration is explicit (:func:`register_measured_devices`), matching
the registry idiom -- "configs and the planner register additional
points at runtime" -- so the idealized Table-2 test pins stay exact
unless a caller opts the measured points in.  ``benchmarks/
drift_headline.py`` registers them for its sweep (one drift row per
device), and ``repro.serving``'s capacity planner includes them in its
candidate set, so "minimum-area design meeting the SLO" is answered over
buildable points, not just idealized ones.
"""

from __future__ import annotations

from repro.core import hw
from repro.core.cpu_model import COAXIAL_4X, MemSystem

#: Measured-profile design points (see module docstring for provenance).
MEASURED_DEVICES: tuple[MemSystem, ...] = (
    MemSystem(
        "cxl-dev-a", dram_channels=4, links=4,
        link_rd_gbps=hw.CXL_X8_RD_GBPS, link_wr_gbps=hw.CXL_X8_WR_GBPS,
        iface_lat_ns=85.0, llc_mb_per_core=1.0,
        rel_area=COAXIAL_4X.rel_area, rel_pins=COAXIAL_4X.rel_pins),
    MemSystem(
        "cxl-dev-b", dram_channels=4, links=4,
        link_rd_gbps=21.0, link_wr_gbps=10.5,
        iface_lat_ns=135.0, llc_mb_per_core=1.0,
        rel_area=COAXIAL_4X.rel_area, rel_pins=COAXIAL_4X.rel_pins),
    MemSystem(
        "cxl-dev-c", dram_channels=4, links=4,
        link_rd_gbps=13.0, link_wr_gbps=6.5,
        iface_lat_ns=170.0, llc_mb_per_core=1.0,
        rel_area=COAXIAL_4X.rel_area, rel_pins=COAXIAL_4X.rel_pins),
)

MEASURED_NAMES = tuple(d.name for d in MEASURED_DEVICES)


def register_measured_devices(*, overwrite: bool = False) -> tuple:
    """Add every measured-device point to the coaxial design registry.

    Returns the registered points.  Already-registered names are left
    alone unless ``overwrite`` (idempotent opt-in)."""
    from repro.core import coaxial
    out = []
    registered = {d.name for d in coaxial.all_designs()}
    for d in MEASURED_DEVICES:
        if d.name in registered and not overwrite:
            out.append(coaxial.get_design(d.name))
            continue
        out.append(coaxial.register_design(d, overwrite=overwrite))
    return tuple(out)


def unregister_measured_devices() -> None:
    """Remove every measured-device point from the registry (no-op for
    names that are not currently registered)."""
    from repro.core import coaxial
    registered = {d.name for d in coaxial.all_designs()}
    for name in MEASURED_NAMES:
        if name in registered:
            coaxial.unregister_design(name)
