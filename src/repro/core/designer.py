"""Projected-gradient-ascent co-design under an area budget and p99 SLO.

ROADMAP open item 3, closed: instead of sweeping a grid and eyeballing
the Pareto plot, :func:`optimize_design` *returns* the design.  It

1. solves the channels x LLC frontier grid under ``queue_model="memsim"``
   (the DES-derived :class:`~repro.core.queuelut.QueueLUT` carries the
   p99-wait table, so every cell has a mechanistic tail),
2. ranks the frontier tail-aware -- ``SweepResult.pareto(tail=True)``
   orders by (area, mean speedup, p99) -- and starts at the knee of the
   within-budget subset (:func:`coaxial.knee_point`),
3. ascends ``jax.grad`` of the objective THROUGH the damped fixed point
   and the LUT's multilinear interpolation: geomean speedup of the
   workload mix, minus a quadratic penalty when the serving workload's
   p99 TOKEN latency (the capacity planner's wave model, composed
   in-loop from the differentiable ``latency_p99_ns``) exceeds the SLO,
4. projects each iterate onto the feasible set: clip to the box the
   frontier spec implies (:func:`sweepspec.field_bounds`), then bisect
   back toward the last feasible point until the Table-1/2 cost
   (:func:`coaxial.design_cost`) meets the area/pin budget -- the cost
   is monotone in (channels, LLC), so the segment crossing is unique,
5. re-verifies the returned optimum with ONE direct
   ``memsim.simulate(engine="event")`` run at the solved operating point
   and gates the model-vs-DES p99 within the calibration tolerance.

The optimizer moves the continuous fields ``dram_channels`` (links tied
1:1 for CXL topologies, the coaxial-Nx idiom) and ``llc_mb_per_core``;
the DDR/CXL topology itself is fixed by the starting point.  One jitted
value-and-grad serves every iteration -- the jit cache keys on array
shapes, so the whole ascent costs ONE trace (``designer_trace_count``
pins it, like ``cpu_model.solve_trace_count``).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coaxial, cpu_model, hw, memsim, queuelut, sweepspec
from repro.core.cpu_model import DDR_BASELINE, MemSystem
from repro.core.workloads import WORKLOADS, as_arrays

#: Frontier grid the optimizer starts from (mirrors
#: ``benchmarks/pareto_frontier.py``'s channels x LLC plane).
DEFAULT_CHANNELS = tuple(range(1, 9))
DEFAULT_LLC_MB = (0.5, 1.0, 2.0, 4.0)
#: Ascent hyperparameters.
DEFAULT_ITERS = 40
DEFAULT_LR = 0.3
DEFAULT_TOL = 1e-4
#: SLO-violation penalty weight (objective units: geomean speedup).
DEFAULT_PENALTY = 10.0
#: Simulated 12-core slice -> full server (Table 2's scale factor).
SCALE = coaxial.FULL_CORES // hw.SIM_CORES
#: Model-vs-DES p99 gate at the returned optimum: same envelope as the
#: LUT's off-grid interpolation cross-check (relative OR absolute).
VERIFY_REL_TOL = 0.35
VERIFY_ABS_TOL_NS = 4.0


def default_steps() -> int:
    """Default LUT-build DES budget, honoring ``$REPRO_DES_STEPS``."""
    cap = os.environ.get("REPRO_DES_STEPS")
    if cap:
        return min(queuelut.DEFAULT_STEPS, int(cap))
    return queuelut.DEFAULT_STEPS


# ---------------------------------------------------------------------------
# The differentiable objective: ONE jitted value-and-grad for every step.
# ---------------------------------------------------------------------------

#: Times the jitted objective has been TRACED (not called); the ascent
#: loop re-uses one compiled value-and-grad, so a whole optimize run --
#: any iteration count -- bumps this at most once per array-shape set.
_TRACE_COUNT = [0]


def designer_trace_count() -> int:
    return _TRACE_COUNT[0]


def _objective(x, sysa0, tie, wl, basea, n_active, base_ipc, lut,
               slo_s, waves, model_coef, penalty):
    """Penalized geomean speedup at design fields ``x``.

    ``x`` binds ``dram_channels`` and ``llc_mb_per_core`` (plus
    ``harvest_duty`` when idle-I/O harvesting is an ascent variable --
    the lent bandwidth itself stays a constant of ``sysa0``); ``tie``
    (0/1) ties the link count to the channel count for CXL topologies.
    The
    SLO term composes the LAST workload's (the serving workload's)
    differentiable p99 access latency into the capacity planner's wave
    model: ``token_p99 = max(waves * latency_p99, model_coef / ipc)``,
    and charges ``penalty * relu(token_p99/slo - 1)^2``.  ``slo_s=inf``
    disables the constraint (the relu is exactly zero).
    """
    _TRACE_COUNT[0] += 1  # side effect runs at trace time only
    ch = jnp.asarray(x["dram_channels"])
    llc = jnp.asarray(x["llc_mb_per_core"])
    links = tie * ch + (1.0 - tie) * sysa0.links
    sysa = sysa0._replace(dram_channels=ch, links=links,
                          llc_mb_per_core=llc)
    if "harvest_duty" in x:
        sysa = sysa._replace(harvest_duty=jnp.asarray(x["harvest_duty"]))
    nan = jnp.asarray(float("nan"))
    out = cpu_model._solve_point(wl, sysa, basea, n_active, nan, lut)
    ipc, lat99 = out[0], out[8]
    gm = jnp.exp(jnp.mean(jnp.log(ipc / base_ipc)))
    tok99_s = jnp.maximum(waves * lat99[-1] * 1e-9,
                          model_coef / ipc[-1])
    viol = jnp.maximum(tok99_s / slo_s - 1.0, 0.0)
    value = gm - penalty * viol ** 2
    aux = dict(gm=gm, latency_p99_ns=lat99[-1], token_p99_s=tok99_s,
               rho=out[4][-1], ipc=ipc[-1], worst_p99_ns=jnp.max(lat99))
    return value, aux


_obj_vg = jax.jit(jax.value_and_grad(_objective, has_aux=True))


# ---------------------------------------------------------------------------
# Projection: box clip + bisection back to the budget surface.
# ---------------------------------------------------------------------------

def _clip_box(x: dict, box: dict) -> dict:
    return {k: float(np.clip(v, *box[k])) for k, v in x.items()}


def _cost_of(x: dict, tie: float, links0: float) -> dict:
    ch = x["dram_channels"]
    links = tie * ch + (1.0 - tie) * links0
    c = coaxial.design_cost(ch, links, x["llc_mb_per_core"])
    return {k: float(v) for k, v in c.items()}


def _within_budget(cost: dict, area_budget: float,
                   pin_budget: float) -> bool:
    return (cost["rel_area"] <= area_budget + 1e-9
            and cost["rel_pins"] <= pin_budget + 1e-9)


def make_projector(box: dict, area_budget: float, pin_budget: float,
                   tie: float, links0: float):
    """Projection onto the feasible set for :func:`projected_ascent`.

    Feasible = inside ``box`` AND Table-1/2 cost within the budgets.
    The returned function clips to the box, then -- if the budget is
    violated -- bisects along the segment back to the (feasible)
    previous iterate: the cost is monotone in every field, so the
    segment crosses the budget surface exactly once.
    """
    def project(x: dict, x_prev: dict | None) -> dict:
        x = _clip_box(x, box)
        if _within_budget(_cost_of(x, tie, links0), area_budget,
                          pin_budget):
            return x
        if x_prev is None:
            raise ValueError(
                f"infeasible start {x}: cost {_cost_of(x, tie, links0)} "
                f"exceeds budget (area<={area_budget}, "
                f"pins<={pin_budget})")
        lo, hi = 0.0, 1.0  # t=0 is x_prev (feasible), t=1 is x
        for _ in range(40):
            mid = 0.5 * (lo + hi)
            xm = {k: x_prev[k] + mid * (x[k] - x_prev[k]) for k in x}
            if _within_budget(_cost_of(xm, tie, links0), area_budget,
                              pin_budget):
                lo = mid
            else:
                hi = mid
        return {k: x_prev[k] + lo * (x[k] - x_prev[k]) for k in x}

    return project


def projected_ascent(x0: dict, value_and_grad, project, *,
                     widths: dict, lr: float = DEFAULT_LR,
                     iters: int = DEFAULT_ITERS,
                     tol: float = DEFAULT_TOL):
    """Generic projected-gradient-ascent driver.

    ``value_and_grad(x) -> ((value, aux), grad)`` with ``grad`` a dict
    matching ``x``; ``project(x, x_prev) -> x`` maps any point onto the
    feasible set (``x_prev`` is the last feasible iterate, or None for
    the start).  Steps are preconditioned by the squared box widths
    (``x += lr * g * width^2``), so fields on wildly different scales
    (channels ~1..8, LLC ~0.5..4) move comparably.  Stops early when the
    projected step falls below ``tol`` in box-relative units.

    Returns ``(x, trajectory, converged)``; ``trajectory`` has one entry
    per evaluated iterate (the start included), each carrying the fields,
    objective value and aux -- exactly ``iters + 1`` objective calls at
    most, all through the one compiled ``value_and_grad``.
    """
    x = project(dict(x0), None)
    traj = []
    converged = False
    for it in range(int(iters)):
        (value, aux), g = value_and_grad(x)
        traj.append(dict(iter=it, **x, objective=float(value),
                         **{k: float(v) for k, v in aux.items()}))
        x_new = project({k: x[k] + lr * float(g[k]) * widths[k] ** 2
                         for k in x}, x)
        step = max(abs(x_new[k] - x[k]) / widths[k] for k in x)
        x = x_new
        if step < tol:
            converged = True
            break
    (value, aux), _ = value_and_grad(x)
    traj.append(dict(iter=len(traj), **x, objective=float(value),
                     **{k: float(v) for k, v in aux.items()}))
    return x, traj, converged


# ---------------------------------------------------------------------------
# The end-to-end designer.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DesignerResult:
    """The optimized design plus everything needed to audit it."""

    design: MemSystem        # the returned (continuous-field) optimum
    start: MemSystem         # the frontier-knee starting point
    frontier: tuple          # pareto(tail=True) points the knee came from
    gm_speedup: float        # geomean speedup of the mix at the optimum
    rel_area: float
    rel_pins: float
    area_budget: float
    pin_budget: float
    slo_ms: float | None
    token_p99_ms: float      # in-loop wave-model token p99 (SLO workload)
    latency_p99_ns: float    # in-loop p99 access latency (SLO workload)
    meets_budget: bool
    meets_slo: bool          # in-loop token p99 vs the SLO
    iters: int               # objective evaluations spent
    converged: bool
    trajectory: tuple        # per-iterate records (fields, value, aux)
    verify: dict             # direct-DES re-verification at the optimum

    def summary(self) -> str:
        d, v = self.design, self.verify
        lines = [
            f"start   {self.start.name}: ch={self.start.dram_channels:g} "
            f"llc={self.start.llc_mb_per_core:g}MB",
            f"optimum ch={float(d.dram_channels):.2f} "
            f"llc={float(d.llc_mb_per_core):.2f}MB "
            f"links={float(d.links):.2f}"
            + ("" if not d.harvest_duty else
               f" harvest duty={float(d.harvest_duty):.2f}"
               f"@{float(d.harvest_bw_gbps):g}GB/s"),
            f"cost    rel_area={self.rel_area:.3f} (<= {self.area_budget:g})"
            f" rel_pins={self.rel_pins:.3f}"
            + ("" if np.isinf(self.pin_budget)
               else f" (<= {self.pin_budget:g})"),
            f"mix     geomean speedup {self.gm_speedup:.3f}x "
            f"in {self.iters} iters"
            f" ({'converged' if self.converged else 'budget-limited'})",
            f"tail    access p99 {self.latency_p99_ns:.0f}ns -> token p99 "
            f"{self.token_p99_ms:.2f}ms"
            + ("" if self.slo_ms is None
               else f" (SLO {self.slo_ms:g}ms: "
                    f"{'ok' if self.meets_slo else 'MISS'})"),
            f"verify  DES p99 {v['des_p99_ns']:.0f}ns vs model "
            f"{v['model_p99_ns']:.0f}ns (rel err {v['rel_err']:+.2%}, "
            f"{'ok' if v['ok'] else 'DRIFT'})",
        ]
        return "\n".join(lines)


def _frontier_designs(channels) -> list[MemSystem]:
    """DDR baseline + one coaxial-Nx-idiom point per channel count."""
    return [DDR_BASELINE] + [
        MemSystem(f"designer-cxl-{ch}x", dram_channels=ch, links=ch,
                  link_rd_gbps=hw.CXL_X8_RD_GBPS,
                  link_wr_gbps=hw.CXL_X8_WR_GBPS,
                  iface_lat_ns=hw.CXL_LAT_NS, llc_mb_per_core=1.0)
        for ch in channels]


def _wave_geometry(arch: str | None, batch: int, context: int):
    """(waves, model_coef) of the capacity planner's token composition;
    both constants w.r.t. the design fields, so they close over the
    jitted objective as plain scalars."""
    if arch is None:
        return 0.0, 0.0
    from repro.serving.demand import decode_demand
    d = decode_demand(arch, batch=batch, context=context)
    in_flight = hw.MAX_MLP * hw.SIM_CORES * SCALE
    waves = max(batch * d.read_bytes / hw.CACHE_LINE_B / in_flight, 1.0)
    model_coef = (batch * d.inst_per_token /
                  (hw.CORE_CLK_GHZ * 1e9 * hw.SIM_CORES * SCALE))
    return waves, model_coef


def _verify_optimum(*, rho, kappa, eta, outstanding, premium_ns,
                    model_p99_ns, steps, seed, engine="event",
                    harvest_duty=0.0, harvest_bw_gbps=0.0) -> dict:
    """ONE direct DES run at the optimum's operating point.

    The channel config mirrors the LUT's build base (default transfer
    and service constants) at the solved (rho, kappa, outstanding, eta)
    and the design's CXL premium; ``rho`` is clamped to the LUT hull so
    the comparison judges the table's interpolation, not extrapolation
    beyond where the surface was ever built.  A harvesting optimum runs
    the DES with the TRUE per-channel ``(harvest_duty, harvest_bw_gbps)``
    pair -- this is the backstop for the LUT's reference-bandwidth
    ``duty_eff`` reduction (see queuelut.DEFAULT_HARVEST_GRID).
    """
    rho_c = float(np.clip(rho, queuelut.DEFAULT_RHO_GRID[0],
                          queuelut.DEFAULT_RHO_GRID[-1]))
    cfg = memsim.ChannelConfig(
        rho=rho_c, kappa=float(kappa), outstanding=float(outstanding),
        eta=float(eta), cxl_lat_ns=float(premium_ns),
        harvest_duty=float(harvest_duty),
        harvest_bw_gbps=float(harvest_bw_gbps))
    stats = memsim.simulate([cfg], steps=int(steps), seed=int(seed),
                            engine=engine)
    des99 = float(np.asarray(stats.p99_ns).reshape(-1)[0])
    rel_err = (des99 - model_p99_ns) / max(model_p99_ns, 1e-9)
    ok = (abs(rel_err) <= VERIFY_REL_TOL
          or abs(des99 - model_p99_ns) <= VERIFY_ABS_TOL_NS)
    return dict(engine=engine, steps=int(steps), rho=rho_c,
                kappa=float(kappa), eta=float(eta),
                outstanding=float(outstanding),
                premium_ns=float(premium_ns),
                harvest_duty=float(harvest_duty),
                harvest_bw_gbps=float(harvest_bw_gbps),
                des_p99_ns=des99,
                model_p99_ns=float(model_p99_ns),
                rel_err=float(rel_err), ok=bool(ok))


def optimize_design(*, area_budget: float = 1.2,
                    pin_budget: float | None = None,
                    slo_ms: float | None = 500.0,
                    arch: str | None = "stablelm-1.6b",
                    batch: int = 32, context: int = 2048,
                    channels=DEFAULT_CHANNELS, llc_mb=DEFAULT_LLC_MB,
                    cost: str = "rel_area",
                    iters: int = DEFAULT_ITERS, lr: float = DEFAULT_LR,
                    tol: float = DEFAULT_TOL,
                    penalty: float = DEFAULT_PENALTY,
                    harvest_bw_gbps: float = 0.0,
                    harvest_duty_max: float | None = None,
                    lut=None, steps: int | None = None, seed: int = 0,
                    engine: str = "event",
                    verify_steps: int | None = None,
                    workloads=None) -> DesignerResult:
    """Optimize a memory system under an area/pin budget and a p99 SLO.

    See the module docstring for the five stages.  ``arch`` names the
    serving workload whose wave-model TOKEN p99 carries the SLO (its
    derived LLM workload joins the Table-4 mix); ``slo_ms=None`` or
    ``arch=None`` drops the constraint.  ``lut``/``steps``/``engine``
    control the QueueLUT surface (default: the default grid at
    :func:`default_steps`, resolved through the persistent LUT store --
    with a warm ``$REPRO_LUT_CACHE`` the optimizer starts without
    running the DES at all); ``verify_steps`` the final DES
    re-verification budget (default: the LUT's).

    ``harvest_bw_gbps > 0`` makes idle-I/O harvesting (arXiv 2511.12349)
    a THIRD ascent variable: the design may lend that much idle I/O
    bandwidth per DRAM channel, and ``harvest_duty`` joins the ascent in
    the box ``[0, harvest_duty_max]`` (default: the top of the LUT's
    harvest grid -- the ascent stays on the measured surface).  Lending
    idle links costs no area or pins (they are already on the package --
    the whole point of harvesting), so the projection leaves the duty
    untouched; the QueueLUT then needs its harvest axis (the default
    build gains it automatically).  Returns a :class:`DesignerResult`;
    ``result.design`` is the optimized (continuous-field)
    :class:`MemSystem`.
    """
    if slo_ms is not None and arch is None:
        raise ValueError("an SLO needs a serving workload: pass arch=")
    steps = default_steps() if steps is None else int(steps)
    harvesting = float(harvest_bw_gbps) > 0.0
    if lut is None:
        lut = queuelut.default_queue_lut(steps=steps, engine=engine,
                                         harvest=harvesting)
    elif harvesting and lut.harvest_grid is None:
        raise ValueError("harvest_bw_gbps > 0 needs a QueueLUT with the "
                         "harvest axis; build_queue_lut(harvest=...) or "
                         "pass lut=None")
    if harvest_duty_max is None:
        harvest_duty_max = (float(lut.harvest_grid[-1]) if harvesting
                            else 0.0)
    pin_budget = float("inf") if pin_budget is None else float(pin_budget)

    if workloads is None:
        workloads = tuple(WORKLOADS)
        if arch is not None:
            from repro.serving.demand import llm_workload
            workloads += (llm_workload(arch, batch=batch,
                                       context=context),)
    else:
        workloads = tuple(workloads)

    # -- stage 1+2: tail-ranked frontier, knee of the in-budget subset --
    designs = _frontier_designs(channels)
    spec = sweepspec.sweep_spec(design=designs, llc_mb_per_core=llc_mb)
    sw = coaxial.solve_spec(spec, workloads=workloads,
                            queue_model="memsim", lut=lut)
    frontier = sw.pareto(cost=cost, tail=True)
    feasible = [p for p in frontier
                if p["rel_area"] <= area_budget + 1e-9
                and p["rel_pins"] <= pin_budget + 1e-9]
    if not feasible:
        cheapest = min(frontier, key=lambda p: (p["rel_area"],
                                                p["rel_pins"]))
        raise ValueError(
            f"no frontier point fits the budget (area<={area_budget}, "
            f"pins<={pin_budget}); cheapest frontier point costs "
            f"rel_area={cheapest['rel_area']:.3f}, "
            f"rel_pins={cheapest['rel_pins']:.3f}")
    knee = coaxial.knee_point(feasible, cost=cost)
    start = dataclasses.replace(
        next(d for d in designs if d.name == knee["design"]),
        llc_mb_per_core=float(knee["llc_mb_per_core"]),
        harvest_bw_gbps=float(harvest_bw_gbps))

    # -- stage 3+4: projected ascent from the knee ----------------------
    bounds = sweepspec.field_bounds(spec)
    box = {f: bounds[f] for f in ("dram_channels", "llc_mb_per_core")}
    if harvesting:
        box["harvest_duty"] = (0.0, float(harvest_duty_max))
    widths = {f: hi - lo for f, (lo, hi) in box.items()}
    tie = 1.0 if start.is_cxl else 0.0
    project = make_projector(box, float(area_budget), pin_budget, tie,
                             float(start.links))

    wl = cpu_model._to_jnp(as_arrays(workloads))
    basea = DDR_BASELINE.as_arrays()
    base_ipc = jnp.asarray(
        cpu_model.solve(DDR_BASELINE, baseline=DDR_BASELINE,
                        workloads=workloads, queue_model="memsim",
                        lut=lut).ipc)
    waves, model_coef = _wave_geometry(arch, batch, context)
    slo_s = float("inf") if slo_ms is None else slo_ms * 1e-3
    sysa0 = start.as_arrays()
    j = lambda v: jnp.asarray(float(v))

    def value_and_grad(x):
        return _obj_vg({k: j(v) for k, v in x.items()}, sysa0, j(tie),
                       wl, basea, j(hw.SIM_CORES), base_ipc, lut,
                       j(slo_s), j(waves), j(model_coef), j(penalty))

    x0 = {"dram_channels": float(start.dram_channels),
          "llc_mb_per_core": float(start.llc_mb_per_core)}
    if harvesting:
        x0["harvest_duty"] = 0.0
    x, traj, converged = projected_ascent(
        x0, value_and_grad, project, widths=widths, lr=lr, iters=iters,
        tol=tol)

    # -- stage 5: package + direct-DES re-verification ------------------
    final = traj[-1]
    ch = x["dram_channels"]
    links = tie * ch + (1.0 - tie) * float(start.links)
    costs = _cost_of(x, tie, float(start.links))
    design = dataclasses.replace(
        start, name="designer-opt", dram_channels=ch, links=links,
        llc_mb_per_core=x["llc_mb_per_core"],
        harvest_duty=x.get("harvest_duty", 0.0),
        rel_area=costs["rel_area"], rel_pins=costs["rel_pins"])
    slo_wl = workloads[-1]
    outstanding = hw.SIM_CORES * hw.MAX_MLP / max(ch, 1e-9)
    verify = _verify_optimum(
        rho=final["rho"], kappa=slo_wl.kappa, eta=slo_wl.eta,
        outstanding=outstanding, premium_ns=design.iface_lat_ns,
        model_p99_ns=final["latency_p99_ns"],
        steps=steps if verify_steps is None else int(verify_steps),
        seed=seed, engine="event",
        harvest_duty=design.harvest_duty,
        harvest_bw_gbps=design.harvest_bw_gbps)
    tok99_ms = final["token_p99_s"] * 1e3
    return DesignerResult(
        design=design, start=start, frontier=tuple(frontier),
        gm_speedup=final["gm"], rel_area=costs["rel_area"],
        rel_pins=costs["rel_pins"], area_budget=float(area_budget),
        pin_budget=pin_budget, slo_ms=slo_ms,
        token_p99_ms=tok99_ms,
        latency_p99_ns=final["latency_p99_ns"],
        meets_budget=_within_budget(costs, float(area_budget),
                                    pin_budget),
        meets_slo=bool(slo_ms is None or tok99_ms <= slo_ms),
        iters=len(traj), converged=converged, trajectory=tuple(traj),
        verify=verify)
