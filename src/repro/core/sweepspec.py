"""Declarative named-axis sweep specs over the COAXIAL design space.

A :class:`SweepSpec` is an ordered set of named :class:`Axis` objects; an
axis can bind

  * the ``design`` axis itself (a tuple of :class:`MemSystem` points),
  * any sweepable design field (``dram_channels``, ``links``,
    ``link_rd_gbps``, ``link_wr_gbps``, ``llc_mb_per_core``) -- the axis
    value overrides that field for EVERY design in the sweep,
  * ``iface_lat_ns`` -- the legacy CXL-latency-premium axis (``None`` =
    each design's own premium; non-CXL designs ignore the override),
  * ``n_active`` -- active core counts (calibration is redone per count),
  * any workload behavioral parameter (``kappa``, ``eta``, ``mpki``, ...)
    -- the axis value overrides that parameter for EVERY workload, and
    calibration runs against the overridden workload (it IS a different
    synthetic workload).

Example::

    spec = sweep_spec(design=all_designs(),
                      iface_lat_ns=[None, 50.0],
                      llc_mb_per_core=np.linspace(0.5, 4, 8),
                      kappa=[1.0, 1.6, 3.2])
    sw = spec.solve()                      # ONE XLA trace for the 4-D grid
    sw.sel(design="coaxial-4x", kappa=1.6).geomean_grid()

The spec is pure data: :func:`build_flat` lowers it to the flattened
per-cell arrays the jitted solver (:func:`cpu_model.solve_cells`) consumes,
and ``coaxial.solve_spec`` wraps the solved grid in a named-axis
``SweepResult``.  Overrides are applied branch-free inside the trace
(NaN = "keep the design's / workload's own value"), so the whole grid --
however many axes -- costs one compile per flattened cell count.

The DES is a sweep target too: :func:`distribution_spec` builds a spec
whose axes bind :class:`memsim.ChannelConfig` fields (``rho``, ``kappa``,
``cxl_lat_ns``, any calibration constant), :func:`build_flat_memsim`
lowers it the same NaN-masked way, and ``spec.solve()`` dispatches on
``spec.target`` -- ``coaxial.distribution_sweep`` returns named-axis
latency *distributions* instead of model results::

    sw = coaxial.distribution_sweep(rho=np.linspace(.1, .8, 8),
                                    cxl_lat_ns=[0.0, 30.0])
    sw.sel(rho=0.6, cxl_lat_ns=30.0).p90_ns
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cpu_model, memsim, workloads
from repro.core.cpu_model import QUEUE_MODELS, MemSystem, MemSystemArrays
from repro.core.memsim import ChannelArrays, ChannelConfig

#: Design fields an axis may override (``iface_lat_ns`` has its own
#: dedicated axis with the legacy CXL-only semantics).
DESIGN_FIELDS = cpu_model.SWEEPABLE_DESIGN_FIELDS
#: Workload behavioral parameters an axis may override.
WORKLOAD_FIELDS = workloads.SWEEPABLE_FIELDS
#: memsim channel fields a distribution-sweep axis may bind (the operating
#: point AND every calibration constant; see :func:`distribution_spec`).
CHANNEL_FIELDS = memsim.CHANNEL_FIELDS

#: Axis kinds.
KIND_DESIGN = "design"
KIND_IFACE = "iface_lat"
KIND_N_ACTIVE = "n_active"
KIND_DESIGN_FIELD = "design_field"
KIND_WORKLOAD_FIELD = "workload_field"
KIND_CHANNEL_FIELD = "channel_field"
KIND_QUEUE_MODEL = "queue_model"

#: Every bindable axis name (the valid ``sweep_spec`` keywords).
AXIS_NAMES = (("design", "iface_lat_ns", "n_active", "queue_model") +
              DESIGN_FIELDS + WORKLOAD_FIELDS)


def _kind_of(name: str) -> str:
    if name == "design":
        return KIND_DESIGN
    if name == "iface_lat_ns":
        return KIND_IFACE
    if name == "n_active":
        return KIND_N_ACTIVE
    if name == "queue_model":
        return KIND_QUEUE_MODEL
    if name in DESIGN_FIELDS:
        return KIND_DESIGN_FIELD
    if name in WORKLOAD_FIELDS:
        return KIND_WORKLOAD_FIELD
    raise ValueError(
        f"unknown sweep axis {name!r}; bindable axes: design, iface_lat_ns, "
        f"n_active, queue_model, design fields {DESIGN_FIELDS}, "
        f"workload fields {WORKLOAD_FIELDS}")


@dataclasses.dataclass(frozen=True)
class Axis:
    """One named sweep dimension: a field name and its coordinate values."""

    name: str
    values: tuple
    kind: str

    def __len__(self) -> int:
        return len(self.values)

    @property
    def coords(self) -> tuple:
        """Human-facing coordinates (design names for the design axis)."""
        if self.kind == KIND_DESIGN:
            return tuple(d.name for d in self.values)
        return self.values

    def index(self, value) -> int:
        """Tolerant coordinate lookup.

        Designs match by name (or :class:`MemSystem` identity); numeric
        coordinates match with ``np.isclose`` so ``50`` and ``50.0`` (or a
        linspace-rounded ``49.999999999``) resolve to the same cell; ``None``
        matches only ``None``.  Raises one clear :class:`KeyError` listing
        the valid coordinates otherwise.
        """
        if self.kind == KIND_DESIGN:
            name = value.name if isinstance(value, MemSystem) else value
            for i, d in enumerate(self.values):
                if d.name == name:
                    return i
        elif self.kind == KIND_QUEUE_MODEL:
            for i, v in enumerate(self.values):
                if v == value:
                    return i
        else:
            try:
                num = None if value is None else float(value)
            except (TypeError, ValueError):
                num = object()  # not float-convertible: matches nothing
            for i, v in enumerate(self.values):
                if v is None or num is None:
                    if v is None and num is None:
                        return i
                    continue
                if not isinstance(num, float):
                    break
                if np.isclose(num, float(v), rtol=1e-6, atol=1e-12):
                    return i
        raise KeyError(
            f"{value!r} is not a coordinate of axis {self.name!r}; "
            f"valid coordinates: {list(self.coords)}")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """An ordered tuple of named axes describing one sweep grid."""

    axes: tuple[Axis, ...]

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(ax) for ax in self.axes)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(ax.name for ax in self.axes)

    def axis(self, name: str) -> Axis:
        for ax in self.axes:
            if ax.name == name:
                return ax
        raise KeyError(f"no axis {name!r} in spec; axes: {self.names}")

    @property
    def target(self) -> str:
        """Which engine the spec lowers to: ``"cpu"`` (the closed-form
        ``cpu_model`` solver) or ``"memsim"`` (the DES)."""
        return ("memsim" if any(ax.kind == KIND_CHANNEL_FIELD
                                for ax in self.axes) else "cpu")

    def solve(self, **kwargs):
        """Solve the grid: ``coaxial.SweepResult`` for cpu-targeted specs,
        ``coaxial.DistributionSweepResult`` for memsim-targeted ones.
        Keyword arguments pass through to the solver -- memsim-targeted
        specs accept ``engine="timestep"|"event"`` (and ``steps``,
        ``seed``, ``reps``, ...) exactly like
        ``coaxial.distribution_sweep``."""
        from repro.core import coaxial  # runtime import: coaxial imports us
        if self.target == "memsim":
            return coaxial.distribution_sweep(self, **kwargs)
        return coaxial.solve_spec(self, **kwargs)


def _as_axis(name: str, values) -> Axis:
    kind = _kind_of(name)
    if kind == KIND_DESIGN:
        values = tuple(values)
        for d in values:
            if not isinstance(d, MemSystem):
                raise TypeError(
                    f"design axis entries must be MemSystem, got {d!r}")
    elif kind == KIND_QUEUE_MODEL:
        if isinstance(values, str):
            values = (values,)
        values = tuple(values)
        for v in values:
            if v not in QUEUE_MODELS:
                raise ValueError(
                    f"axis 'queue_model': {v!r} is not a backend; choose "
                    f"from {QUEUE_MODELS}")
    else:
        if np.ndim(values) == 0 and not isinstance(values, (list, tuple)):
            values = (values,)
        conv = []
        for v in values:
            if v is None:
                if kind != KIND_IFACE:
                    raise ValueError(
                        f"axis {name!r}: None is only meaningful on the "
                        f"iface_lat_ns axis ('use the design's own premium')")
                conv.append(None)
            else:
                conv.append(int(v) if kind == KIND_N_ACTIVE else float(v))
        values = tuple(conv)
    if not values:
        raise ValueError(f"axis {name!r} has no coordinate values")
    return Axis(name=name, values=values, kind=kind)


def sweep_spec(design=None, **axes) -> SweepSpec:
    """Build a :class:`SweepSpec`; axis order is declaration order.

    ``design`` defaults to every registered design (``coaxial.
    all_designs()``) and always comes first; the remaining keyword
    arguments each declare one axis binding the named field.  Scalars are
    promoted to length-1 axes.  ``queue_model`` is an axis too -- the
    solver backend (``"closed_form"`` / ``"memsim"``) sweeps like any
    other coordinate (``coaxial.solve_spec`` runs one jitted pass per
    backend and stacks them).

    Example::

        >>> from repro.core.sweepspec import sweep_spec
        >>> from repro.core.cpu_model import COAXIAL_4X, DDR_BASELINE
        >>> spec = sweep_spec(design=(DDR_BASELINE, COAXIAL_4X),
        ...                   iface_lat_ns=[None, 50.0],
        ...                   kappa=[1.0, 1.6],
        ...                   queue_model=("closed_form", "memsim"))
        >>> spec.names
        ('design', 'iface_lat_ns', 'kappa', 'queue_model')
        >>> spec.shape
        (2, 2, 2, 2)
        >>> spec.axis("kappa").values
        (1.0, 1.6)
        >>> spec.axis("queue_model").index("memsim")
        1
    """
    if design is None:
        from repro.core import coaxial  # runtime import (registry lives there)
        design = coaxial.all_designs()
    built = [_as_axis("design", design)]
    for name, values in axes.items():
        _kind_of(name)  # raise the single clear error before building
        built.append(_as_axis(name, values))
    return SweepSpec(axes=tuple(built))


def field_bounds(spec: SweepSpec) -> dict[str, tuple[float, float]]:
    """Per-design-field ``(lo, hi)`` ranges implied by a spec's axes.

    The feasible box a projected-ascent optimizer
    (:mod:`repro.core.designer`) derives from the frontier spec it
    started from: a design-field axis bounds its field directly by its
    min/max coordinates, and the design axis bounds every remaining
    sweepable field by the spread across its design points -- so the
    optimizer can never leave the region the grid (and hence the pareto
    knee it started at) actually covered.

    Example::

        >>> from repro.core.cpu_model import COAXIAL_4X, DDR_BASELINE
        >>> from repro.core.sweepspec import field_bounds, sweep_spec
        >>> b = field_bounds(sweep_spec(
        ...     design=(DDR_BASELINE, COAXIAL_4X),
        ...     llc_mb_per_core=(0.5, 4.0)))
        >>> b["llc_mb_per_core"]
        (0.5, 4.0)
        >>> b["dram_channels"]      # from the design axis' spread
        (1.0, 4.0)
    """
    out: dict[str, tuple[float, float]] = {}
    design_ax = None
    for ax in spec.axes:
        if ax.kind == KIND_DESIGN:
            design_ax = ax
        elif ax.kind == KIND_DESIGN_FIELD:
            vals = [float(v) for v in ax.values]
            out[ax.name] = (min(vals), max(vals))
    if design_ax is not None:
        for f in DESIGN_FIELDS:
            if f in out:
                continue
            vals = [float(getattr(d, f)) for d in design_ax.values]
            out[f] = (min(vals), max(vals))
    return out


# ---------------------------------------------------------------------------
# Lowering: spec -> the flattened per-cell arrays the jitted solver eats.
# ---------------------------------------------------------------------------

def _flat(values, pos: int, shape: tuple[int, ...]) -> np.ndarray:
    """Broadcast one axis' values across the grid, flattened to ``(N,)``."""
    arr = np.asarray(values, np.float64)
    view = arr.reshape(tuple(arr.size if j == pos else 1
                             for j in range(len(shape))))
    return np.ascontiguousarray(np.broadcast_to(view, shape)).reshape(-1)


def _design_leaves(designs) -> dict[str, np.ndarray]:
    leaves = {f: np.array([float(getattr(d, f)) for d in designs])
              for f in MemSystemArrays._fields if f != "is_cxl"}
    leaves["is_cxl"] = np.array([1.0 if d.is_cxl else 0.0 for d in designs])
    return leaves


def build_flat(spec: SweepSpec, *, pin_design: MemSystem | None = None,
               default_n_active: int | None = None) -> dict:
    """Lower ``spec`` to flattened solver inputs (all leaves ``(N,)``).

    Returns a dict with keys ``sysa`` (a :class:`MemSystemArrays` of
    numpy leaves), ``n_active``, ``iface_override_ns``,
    ``design_overrides`` and ``workload_overrides`` (NaN = unbound).

    ``pin_design`` replaces every cell's design with the given point and
    drops the design-field overrides -- the un-overridden reference column
    :meth:`coaxial.SweepResult.baseline_ipc_grid` is built from.
    """
    shape = spec.shape
    n = int(np.prod(shape))
    nans = np.full(n, np.nan)
    sys_ov = {f: nans for f in DESIGN_FIELDS}
    wl_ov = {f: nans for f in WORKLOAD_FIELDS}
    n_active = np.full(
        n, float(default_n_active if default_n_active is not None
                 else cpu_model.hw.SIM_CORES))
    iface = nans
    sysa = None
    for pos, ax in enumerate(spec.axes):
        if ax.kind == KIND_DESIGN:
            designs = ((pin_design,) * len(ax) if pin_design is not None
                       else ax.values)
            leaves = _design_leaves(designs)
            sysa = MemSystemArrays(**{
                f: _flat(v, pos, shape) for f, v in leaves.items()})
        elif ax.kind == KIND_IFACE:
            vals = [np.nan if v is None else v for v in ax.values]
            iface = _flat(vals, pos, shape)
        elif ax.kind == KIND_N_ACTIVE:
            n_active = _flat(ax.values, pos, shape)
        elif ax.kind == KIND_QUEUE_MODEL:
            # The backend is a trace-level choice, not a per-cell array:
            # coaxial.solve_spec splits the grid and solves one jitted
            # pass per backend before lowering reaches this point.
            raise ValueError(
                "queue_model axes cannot lower to flat cell arrays; "
                "solve them through coaxial.solve_spec")
        elif ax.kind == KIND_DESIGN_FIELD:
            if pin_design is None:
                sys_ov = dict(sys_ov)
                sys_ov[ax.name] = _flat(ax.values, pos, shape)
        else:
            wl_ov = dict(wl_ov)
            wl_ov[ax.name] = _flat(ax.values, pos, shape)
    if sysa is None:
        raise ValueError("spec has no design axis (use sweep_spec(...))")
    return dict(sysa=sysa, n_active=n_active, iface_override_ns=iface,
                design_overrides=sys_ov, workload_overrides=wl_ov)


# ---------------------------------------------------------------------------
# memsim target: distribution sweeps over ChannelConfig fields.
# ---------------------------------------------------------------------------

def distribution_spec(**axes) -> SweepSpec:
    """Build a memsim-targeted :class:`SweepSpec` of channel-field axes.

    Every keyword names a :class:`memsim.ChannelConfig` field (``rho``,
    ``kappa``, ``cxl_lat_ns``, ``stall_ns``, ...); axis order is
    declaration order and scalars are promoted to length-1 axes.  The
    resulting spec lowers to ONE jitted simulation over the flattened
    cell batch (:func:`build_flat_memsim`) -- under either memsim engine
    (``spec.solve(engine="event")``) -- and
    ``coaxial.distribution_sweep`` wraps the result in a named-axis
    ``DistributionSweepResult``.
    """
    if not axes:
        raise ValueError("distribution_spec needs at least one axis; "
                         f"bindable channel fields: {CHANNEL_FIELDS}")
    built = []
    for name, values in axes.items():
        if name not in CHANNEL_FIELDS:
            raise ValueError(
                f"unknown distribution axis {name!r}; bindable channel "
                f"fields: {CHANNEL_FIELDS}")
        if np.ndim(values) == 0 and not isinstance(values, (list, tuple)):
            values = (values,)
        conv = []
        for v in values:
            if v is None:
                raise ValueError(
                    f"axis {name!r}: None is not a channel coordinate")
            conv.append(float(v))
        if not conv:
            raise ValueError(f"axis {name!r} has no coordinate values")
        built.append(Axis(name=name, values=tuple(conv),
                          kind=KIND_CHANNEL_FIELD))
    return SweepSpec(axes=tuple(built))


def build_flat_memsim(spec: SweepSpec,
                      base: ChannelConfig | None = None) -> dict:
    """Lower a memsim-targeted spec to flattened simulator inputs.

    Returns ``cha`` (a :class:`ChannelArrays` of the base channel's values
    broadcast to ``(N,)``) and ``overrides`` (NaN = "keep the base
    channel's value", one ``(N,)`` array per bound axis) -- the overrides
    are applied branch-free in-trace by ``memsim.simulate_cells`` (under
    whichever engine runs the sweep), so each engine's jit cache keys on
    the flattened cell count alone, exactly like the cpu target.
    """
    base = base if base is not None else ChannelConfig(rho=0.5)
    bad = [ax.name for ax in spec.axes if ax.kind != KIND_CHANNEL_FIELD]
    if bad:
        raise ValueError(
            f"memsim lowering needs channel-field axes only; non-channel "
            f"axes in spec: {bad} (build with distribution_spec(...))")
    shape = spec.shape
    n = int(np.prod(shape))
    cha = ChannelArrays(*(
        np.full(n, float(getattr(base, f))) for f in CHANNEL_FIELDS))
    overrides = {}
    for pos, ax in enumerate(spec.axes):
        overrides[ax.name] = _flat(ax.values, pos, shape)
    return dict(cha=cha, overrides=overrides)
