"""HLO text analysis with loop-trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless
of trip count -- useless for scan-over-layers models (an 88-layer scan reads
as one layer).  This module re-derives the roofline numerators from
``compiled.as_text()`` by walking the computation call graph:

  * dot FLOPs: 2 * prod(output dims) * prod(contracting dims), per dot;
  * HBM-traffic proxy: operand+output bytes of every top-level op that
    actually moves data (fusions count their boundary, not their interior);
  * collective bytes: output size per all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * ``while`` bodies are multiplied by the trip count parsed from the loop
    condition's comparison constant; ``conditional`` takes the max branch.

The result is a per-chip (the module is the per-partition SPMD program)
{flops, bytes, collective bytes} that correctly scales with loop depth.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

#: ops whose operand/output boundary traffic we count as HBM bytes.
_DATA_OPS = {
    "fusion", "dot", "convolution", "copy", "transpose", "concatenate",
    "dynamic-update-slice", "dynamic-slice", "gather", "scatter", "reduce",
    "broadcast", "slice", "pad", "reverse", "sort", "convert", "select",
    "add", "multiply", "subtract", "divide", "maximum", "minimum", "tanh",
    "exponential", "log", "rsqrt", "compare", "iota", "reduce-window",
    "custom-call", "cholesky", "triangular-solve", "clamp", "negate",
} | set(COLLECTIVES)

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<rest>.*)$")
_CALLEE_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _array_bytes(type_str: str) -> int:
    """Total bytes of all arrays mentioned in a type string (tuples sum)."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_array_dims(type_str: str):
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    op: str
    type_str: str
    operands: list
    attrs: str
    raw: str = ""


def _split_type_op(rest: str):
    """Split '<type> <opname>(<operands>)<attrs>' robustly."""
    # Find the op name: the last bare word before the first '(' that opens
    # the operand list.  Types may themselves contain parens (tuples), so
    # scan for ' <word>(' occurrences and take the first whose word is a
    # plausible op (lowercase alnum/dash).
    for m in re.finditer(r"\s([a-z][\w\-]*)\(", rest):
        word = m.group(1)
        type_str = rest[:m.start()]
        # types never *end* with a bare lowercase word; accept first match.
        depth = 0
        i = m.end() - 1
        for j in range(i, len(rest)):
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
                if depth == 0:
                    operands = rest[i + 1:j]
                    attrs = rest[j + 1:]
                    return type_str.strip(), word, operands, attrs
        break
    return rest, None, "", ""


def parse_computations(text: str) -> dict:
    """name -> list[Op]; also tags the ENTRY computation as '__entry__'."""
    comps: dict = {}
    current = None
    entry = None
    for line in text.splitlines():
        stripped = line.rstrip()
        header = re.match(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{",
                          stripped)
        if header and not stripped.lstrip().startswith("//"):
            current = header.group(2)
            comps[current] = []
            if header.group(1):
                entry = current
            continue
        if stripped.strip() == "}":
            current = None
            continue
        if current is None or "=" not in stripped:
            continue
        m = _OP_RE.match(stripped)
        if not m:
            continue
        type_str, op, operands, attrs = _split_type_op(m.group("rest"))
        if op is None:
            continue
        ops = [o.strip().lstrip("%") for o in re.findall(
            r"%([\w\.\-]+)", operands)]
        comps[current].append(Op(m.group("name"), op, type_str, ops, attrs,
                                 raw=stripped))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry
    return comps


#: the ops whose boundary traffic survives TPU-style fusion: matmuls,
#: data movement, and collectives.  Elementwise chains fuse away.
_HBM_OPS = {"dot", "convolution", "gather", "scatter", "dynamic-slice",
            "dynamic-update-slice", "custom-call", "sort", "reduce",
            "copy"} | set(COLLECTIVES)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # all-op boundary traffic (unfused bound)
    bytes_hbm: float = 0.0      # dot/data-movement boundary (fused proxy)
    coll: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.bytes_hbm * k,
                    {c: v * k for c, v in self.coll.items()})

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.bytes_hbm += other.bytes_hbm
        for c in COLLECTIVES:
            self.coll[c] += other.coll[c]

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _trip_count(cond_ops) -> int:
    """Largest integer constant in the loop condition (the bound)."""
    best = 1
    for op in cond_ops:
        for m in _CONST_RE.finditer(op.raw):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, shapes: dict) -> float:
    _, out_dims = _first_array_dims(op.type_str)
    if out_dims is None:
        return 0.0
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    contract = 1
    m = _CONTRACT_RE.search(op.attrs)
    lhs_dims = shapes.get(op.operands[0]) if op.operands else None
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze(text: str) -> Cost:
    comps = parse_computations(text)
    entry = comps["__entry_name__"]
    memo: dict = {}
    # fusion-called computations are accounted at their call site boundary
    # for bytes, but their interior dots still count as flops.

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        ops = comps.get(name, [])
        shapes = {}
        sizes = {}
        for op in ops:
            _, dims = _first_array_dims(op.type_str)
            shapes[op.name] = dims
            sizes[op.name] = _array_bytes(op.type_str)
        cost = Cost()
        for op in ops:
            if op.op in ("parameter", "constant", "get-tuple-element",
                         "tuple", "bitcast", "after-all", "reshape", None):
                continue
            out_b = _array_bytes(op.type_str)
            in_b = sum(sizes.get(o, 0) for o in op.operands)
            if op.op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    cost.add(comp_cost(body).scaled(trips))
                continue
            if op.op == "conditional":
                mbr = _BRANCHES_RE.search(op.attrs)
                if mbr:
                    branch_costs = [comp_cost(b.strip().lstrip("%"))
                                    for b in mbr.group(1).split(",")]
                    if branch_costs:
                        best = max(branch_costs, key=lambda c: c.flops +
                                   c.bytes)
                        cost.add(best)
                continue
            if op.op == "call":
                m2 = re.search(r"to_apply=%?([\w\.\-]+)", op.attrs)
                if m2:
                    cost.add(comp_cost(m2.group(1)))
                continue
            if op.op in COLLECTIVES:
                cost.coll[op.op] += out_b
                cost.bytes += out_b + in_b
                cost.bytes_hbm += out_b + in_b
                continue
            if op.op == "fusion":
                m2 = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                if m2:
                    inner = comp_cost(m2.group(1))
                    cost.flops += inner.flops      # dots inside fusions
                    cost.bytes_hbm += inner.bytes_hbm  # dots inside fusions
                    for c in COLLECTIVES:
                        cost.coll[c] += inner.coll[c]
                cost.bytes += out_b + in_b
                continue
            if op.op == "dot":
                cost.flops += _dot_flops(op, shapes)
                cost.bytes += out_b + in_b
                cost.bytes_hbm += out_b + in_b
                continue
            if op.op == "dynamic-update-slice":
                # In-place aliased update: traffic = 2 x update slice, not
                # the whole buffer (which the output type reports).
                upd = sizes.get(op.operands[1], 0) if len(op.operands) > 1 \
                    else 0
                cost.bytes += 2 * upd
                cost.bytes_hbm += 2 * upd
                continue
            if op.op == "dynamic-slice":
                cost.bytes += 2 * out_b
                cost.bytes_hbm += 2 * out_b
                continue
            if op.op == "gather":
                cost.bytes += 2 * out_b
                cost.bytes_hbm += 2 * out_b
                continue
            if op.op == "scatter":
                upd = sizes.get(op.operands[-1], 0)
                cost.bytes += 2 * upd
                cost.bytes_hbm += 2 * upd
                continue
            if op.op == "copy":
                cost.bytes += 2 * out_b
                cost.bytes_hbm += 2 * out_b
                continue
            if op.op in _DATA_OPS:
                cost.bytes += out_b + in_b
                if op.op in _HBM_OPS:
                    cost.bytes_hbm += out_b + in_b
        memo[name] = cost
        return cost

    return comp_cost(entry) if entry else Cost()
