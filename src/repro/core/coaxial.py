"""End-to-end COAXIAL evaluation engine (paper §4-§6, Tables 2 & 5).

Everything the paper reports is derivable from here:

  * :func:`sweep` -- the design-space engine: one jitted pass over
    designs x interface latencies x active-core counts, returning a
    :class:`SweepResult` from which all figures slice;
  * :func:`evaluate` -- per-workload speedups, latency breakdowns and
    utilizations for any design point (Figs 5, 7, 8, 9);
  * :func:`register_design` / :func:`get_design` / :func:`all_designs` --
    the design registry (configs and the planner can add points);
  * :func:`area_report` / :func:`pin_report` -- Table 1/2 accounting;
  * :func:`edp_report` -- the §6.6 power and energy-delay-product model
    (Table 5);
  * :func:`sensitivity_latency` / :func:`sensitivity_cores` -- §6.4 / §6.5.

The sweep engine is what makes dense grids cheap: a sweep lowers to ONE
flattened, vmapped solver call, so a 100-point channels x latency grid
costs one XLA compile instead of 100.  Sweeps are declared as a
:func:`sweep_spec` of named axes -- the design axis, ``iface_lat_ns``,
``n_active``, any design field (``llc_mb_per_core``, ``dram_channels``,
...) or any workload parameter (``kappa``, ``mpki``, ...)::

    sw = coaxial.solve_spec(coaxial.sweep_spec(
        design=coaxial.all_designs(), iface_lat_ns=[None, 50.0],
        llc_mb_per_core=np.linspace(0.5, 4, 8), kappa=[1.0, 1.6, 3.2]))
    sw.sel(design="coaxial-4x", kappa=1.6).geomean_grid()
    sw.pareto()                      # area/pins vs speedup frontier

:func:`sweep` / :func:`default_sweep` / :func:`evaluate` are thin shims
over the spec path (bit-identical to the historical positional grid), and
:func:`design_gradient` differentiates the same solve for gradient-based
design optimization.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools

import numpy as np

from repro.core import cpu_model, hw, memsim, queueing
from repro.core.cpu_model import (COAXIAL_2X, COAXIAL_4X, COAXIAL_5X,
                                  COAXIAL_ASYM, DDR_BASELINE, DESIGNS,
                                  QUEUE_MODELS, MemSystem, ModelResult,
                                  design_gradient, geomean, solve,
                                  solve_batch)
from repro.core.memsim import ChannelConfig, LatencyStats
from repro.core.queuelut import (QueueLUT, build_queue_lut,
                                 default_queue_lut)
from repro.core.sweepspec import (KIND_CHANNEL_FIELD, KIND_DESIGN,
                                  KIND_IFACE, KIND_N_ACTIVE,
                                  KIND_QUEUE_MODEL, KIND_WORKLOAD_FIELD,
                                  Axis, SweepSpec, build_flat,
                                  build_flat_memsim, distribution_spec,
                                  sweep_spec)
from repro.core.workloads import NAMES, WORKLOADS

__all__ = [
    "COAXIAL_2X", "COAXIAL_4X", "COAXIAL_5X", "COAXIAL_ASYM", "DDR_BASELINE",
    "DESIGNS", "MemSystem", "evaluate", "Comparison", "SweepResult", "sweep",
    "Axis", "SweepSpec", "sweep_spec", "solve_spec", "design_gradient",
    "default_sweep", "register_design", "unregister_design", "get_design",
    "all_designs", "scoped_registry", "knee_point",
    "area_report", "pin_report", "design_cost", "edp_report",
    "sensitivity_latency", "sensitivity_cores", "ChannelConfig",
    "LatencyStats", "DistributionSweepResult", "distribution_spec",
    "distribution_sweep", "validate_calibration", "crosscheck_engines",
    "QUEUE_MODELS",
    "QueueLUT", "build_queue_lut", "default_queue_lut",
]


# ---------------------------------------------------------------------------
# Design registry.  Seeded with the paper's Table-2 points; configs and the
# planner register additional points (e.g. channel-count sweeps) at runtime.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, MemSystem] = {}


def register_design(sys: MemSystem, *, overwrite: bool = False) -> MemSystem:
    """Add a design point to the registry (and to every future sweep).

    Re-registering the SAME design is an idempotent no-op (the existing
    entry is returned and the sweep cache is left warm); only a
    *different* design under an existing name raises without
    ``overwrite`` -- that is the silent-shadowing case worth refusing.
    """
    prev = _REGISTRY.get(sys.name)
    if prev is not None:
        if prev == sys:
            return prev
        if not overwrite:
            raise ValueError(f"design {sys.name!r} already registered "
                             f"with different parameters")
    _REGISTRY[sys.name] = sys
    default_sweep.cache_clear()
    return sys


def unregister_design(name: str) -> MemSystem:
    """Remove a registered design point (the seed points may be removed
    too, but the DDR baseline is always re-added by :func:`sweep`)."""
    sys = _REGISTRY.pop(name)
    default_sweep.cache_clear()
    return sys


def get_design(name: str) -> MemSystem:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown design {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def all_designs() -> tuple[MemSystem, ...]:
    """All registered design points, registration-ordered."""
    return tuple(_REGISTRY.values())


for _d in DESIGNS:
    _REGISTRY[_d.name] = _d
del _d


@contextlib.contextmanager
def scoped_registry():
    """Snapshot both runtime registries; restore them on exit.

    Guards the design registry (this module) and the workload registry
    (:mod:`repro.core.workloads`) against mutation leaks: anything
    registered inside the ``with`` block -- measured devices, LLM
    workloads, planner candidates -- is rolled back afterwards, and the
    :func:`default_sweep` cache is invalidated iff the registries
    actually changed, so later sweeps solve exactly the pre-block world.
    Reentrant and exception-safe (restore runs in a ``finally``).
    """
    from repro.core import workloads as _workloads
    designs = dict(_REGISTRY)
    wls = dict(_workloads._REGISTRY)
    try:
        yield
    finally:
        changed = (_REGISTRY != designs
                   or _workloads._REGISTRY != wls)
        _REGISTRY.clear()
        _REGISTRY.update(designs)
        _workloads._REGISTRY.clear()
        _workloads._REGISTRY.update(wls)
        if changed:
            default_sweep.cache_clear()


@dataclasses.dataclass
class Comparison:
    """A design point evaluated against the DDR baseline."""

    sys: MemSystem
    base: ModelResult
    res: ModelResult
    names: tuple

    @property
    def speedup(self) -> np.ndarray:
        return self.res.speedup_vs(self.base)

    @property
    def geomean_speedup(self) -> float:
        return geomean(self.speedup, self.names)

    @property
    def n_above_2x(self) -> int:
        return int(np.sum(self.speedup > 2.0))

    @property
    def n_regressions(self) -> int:
        return int(np.sum(self.speedup < 0.995))

    @property
    def worst(self) -> tuple[str, float]:
        i = int(np.argmin(self.speedup))
        return self.names[i], float(self.speedup[i])

    @property
    def best(self) -> tuple[str, float]:
        i = int(np.argmax(self.speedup))
        return self.names[i], float(self.speedup[i])

    def row(self, name: str) -> dict:
        i = self.names.index(name)
        return dict(
            name=name, speedup=float(self.speedup[i]),
            base_latency_ns=float(self.base.latency_ns[i]),
            base_queue_ns=float(self.base.queue_ns[i]),
            latency_ns=float(self.res.latency_ns[i]),
            queue_ns=float(self.res.queue_ns[i]),
            base_rho=float(self.base.rho[i]), rho=float(self.res.rho[i]),
        )

    def summary(self) -> dict:
        return dict(
            design=self.sys.name,
            geomean_speedup=self.geomean_speedup,
            best=self.best, worst=self.worst,
            n_above_2x=self.n_above_2x, n_regressions=self.n_regressions,
            mean_base_queue_ns=float(np.mean(self.base.queue_ns)),
            mean_queue_ns=float(np.mean(self.res.queue_ns)),
            mean_base_rho=float(np.mean(self.base.rho)),
            mean_rho=float(np.mean(self.res.rho)),
            queue_share_of_latency=float(np.mean(
                self.base.queue_ns / self.base.latency_ns)),
            max_queue_share=float(np.max(
                self.base.queue_ns / self.base.latency_ns)),
        )


# ---------------------------------------------------------------------------
# The sweep engine.
# ---------------------------------------------------------------------------

_UNSET = object()


class _NamedAxes:
    """Shared axis plumbing for named-axis result containers (the
    model-sweep and distribution-sweep results both carry an ``axes``
    tuple and resolve coordinates the same way)."""

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(ax) for ax in self.axes)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(ax.name for ax in self.axes)

    def _axis_pos(self, name: str) -> int:
        for p, ax in enumerate(self.axes):
            if ax.name == name:
                return p
        raise KeyError(f"no axis {name!r} in sweep; axes: "
                       f"{list(self.axis_names)}")

    def axis(self, name: str) -> Axis:
        return self.axes[self._axis_pos(name)]


@dataclasses.dataclass(frozen=True)
class SweepResult(_NamedAxes):
    """Stacked model results over a grid of named axes.

    ``results`` arrays have shape ``spec shape + (n_workloads,)``; the
    axes (in grid order) name each dimension.  Individual
    :class:`ModelResult` slices and baseline :class:`Comparison` objects
    are views into the one batched solve -- no further compilation or
    fixed-point iteration happens after construction.  Cells are selected
    by coordinate, never by position: ``sw.sel(design="coaxial-4x",
    kappa=1.6)``, with numeric coordinates matched tolerantly
    (``iface_lat_ns=50`` and ``50.0`` resolve identically).
    """

    axes: tuple[Axis, ...]
    names: tuple[str, ...]
    results: ModelResult
    baseline_name: str = DDR_BASELINE.name
    workloads: tuple = WORKLOADS
    baseline_sys: MemSystem = DDR_BASELINE
    #: Length-1 axes recording the coordinates :meth:`sel` pinned, so the
    #: baseline reference and cost accounting keep honouring them.
    pinned: tuple[Axis, ...] = ()
    #: Queue-wait backend the grid was solved under; when a
    #: ``queue_model`` AXIS is present it overrides this scalar per cell.
    queue_model: str = "closed_form"
    #: Resolved :class:`QueueLUT` (memsim backend only) so the baseline
    #: reference re-solves against the same surface.
    lut: object = dataclasses.field(default=None, repr=False, compare=False)

    # -- legacy positional views (the historical D/L/C triple) ------------

    @property
    def designs(self) -> tuple[MemSystem, ...]:
        return self.axis("design").values

    @property
    def iface_lats(self) -> tuple:
        return self.axis("iface_lat_ns").values

    @property
    def cores(self) -> tuple[int, ...]:
        return tuple(int(v) for v in self.axis("n_active").values)

    def design_index(self, sys) -> int:
        return self.axis("design").index(sys)

    # -- coordinate resolution --------------------------------------------

    def _coord_index(self, ax: Axis, value, design=None) -> int:
        """Axis lookup + the iface aliasing rule: for a given design, its
        own premium and an equal explicit override are the same column
        (the solver's NaN mask makes them identical)."""
        try:
            return ax.index(value)
        except KeyError as err:
            if ax.kind == KIND_IFACE and design is not None:
                if value is None:
                    try:
                        return ax.index(design.iface_lat_ns)
                    except KeyError:
                        pass
                else:
                    try:
                        aliases = np.isclose(float(value),
                                             design.iface_lat_ns,
                                             rtol=1e-6, atol=1e-12)
                    except (TypeError, ValueError):
                        aliases = False
                    if aliases:
                        try:
                            return ax.index(None)
                        except KeyError:
                            pass
            raise err

    def _design_ctx(self, coords):
        """Validate coordinate names; resolve the design the coordinates
        address (the iface-aliasing context), if any."""
        for k in coords:
            if k not in self.axis_names:
                raise KeyError(f"no axis {k!r} in sweep; axes: "
                               f"{list(self.axis_names)}")
        if "design" in coords:
            dax = self.axis("design")
            return dax.values[dax.index(coords["design"])]
        return None

    def indices(self, **coords) -> tuple[int, ...]:
        """Full grid index from named coordinates.

        Axes of length 1 may be omitted; any longer axis must be pinned.
        """
        design = self._design_ctx(coords)
        out = []
        for ax in self.axes:
            if ax.name in coords:
                out.append(self._coord_index(ax, coords[ax.name], design))
            elif len(ax) == 1:
                out.append(0)
            else:
                raise KeyError(
                    f"axis {ax.name!r} has {len(ax)} coordinates; pass "
                    f"{ax.name}=<one of {list(ax.coords)}>")
        return tuple(out)

    def sel(self, **coords) -> "SweepResult":
        """Select coordinates by axis name; each selected axis is dropped.

        ``sw.sel(design="coaxial-4x", kappa=1.6)`` replaces the historical
        positional index triple.  Partial selection returns a reduced
        sweep over the remaining axes; the selected coordinates stay
        pinned, so :meth:`speedup_grid` / :meth:`pareto` keep comparing
        and costing the reduced grid at those coordinates.

        Example::

            >>> from repro.core import coaxial
            >>> sw = coaxial.sweep((coaxial.DDR_BASELINE,
            ...                     coaxial.COAXIAL_4X),
            ...                    iface_lat_grid=(None, 50.0))
            >>> sub = sw.sel(design="coaxial-4x", iface_lat_ns=50.0)
            >>> sub.axis_names           # selected axes are dropped
            ('n_active',)
            >>> sub.results.ipc.shape    # one cell x 35 workloads
            (1, 35)
            >>> sw.sel(design="coaxial-4x", iface_lat_ns=50
            ...        ).results.ipc.shape    # tolerant numeric lookup
            (1, 35)
        """
        design = self._design_ctx(coords)
        res = self.results
        kept: list[Axis] = []
        pins: list[Axis] = []
        pos = 0
        for ax in self.axes:
            if ax.name in coords:
                i = self._coord_index(ax, coords[ax.name], design)
                res = res[(slice(None),) * pos + (i,)]
                pins.append(Axis(ax.name, (ax.values[i],), ax.kind))
            else:
                kept.append(ax)
                pos += 1
        return dataclasses.replace(self, axes=tuple(kept), results=res,
                                   pinned=self.pinned + tuple(pins))

    def _legacy_coords(self, sys, iface_lat, n_active, coords) -> dict:
        coords = dict(coords)
        if sys is not None:
            coords.setdefault("design", sys)
        if iface_lat is not _UNSET:
            coords["iface_lat_ns"] = iface_lat
        elif "iface_lat_ns" in self.axis_names:
            coords.setdefault("iface_lat_ns", None)
        if n_active is not _UNSET:
            coords["n_active"] = n_active
        elif "n_active" in self.axis_names:
            coords.setdefault("n_active", hw.SIM_CORES)
        return coords

    def result(self, sys=None, *, iface_lat=_UNSET, n_active=_UNSET,
               **coords) -> ModelResult:
        """The ``(n_workloads,)`` ModelResult slice for one grid point."""
        coords = self._legacy_coords(sys, iface_lat, n_active, coords)
        return self.results[self.indices(**coords)]

    def comparison(self, sys, *, iface_lat=_UNSET, n_active=_UNSET,
                   **coords) -> Comparison:
        """``sys`` vs the DDR baseline at the same grid coordinates.

        The baseline is sliced from the same non-design cell as ``sys``
        (it ignores the latency override -- no CXL interface -- so any
        latency column serves as its reference).
        """
        coords = self._legacy_coords(sys, iface_lat, n_active, coords)
        idx = self.indices(**coords)
        p = self._axis_pos("design")
        bidx = idx[:p] + (self.design_index(self.baseline_name),) + idx[p + 1:]
        return Comparison(sys=self.axis("design").values[idx[p]],
                          base=self.results[bidx], res=self.results[idx],
                          names=self.names)

    # -- grid-level reductions --------------------------------------------

    def geomean_grid(self) -> np.ndarray:
        """Geomean speedup vs the in-grid baseline row, for every cell.

        Shape = the grid shape.  The reference is the baseline design at
        the SAME non-design coordinates, so axes that override the
        baseline too (workload or design-field axes) compare like against
        like; :meth:`speedup_grid` compares against the un-overridden
        baseline instead.  Once :meth:`sel` has pinned the design axis the
        in-grid baseline row is gone, so this delegates to
        :meth:`speedup_grid` (identical whenever no design-field axis is
        in play).
        """
        if "design" not in self.axis_names:
            return self.speedup_grid()
        p = self._axis_pos("design")
        b = self.design_index(self.baseline_name)
        ipc = self.results.ipc
        base = np.take(ipc, [b], axis=p)
        return np.exp(np.mean(np.log(ipc / base), axis=-1))

    @functools.cached_property
    def _baseline_ipc(self) -> np.ndarray:
        """IPC of the UN-overridden baseline design at every cell's
        workload / core-count coordinates (design and design-field axes
        pinned to the plain baseline): the fixed reference column for
        :meth:`speedup_grid` and :meth:`pareto`.

        The baseline only varies along ``n_active``, workload and
        ``queue_model`` axes (and the iface axis if the baseline itself
        is CXL), so only those are solved -- sel()-pinned coordinates
        included -- and the result is broadcast across the rest of the
        grid.  A queue-model axis is a per-backend re-solve (each backend
        gets its own reference: a memsim-backed cell is compared against
        the memsim-backed baseline, never across models).
        """
        base = self.baseline_sys
        varying = (KIND_N_ACTIVE, KIND_WORKLOAD_FIELD, KIND_QUEUE_MODEL) + (
            (KIND_IFACE,) if base.is_cxl else ())
        live = [ax for ax in self.axes if ax.kind in varying]
        pins = [ax for ax in self.pinned if ax.kind in varying]
        qax = next((ax for ax in live + pins
                    if ax.kind == KIND_QUEUE_MODEL), None)
        solve_live = [ax for ax in live if ax.kind != KIND_QUEUE_MODEL]
        solve_pins = [ax for ax in pins if ax.kind != KIND_QUEUE_MODEL]
        spec = SweepSpec((Axis("design", (base,), KIND_DESIGN),
                          *solve_live, *solve_pins))
        flat = build_flat(spec, pin_design=base)
        backends = (tuple(qax.values) if qax is not None
                    else (self.queue_model,))
        cells = []
        for qm in backends:
            res = cpu_model.solve_cells(
                flat["sysa"], n_active=flat["n_active"],
                iface_override_ns=flat["iface_override_ns"],
                workload_overrides=flat["workload_overrides"],
                baseline=base, workloads=self.workloads,
                queue_model=qm, lut=self.lut)
            w = res.ipc.shape[-1]
            cells.append(res.ipc.reshape(
                tuple(len(ax) for ax in solve_live) + (w,)))
        if qax is not None and qax in live:
            # Stack the per-backend references at the axis' live position.
            ipc = np.stack(cells, axis=live.index(qax))
        else:
            ipc = cells[0]
        w = ipc.shape[-1]
        # Broadcastable view: live-axis lengths in grid position, 1 elsewhere.
        bshape = tuple(len(ax) if ax.kind in varying else 1
                       for ax in self.axes) + (w,)
        return ipc.reshape(bshape)

    def speedup_grid(self) -> np.ndarray:
        """Geomean speedup of every cell vs the fixed, un-overridden
        baseline design (workload axes still apply to the reference --
        a modified workload is compared on both systems)."""
        ratio = self.results.ipc / self._baseline_ipc
        return np.exp(np.mean(np.log(ratio), axis=-1))

    def _effective_fields(self) -> dict[str, np.ndarray]:
        """Per-cell effective design fields: the design axis' own values,
        replaced wherever a design-field axis overrides them.  sel()-pinned
        axes participate as length-1 trailing dimensions, so a pinned
        design or field override still shapes the cost accounting."""
        from repro.core.sweepspec import _flat
        axes = self.axes + self.pinned
        ext = tuple(len(ax) for ax in axes)
        names = [ax.name for ax in axes]
        designs = axes[names.index("design")].values
        out = {}
        for f in ("dram_channels", "links", "llc_mb_per_core"):
            if f in names:
                q = names.index(f)
                eff = _flat(axes[q].values, q, ext)
            else:
                per_design = [float(getattr(d, f)) for d in designs]
                eff = _flat(per_design, names.index("design"), ext)
            # pinned axes are length 1, so the flat cell count equals the
            # live grid's -- collapse straight to the live shape.
            out[f] = eff.reshape(self.shape)
        return out

    def design_cost_grid(self) -> dict[str, np.ndarray]:
        """Per-cell ``rel_area`` / ``rel_pins`` from the effective design
        fields -- a swept LLC or channel count changes the cost too."""
        eff = self._effective_fields()
        return design_cost(eff["dram_channels"], eff["links"],
                           eff["llc_mb_per_core"])

    def p99_grid(self) -> np.ndarray:
        """Worst-workload p99 LLC-miss latency per cell (ns).

        Max (not geomean) across the workload axis: the tail story is a
        guarantee, so the slowest workload's p99 is the cell's p99.  All
        NaN unless the grid was solved under ``queue_model="memsim"``
        (the closed form has no tail law).
        """
        return np.max(self.results.latency_p99_ns, axis=-1)

    def _cell_point(self, cell, flat_costs, gm) -> dict:
        """Named coordinates + cost/speedup payload for one flat cell."""
        idx = np.unravel_index(cell, self.shape)
        point = {ax.name: ax.coords[0] for ax in self.pinned}
        point.update({ax.name: ax.coords[i]
                      for ax, i in zip(self.axes, idx)})
        point.update(
            rel_area=float(flat_costs["rel_area"][cell]),
            rel_pins=float(flat_costs["rel_pins"][cell]),
            geomean_speedup=float(gm[cell]))
        return point

    def pareto(self, *, cost: str = "rel_area",
               tail: bool = False) -> list[dict]:
        """The non-dominated (min cost, max geomean speedup) frontier over
        every grid cell.

        ``cost`` is ``"rel_area"`` or ``"rel_pins"``.  Pin axes first with
        :meth:`sel` to restrict the subset: ``sw.sel(n_active=12).
        pareto()``.  Returns frontier points sorted by ascending cost,
        each a dict of the cell's named coordinates plus ``rel_area``,
        ``rel_pins`` and ``geomean_speedup`` (vs the un-overridden
        baseline).

        ``tail=True`` ranks by ``(cost, mean speedup, p99)`` instead: a
        cell survives unless some other cell is at least as good on ALL
        of (min cost, max geomean speedup, min worst-workload p99) and
        strictly better on one -- so a design that pays a little area to
        cut the tail stays on the frontier even when a cheaper point
        matches its mean.  Each point then also carries
        ``latency_p99_ns`` (from :meth:`p99_grid`).  Requires a
        ``queue_model="memsim"`` solve; raises otherwise (the closed
        form's tail is NaN).

        Example::

            >>> from repro.core import coaxial
            >>> sw = coaxial.sweep((coaxial.DDR_BASELINE,
            ...                     coaxial.COAXIAL_2X,
            ...                     coaxial.COAXIAL_4X))
            >>> front = sw.pareto(cost="rel_area")
            >>> [round(p["rel_area"], 3) for p in front] == sorted(
            ...     round(p["rel_area"], 3) for p in front)
            True
            >>> front[-1]["design"]      # max speedup ends the frontier
            'coaxial-4x'
        """
        costs = self.design_cost_grid()
        if cost not in costs:
            raise ValueError(f"cost must be one of {sorted(costs)}, "
                             f"got {cost!r}")
        gm = self.speedup_grid().reshape(-1)
        flat_costs = {k: v.reshape(-1) for k, v in costs.items()}
        if tail:
            return self._pareto_tail(cost, flat_costs, gm)
        order = np.lexsort((-gm, flat_costs[cost]))
        frontier, best = [], -np.inf
        for cell in order:
            if gm[cell] <= best + 1e-12:
                continue
            best = gm[cell]
            frontier.append(self._cell_point(cell, flat_costs, gm))
        return frontier

    def _pareto_tail(self, cost, flat_costs, gm) -> list[dict]:
        """3-objective (min cost, max speedup, min p99) non-dominated
        filter behind ``pareto(tail=True)``."""
        p99 = self.p99_grid().reshape(-1)
        if np.all(np.isnan(p99)):
            raise ValueError(
                "pareto(tail=True) needs p99 latencies; solve the sweep "
                "under queue_model='memsim' (the closed form has no tail "
                "law)")
        c = flat_costs[cost]
        eps = 1e-12
        frontier, seen = [], set()
        for cell in np.lexsort((p99, -gm, c)):
            dominated = np.any((c <= c[cell] + eps)
                               & (gm >= gm[cell] - eps)
                               & (p99 <= p99[cell] + eps)
                               & ((c < c[cell] - eps)
                                  | (gm > gm[cell] + eps)
                                  | (p99 < p99[cell] - eps)))
            key = (round(float(c[cell]), 12), round(float(gm[cell]), 12),
                   round(float(p99[cell]), 9))
            if dominated or key in seen:
                continue
            seen.add(key)
            point = self._cell_point(cell, flat_costs, gm)
            point["latency_p99_ns"] = float(p99[cell])
            frontier.append(point)
        return frontier


def knee_point(frontier, *, cost: str = "rel_area") -> dict:
    """Frontier point farthest (perpendicular) from the endpoint chord.

    The "buy this one" design of a cost-vs-speedup frontier (as returned
    by :meth:`SweepResult.pareto`): beyond the knee, each extra unit of
    ``cost`` buys visibly less speedup.  Degenerate frontiers (<= 2
    points) return the last (max-speedup) point.
    """
    if len(frontier) <= 2:
        return frontier[-1]
    xy = np.array([[p[cost], p["geomean_speedup"]] for p in frontier])
    a, b = xy[0], xy[-1]
    chord = b - a
    chord = chord / np.linalg.norm(chord)
    rel = xy - a
    dist = np.abs(rel[:, 0] * chord[1] - rel[:, 1] * chord[0])
    return frontier[int(np.argmax(dist))]


def solve_spec(spec: SweepSpec, *, workloads=WORKLOADS,
               baseline: MemSystem = DDR_BASELINE,
               queue_model: str = "closed_form",
               lut=None) -> SweepResult:
    """Solve a named-axis :class:`SweepSpec` in one jitted, vmapped pass.

    The baseline is prepended to the design axis if absent so comparisons
    can always be sliced; two different designs sharing a name are
    rejected (results are name-keyed).  However many axes the spec
    declares, the grid costs ONE XLA trace per flattened cell count --
    per backend: ``queue_model`` picks the fixed point's queue-wait
    backend for the whole grid, and a ``queue_model`` AXIS in the spec
    solves one such pass per backend and stacks them (the only
    non-array axis, since the backend is a trace-level choice).
    """
    axes = list(spec.axes)
    try:
        p = [ax.name for ax in axes].index("design")
    except ValueError:
        p = 0
        axes.insert(0, Axis("design", tuple(all_designs()), KIND_DESIGN))
    designs = tuple(axes[p].values)
    if not any(d.name == baseline.name for d in designs):
        designs = (baseline,) + designs
    seen: dict[str, MemSystem] = {}
    for d in designs:
        prev = seen.setdefault(d.name, d)
        if prev != d:
            # Results are sliced by name -- two different designs under one
            # name would silently shadow each other.
            raise ValueError(
                f"two different designs named {d.name!r} in one sweep")
    axes[p] = Axis("design", tuple(seen.values()), KIND_DESIGN)
    qpos = [i for i, ax in enumerate(axes) if ax.kind == KIND_QUEUE_MODEL]
    if len(qpos) > 1:
        raise ValueError("at most one queue_model axis per sweep")
    if qpos:
        if queue_model != "closed_form":
            raise ValueError(
                "pass the backend either as a queue_model axis or as the "
                "queue_model argument, not both")
        q = qpos[0]
        qax = axes.pop(q)
        sub = SweepSpec(tuple(axes))
        subs = [solve_spec(sub, workloads=workloads, baseline=baseline,
                           queue_model=qm, lut=lut)
                for qm in qax.values]
        res = ModelResult(**{
            f.name: np.stack([getattr(s.results, f.name) for s in subs],
                             axis=q)
            for f in dataclasses.fields(ModelResult)})
        first = subs[0]
        return dataclasses.replace(
            first, axes=first.axes[:q] + (qax,) + first.axes[q:],
            results=res,
            lut=next((s.lut for s in subs if s.lut is not None), None))
    spec = SweepSpec(tuple(axes))
    flat = build_flat(spec)
    # Resolve AFTER flattening: a harvesting design (or harvest_duty /
    # harvest_bw_gbps design_field axis) needs the 5-D default surface.
    lut = cpu_model.resolve_queue_lut(
        queue_model, lut,
        harvest=cpu_model._any_harvest(flat["sysa"],
                                       flat["design_overrides"]))
    res = cpu_model.solve_cells(
        flat["sysa"], n_active=flat["n_active"],
        iface_override_ns=flat["iface_override_ns"],
        design_overrides=flat["design_overrides"],
        workload_overrides=flat["workload_overrides"],
        baseline=baseline, workloads=workloads,
        queue_model=queue_model, lut=lut)
    return SweepResult(
        axes=spec.axes, names=tuple(w.name for w in workloads),
        results=res.reshape(*spec.shape), baseline_name=baseline.name,
        workloads=tuple(workloads), baseline_sys=baseline,
        queue_model=queue_model, lut=lut)


def sweep(designs=None, *, iface_lat_grid=(None,),
          n_active_grid=(hw.SIM_CORES,), workloads=WORKLOADS,
          baseline: MemSystem = DDR_BASELINE,
          queue_model: str = "closed_form", lut=None) -> SweepResult:
    """Solve the historical designs x latencies x cores grid.

    Thin shim over :func:`solve_spec` -- the positional triple is just the
    named axes ``(design, iface_lat_ns, n_active)``, so results keep the
    legacy ``(D, L, C, n_workloads)`` layout bit-for-bit.
    ``iface_lat_grid`` entries override the CXL premium of CXL designs
    (``None`` = each design's own value).  ``n_active_grid`` are active
    core counts; calibration is redone per core count, as in the paper.
    ``queue_model="memsim"`` solves the same grid through the DES-derived
    :class:`QueueLUT` instead of the closed form.
    """
    spec = sweep_spec(
        design=tuple(designs) if designs is not None else all_designs(),
        iface_lat_ns=tuple(iface_lat_grid),
        n_active=tuple(n_active_grid))
    return solve_spec(spec, workloads=workloads, baseline=baseline,
                      queue_model=queue_model, lut=lut)


@functools.lru_cache(maxsize=None)
def default_sweep() -> SweepResult:
    """The shared grid behind every figure/table: all registered designs,
    both §6.4 latency points, all §6.5 core counts.  One compile serves the
    entire benchmark report; cache is invalidated when the registry changes.
    """
    return sweep(iface_lat_grid=(None, hw.CXL_LAT_PESSIMISTIC_NS),
                 n_active_grid=(1, 4, 8, hw.SIM_CORES))


def _unshadow(sys: MemSystem) -> MemSystem:
    """Rename a modified design that still carries the baseline's name.

    Sweep results are name-keyed; without the rename such a design would
    either shadow the comparator or be rejected by sweep()'s dedup check.
    """
    if sys.name == DDR_BASELINE.name and sys != DDR_BASELINE:
        return dataclasses.replace(sys, name=f"{sys.name}*")
    return sys


def evaluate(sys: MemSystem = COAXIAL_4X, *, n_active: int = hw.SIM_CORES,
             iface_lat_ns: float | None = None,
             workloads=WORKLOADS) -> Comparison:
    res_sys = sys
    if iface_lat_ns is not None and not sys.is_cxl:
        # The sweep grid's latency override only reaches CXL designs, but
        # evaluate() historically applied an explicit premium to any design
        # -- bake it into the design point.
        res_sys = dataclasses.replace(
            sys, name=f"{sys.name}@{iface_lat_ns:g}ns",
            iface_lat_ns=float(iface_lat_ns))
    res_sys = _unshadow(res_sys)
    sw = sweep((DDR_BASELINE, res_sys), iface_lat_grid=(iface_lat_ns,),
               n_active_grid=(n_active,), workloads=workloads)
    cmp = sw.comparison(res_sys, iface_lat=iface_lat_ns, n_active=n_active)
    if res_sys is not sys:
        cmp = dataclasses.replace(cmp, sys=sys)
    return cmp


def sensitivity_latency(latencies_ns=(hw.CXL_LAT_NS,
                                      hw.CXL_LAT_PESSIMISTIC_NS),
                        sys: MemSystem = COAXIAL_4X) -> dict:
    """§6.4: COAXIAL speedup at 30ns vs 50ns CXL premium (Fig 8)."""
    if not sys.is_cxl:
        # Latency overrides bypass non-CXL designs inside the grid; per-
        # point evaluate() bakes the premium in (still one compile total).
        return {lat: evaluate(sys, iface_lat_ns=lat) for lat in latencies_ns}
    sys = _unshadow(sys)
    sw = sweep((DDR_BASELINE, sys), iface_lat_grid=tuple(latencies_ns))
    return {lat: sw.comparison(sys, iface_lat=lat) for lat in latencies_ns}


def sensitivity_cores(cores=(1, 4, 8, 12), sys: MemSystem = COAXIAL_4X):
    """§6.5: speedup vs active cores; baseline at the same core count."""
    sys = _unshadow(sys)
    sw = sweep((DDR_BASELINE, sys), n_active_grid=tuple(cores))
    return {n: sw.comparison(sys, n_active=n) for n in cores}


# ---------------------------------------------------------------------------
# Distribution sweeps: the DES (memsim) as a first-class sweep target.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistributionSweepResult(_NamedAxes):
    """Stacked DES latency distributions over a grid of named channel axes.

    ``stats`` leaves have the grid shape (``hist`` with one trailing bin
    axis); the axes name each dimension.  Cells are selected by
    coordinate, never by position, with the same tolerant numeric
    matching and KeyError UX as :class:`SweepResult`:
    ``sw.sel(rho=0.6, kappa=2.0, cxl_lat_ns=30.0)`` returns the cell's
    :class:`LatencyStats` once every axis is pinned, or a reduced sweep
    over the remaining axes otherwise.
    """

    axes: tuple[Axis, ...]
    stats: LatencyStats
    base: ChannelConfig
    steps: int
    warmup: int
    seed: int
    reps: int = 1
    #: Which memsim engine produced the distributions ("timestep" or
    #: "event"); the grid cost one trace of that engine's kernel.
    engine: str = "timestep"

    def sel(self, **coords):
        """Select coordinates by axis name; each selected axis is dropped.

        Numeric coordinates match tolerantly (``rho=0.6`` finds a
        linspace-rounded ``0.6000000001`` cell); an unknown axis or
        coordinate raises one clear :class:`KeyError` listing the valid
        choices.  Returns the cell's :class:`LatencyStats` when no axes
        remain, else a reduced :class:`DistributionSweepResult`.
        """
        for k in coords:
            if k not in self.axis_names:
                raise KeyError(f"no axis {k!r} in sweep; axes: "
                               f"{list(self.axis_names)}")
        stats = self.stats
        kept: list[Axis] = []
        pos = 0
        for ax in self.axes:
            if ax.name in coords:
                i = ax.index(coords[ax.name])
                stats = stats[(slice(None),) * pos + (i,)]
            else:
                kept.append(ax)
                pos += 1
        if not kept:
            return stats
        return dataclasses.replace(self, axes=tuple(kept), stats=stats)

    def cell(self, **coords) -> LatencyStats:
        """The single-cell :class:`LatencyStats` at fully pinned
        coordinates (axes of length 1 may be omitted)."""
        full = dict(coords)
        for ax in self.axes:
            if ax.name not in full:
                if len(ax) == 1:
                    full[ax.name] = ax.values[0]
                else:
                    raise KeyError(
                        f"axis {ax.name!r} has {len(ax)} coordinates; pass "
                        f"{ax.name}=<one of {list(ax.coords)}>")
        return self.sel(**full)

    def curve(self, along: str, field: str = "mean_ns", **coords):
        """(axis coordinates, stat values) along one axis, other axes
        pinned by ``coords`` -- the Fig-2a load-latency curve shape."""
        ax = self.axis(along)
        sub = self.sel(**coords) if coords else self
        if isinstance(sub, LatencyStats) or sub.axis_names != (along,):
            raise KeyError(
                f"curve(along={along!r}) needs every other axis pinned; "
                f"axes: {list(self.axis_names)}")
        return np.asarray(ax.values, np.float64), getattr(sub.stats, field)


def distribution_sweep(spec: SweepSpec | None = None, *,
                       base: ChannelConfig | None = None,
                       steps: int = 200_000, seed: int = 0,
                       warmup: int | None = None, reps: int = 1,
                       engine: str = "timestep", devices=None,
                       stream_ids=None, chunk: int | None = None,
                       **axes) -> DistributionSweepResult:
    """Run the DES over a named-axis grid of channel parameters.

    Pass a memsim-targeted :class:`SweepSpec` (from
    :func:`distribution_spec`) or the axes directly as keywords.
    However many axes the grid has, it lowers to ONE jitted simulation
    over the flattened cell batch (``reps`` independent replicas per cell
    are merged into the histograms for variance reduction -- lanes are
    nearly free next to the per-step dispatch).  ``base`` supplies
    every unbound channel field (default: a plain DDR channel at the
    field defaults).  ``engine`` picks the simulation engine:
    ``"timestep"`` (the bit-exact 1-ns reference) or ``"event"`` (the
    per-request Lindley engine -- several times faster at the same
    ``steps`` budget, most on narrow batches and low-rho cells; see
    ``benchmarks/memsim_speed.py``, :mod:`repro.core.memsim` and
    :func:`crosscheck_engines`).  ``devices`` shards the flattened cell
    batch over that many host devices (``None`` consults
    ``$REPRO_DES_DEVICES``; ``"auto"`` = all local devices) --
    bit-identical results at any device count, wall-clock only.

    Example (doctest-sized step budget; real sweeps use the 200k
    default)::

        >>> from repro.core import coaxial
        >>> sw = coaxial.distribution_sweep(rho=(0.2, 0.6),
        ...                                 cxl_lat_ns=(0.0, 30.0),
        ...                                 steps=20_000, reps=2)
        >>> sw.shape                     # ONE lax.scan for the 4 cells
        (2, 2)
        >>> cell = sw.sel(rho=0.6, cxl_lat_ns=30.0)   # -> LatencyStats
        >>> bool(cell.p90_ns >= cell.p50_ns)
        True
        >>> loaded = float(sw.sel(rho=0.6, cxl_lat_ns=0.0).mean_ns)
        >>> idle = float(sw.sel(rho=0.2, cxl_lat_ns=0.0).mean_ns)
        >>> loaded > idle                # more load, more queueing
        True
    """
    if spec is None:
        spec = distribution_spec(**axes)
    elif axes:
        raise TypeError("pass a spec OR axis keywords, not both")
    flat = build_flat_memsim(spec, base=base)
    warmup = memsim.default_warmup(steps) if warmup is None else int(warmup)
    # ``stream_ids``/``chunk`` pass straight through to the simulator:
    # the canonical stream contract of QueueLUT-store builds (per-cell
    # ids over the C-order flattened grid, width-pinned chunk schedule
    # -- see memsim.simulate_cells and queuelut.cell_stream_ids).
    stats = memsim.simulate_cells(
        flat["cha"], overrides=flat["overrides"], steps=steps, seed=seed,
        warmup=warmup, reps=reps, engine=engine, devices=devices,
        stream_ids=stream_ids, chunk=chunk)
    return DistributionSweepResult(
        axes=spec.axes, stats=stats.reshape(*spec.shape),
        base=base if base is not None else ChannelConfig(rho=0.5),
        steps=steps, warmup=warmup, seed=seed, reps=reps, engine=engine)


#: Default rho anchors for the DES <-> closed-form cross-check.
CALIBRATION_RHOS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8)
#: Cross-check tolerances: relative mean / p90 / stdev deviation per
#: anchor.  The stdev gate is deliberately loose: the closed form's sigma
#: is a §6.2 workload-level fit (sqrt(sigma_base^2 + W_q^2)) while the
#: DES measures the channel's own heavy-tailed dispersion, which runs up
#: to ~2x that fit at mid rho -- the gate only catches the surface
#: drifting out of that known envelope.
CALIBRATION_MEAN_TOL = 0.15
CALIBRATION_P90_TOL = 0.20
CALIBRATION_STDEV_TOL = 1.25


def validate_calibration(rhos=CALIBRATION_RHOS, *, kappa: float = 1.0,
                         cxl_lat_ns: float = 0.0, steps: int = 200_000,
                         seed: int = 0, warmup: int | None = None,
                         reps: int = 48, engine: str = "timestep",
                         devices=None,
                         mean_tol: float = CALIBRATION_MEAN_TOL,
                         p90_tol: float = CALIBRATION_P90_TOL,
                         stdev_tol: float = CALIBRATION_STDEV_TOL) -> dict:
    """Cross-validate the DES against the closed-form queueing model.

    The two halves of the reproduction -- ``queueing``'s calibrated
    closed form and ``memsim``'s mechanistic DES -- must tell the same
    story.  This runs ONE batched distribution sweep over the rho anchors
    and compares DES mean / p90 / stdev against
    :func:`queueing.closed_form_stats` at every anchor.  ``engine``
    selects the DES engine; BOTH must pass the same gates (the event
    engine is additionally cross-checked against the timestep engine by
    :func:`crosscheck_engines`).

    Returns ``anchors`` (one row per rho with both values and the
    relative deltas), ``max_abs_mean_err`` / ``max_abs_p90_err`` /
    ``max_abs_stdev_err``, the tolerances, an overall ``ok`` flag, and
    the ``sweep`` itself for further slicing.  Benchmarks surface the
    per-anchor deltas as ``fig2a.crosscheck.*`` rows so calibration
    drift shows up in CI.

    Example (doctest-sized budget; the gates are meant for the 200k
    default)::

        >>> from repro.core import coaxial
        >>> val = coaxial.validate_calibration(rhos=(0.3, 0.5),
        ...                                    steps=20_000, reps=4)
        >>> [a["rho"] for a in val["anchors"]]
        [0.3, 0.5]
        >>> set(val) >= {"anchors", "ok", "max_abs_stdev_err"}
        True
        >>> all(k in val["anchors"][0] for k in
        ...     ("des_mean_ns", "closed_mean_ns", "stdev_err"))
        True
    """
    rhos = tuple(float(r) for r in rhos)
    base = ChannelConfig(rho=0.5, kappa=float(kappa),
                         cxl_lat_ns=float(cxl_lat_ns))
    sw = distribution_sweep(distribution_spec(rho=rhos), base=base,
                            steps=steps, seed=seed, warmup=warmup,
                            reps=reps, engine=engine, devices=devices)
    anchors = []
    for r in rhos:
        des = sw.sel(rho=r)
        cf = {k: float(v) for k, v in queueing.closed_form_stats(
            r, kappa=kappa, cxl_lat_ns=cxl_lat_ns).items()}
        row = dict(rho=r,
                   des_mean_ns=float(des.mean_ns),
                   closed_mean_ns=cf["mean_ns"],
                   mean_err=float(des.mean_ns) / cf["mean_ns"] - 1.0,
                   des_p90_ns=float(des.p90_ns),
                   closed_p90_ns=cf["p90_ns"],
                   p90_err=float(des.p90_ns) / cf["p90_ns"] - 1.0,
                   des_stdev_ns=float(des.stdev_ns),
                   closed_stdev_ns=cf["stdev_ns"],
                   stdev_err=float(des.stdev_ns) / cf["stdev_ns"] - 1.0)
        anchors.append(row)
    max_mean = max(abs(a["mean_err"]) for a in anchors)
    max_p90 = max(abs(a["p90_err"]) for a in anchors)
    max_stdev = max(abs(a["stdev_err"]) for a in anchors)
    return dict(anchors=anchors, max_abs_mean_err=max_mean,
                max_abs_p90_err=max_p90, max_abs_stdev_err=max_stdev,
                mean_tol=mean_tol, p90_tol=p90_tol, stdev_tol=stdev_tol,
                engine=engine,
                ok=bool(max_mean <= mean_tol and max_p90 <= p90_tol
                        and max_stdev <= stdev_tol),
                sweep=sw)


#: Engine-vs-engine agreement gates: the two engines share every law but
#: not the time axis (1-ns Bernoulli lattice vs continuous-time Poisson
#: thinning), so they agree statistically, not bitwise; the gates bound
#: the relative mean / p90 deviation at every anchor.
ENGINE_MEAN_TOL = 0.10
ENGINE_P90_TOL = 0.15
#: Noise allowance on top of the relative gates: an anchor whose engine
#: delta lies within ``k`` batched-means standard errors of zero passes
#: even if the relative deviation exceeds the tolerance -- at low rho the
#: waits are fractions of a bin and a few-percent absolute delta is pure
#: replica noise, not a law drift.
ENGINE_SE_K = 3.0


def crosscheck_engines(rhos=CALIBRATION_RHOS, *, kappa: float = 1.0,
                       cxl_lat_ns: float = 0.0, steps: int = 200_000,
                       seed: int = 0, warmup: int | None = None,
                       reps: int = 32,
                       mean_tol: float = ENGINE_MEAN_TOL,
                       p90_tol: float = ENGINE_P90_TOL,
                       se_k: float = ENGINE_SE_K, devices=None,
                       base: "memsim.ChannelConfig | None" = None) -> dict:
    """Statistical cross-check of the two memsim engines at the closed-form
    rho anchors.

    Runs the SAME anchor grid through both engines at the same ``steps``
    budget (the event engine converts it to its request budget) and
    gates the relative mean (<= 10%) and p90 (<= 15%) deviation per
    anchor -- the mechanism-level counterpart of
    :func:`validate_calibration`'s DES-vs-closed-form gates.

    The gate is standard-error-aware: the ``reps`` independent replicas
    double as batches for a batched-means SE estimate of each engine's
    mean/p90, and an anchor passes if its relative deviation is within
    tolerance OR its delta is within ``se_k`` combined standard errors of
    zero (``|z| <= se_k``) -- so a tight budget fails only on real law
    drift, never on replica noise (with ``reps < 2`` the SE is undefined
    and the pure relative gate applies).  Returns one row per anchor
    (values, relative errors, per-engine SEs and the z-scores) plus
    ``max_abs_mean_err`` / ``max_abs_p90_err`` and an ``ok`` flag.

    ``base`` replaces the default anchor channel wholesale (its ``rho``
    is overridden per anchor) -- e.g. a harvesting configuration
    (``harvest_duty``/``harvest_bw_gbps``) to gate engine agreement on
    the harvested mechanism at every anchor; ``kappa``/``cxl_lat_ns``
    are ignored when it is given.
    """
    rhos = tuple(float(r) for r in rhos)
    if base is None:
        base = ChannelConfig(rho=0.5, kappa=float(kappa),
                             cxl_lat_ns=float(cxl_lat_ns))
    spec = distribution_spec(rho=rhos)
    flat = build_flat_memsim(spec, base=base)
    warm = memsim.default_warmup(steps) if warmup is None else int(warmup)
    sweeps, per_rep = {}, {}
    for eng in memsim.ENGINES:
        # ONE simulation per engine: per-replica stats for the SE, merged
        # histograms (bit-identical to a keep_reps=False run) for the
        # headline numbers and the returned sweeps.
        per_rep[eng] = memsim.simulate_cells(
            flat["cha"], overrides=flat["overrides"], steps=int(steps),
            seed=seed, warmup=warm, reps=reps, engine=eng,
            devices=devices, keep_reps=True)
        merged = memsim.merge_reps(per_rep[eng])
        sweeps[eng] = DistributionSweepResult(
            axes=spec.axes, stats=merged.reshape(*spec.shape), base=base,
            steps=int(steps), warmup=warm, seed=seed, reps=reps,
            engine=eng)

    def se(field, eng, i):
        """Batched-means standard error of the merged statistic: the
        replicas are iid equal-weight batches, so the spread of their
        per-replica statistics estimates it directly."""
        batch = np.asarray(getattr(per_rep[eng], field))[:, i]
        if batch.shape[0] < 2:
            return np.nan
        return float(np.std(batch, ddof=1) / np.sqrt(batch.shape[0]))

    anchors = []
    for i, r in enumerate(rhos):
        ts = sweeps["timestep"].sel(rho=r)
        ev = sweeps["event"].sel(rho=r)
        row = dict(rho=r,
                   timestep_mean_ns=float(ts.mean_ns),
                   event_mean_ns=float(ev.mean_ns),
                   mean_err=float(ev.mean_ns) / float(ts.mean_ns) - 1.0,
                   timestep_p90_ns=float(ts.p90_ns),
                   event_p90_ns=float(ev.p90_ns),
                   p90_err=float(ev.p90_ns) / float(ts.p90_ns) - 1.0)
        for stat, field in (("mean", "mean_ns"), ("p90", "p90_ns")):
            se_d = np.sqrt(se(field, "timestep", i) ** 2 +
                           se(field, "event", i) ** 2)
            delta = row[f"event_{field}"] - row[f"timestep_{field}"]
            # A zero/NaN SE degenerates cleanly: zero delta passes with
            # z = 0, any other delta falls back to the relative gate.
            z = delta / se_d if se_d > 0 else (
                0.0 if delta == 0.0 else np.copysign(np.inf, delta))
            row[f"{stat}_se_ns"] = float(se_d)
            row[f"{stat}_z"] = float(z)
            # NaN SE (reps < 2) makes |z| <= k False: pure relative gate.
            row[f"{stat}_ok"] = bool(abs(row[f"{stat}_err"]) <= (
                mean_tol if stat == "mean" else p90_tol)
                or abs(z) <= se_k)
        row["ok"] = row["mean_ok"] and row["p90_ok"]
        anchors.append(row)
    max_mean = max(abs(a["mean_err"]) for a in anchors)
    max_p90 = max(abs(a["p90_err"]) for a in anchors)
    return dict(anchors=anchors, max_abs_mean_err=max_mean,
                max_abs_p90_err=max_p90, mean_tol=mean_tol,
                p90_tol=p90_tol, se_k=se_k, sweeps=sweeps,
                ok=all(a["ok"] for a in anchors))


# ---------------------------------------------------------------------------
# Table 1 / Table 2: area and pins for the full 144-core server.
# ---------------------------------------------------------------------------

FULL_CORES = 144
FULL_DDR_CHANNELS = 12


def _die_area(cores, llc_mb, ddr_ch, pcie_x8):
    return (cores * hw.AREA_ZEN3_CORE + llc_mb * hw.AREA_L3_PER_MB +
            ddr_ch * hw.AREA_DDR_CH + pcie_x8 * hw.AREA_PCIE_X8)


def design_cost(dram_channels, links, llc_mb_per_core) -> dict:
    """Vectorized Table-1/2 area & pin accounting for arbitrary field
    values (inputs broadcast together; ``is_cxl`` derives from the link
    count).  The shared core behind :func:`area_report` and
    :meth:`SweepResult.design_cost_grid` / :meth:`SweepResult.pareto`."""
    ch = np.asarray(dram_channels, np.float64)
    lk = np.asarray(links, np.float64)
    llc = np.asarray(llc_mb_per_core, np.float64)
    base = _die_area(FULL_CORES, FULL_CORES * 2, FULL_DDR_CHANNELS, 0)
    scale = FULL_CORES // hw.SIM_CORES
    ddr_ch = np.where(lk > 0, 0.0, ch * scale)
    pcie_x8 = lk * scale
    area = _die_area(FULL_CORES, FULL_CORES * llc, ddr_ch, pcie_x8)
    pins = ddr_ch * hw.DDR5_PINS + pcie_x8 * hw.PCIE_X8_PINS
    return dict(rel_area=area / base, mem_pins=pins,
                rel_pins=pins / (12 * hw.DDR5_PINS))


def area_report(designs=None) -> dict:
    """Reproduces Table 2's relative-area column from Table 1's entries.

    Derived from each registered design's own fields (LLC per core, links,
    channels) scaled 12-core slice -> 144-core server, so registry
    additions get Table-2 accounting for free.
    """
    out = {}
    for sys in (designs if designs is not None else all_designs()):
        c = design_cost(sys.dram_channels, sys.links, sys.llc_mb_per_core)
        out[sys.name] = dict(rel_area=float(c["rel_area"]),
                             mem_pins=int(c["mem_pins"]),
                             rel_pins=float(c["rel_pins"]))
    return out


def pin_report() -> dict:
    """§4.1: pins and peak bandwidth per interface choice."""
    ddr_per_pin = hw.DDR5_CH_BW_GBPS / hw.DDR5_PINS
    # The paper's "4x" compares PCIe's *per-direction* bandwidth per pin
    # against DDR's combined-direction figure (conservative: PCIe moves the
    # same bytes in the other direction simultaneously, §2.3).
    x8_per_pin_dir = hw.PCIE_X8_GBPS_PER_DIR / hw.PCIE_X8_PINS
    return dict(
        ddr5_pins=hw.DDR5_PINS,
        ddr5_peak_gbps=hw.DDR5_CH_BW_GBPS,
        ddr5_gbps_per_pin=ddr_per_pin,
        x8_pins=hw.PCIE_X8_PINS,
        x8_peak_gbps_per_dir=hw.PCIE_X8_GBPS_PER_DIR,
        x8_gbps_per_pin_per_dir=x8_per_pin_dir,
        x8_gbps_per_pin_duplex=2 * hw.PCIE_X8_GBPS_PER_DIR / hw.PCIE_X8_PINS,
        bw_per_pin_ratio=x8_per_pin_dir / ddr_per_pin,
        bw_per_pin_ratio_duplex=2 * x8_per_pin_dir / ddr_per_pin,
    )


# ---------------------------------------------------------------------------
# Table 5: power and EDP for the 144-core server.
# ---------------------------------------------------------------------------

def _dimm_power(channels, util):
    return channels * (hw.DIMM_STATIC_W_PER_CH + hw.DIMM_DYN_W_PER_CH * util)


def edp_report(sys: MemSystem = COAXIAL_4X, *,
               cmp: Comparison | None = None) -> dict:
    """§6.6 power/EDP model.  Pass ``cmp`` (e.g. a sweep slice) to reuse an
    already-solved comparison instead of re-evaluating."""
    if cmp is None:
        cmp = evaluate(sys)
    # Scale channel counts 12-core sim -> 144-core server (x12).
    scale = FULL_CORES // hw.SIM_CORES
    base_ch = DDR_BASELINE.dram_channels * scale
    sys_ch = sys.dram_channels * scale
    lanes = sys.links * scale * 8

    util_base = float(np.mean(cmp.base.rho))
    util_sys = float(np.mean(cmp.res.rho))

    p_base = dict(
        package_w=hw.PKG_POWER_W,
        ddr_mc_phy_w=base_ch * hw.DDR_MC_PHY_W_PER_CH,
        dimm_w=_dimm_power(base_ch, util_base),
        cxl_iface_w=0.0)
    p_sys = dict(
        package_w=hw.PKG_POWER_W,
        ddr_mc_phy_w=sys_ch * hw.DDR_MC_PHY_W_PER_CH,
        dimm_w=_dimm_power(sys_ch, util_sys),
        cxl_iface_w=lanes * hw.PCIE_LANE_POWER_W)

    total_base = sum(p_base.values())
    total_sys = sum(p_sys.values())
    cpi_base = geomean(cmp.base.cpi)
    cpi_sys = geomean(cmp.res.cpi)
    edp_base = total_base * cpi_base**2
    edp_sys = total_sys * cpi_sys**2
    return dict(
        baseline=dict(**p_base, total_w=total_base, cpi=cpi_base,
                      util=util_base, edp=edp_base),
        coaxial=dict(**p_sys, total_w=total_sys, cpi=cpi_sys,
                     util=util_sys, edp=edp_sys),
        edp_ratio=edp_sys / edp_base,
        power_ratio=total_sys / total_base,
    )


# ---------------------------------------------------------------------------
# Convenience: the full headline table for tests / EXPERIMENTS.md.
# ---------------------------------------------------------------------------

def headline() -> dict:
    """All headline numbers, sliced out of ONE batched sweep."""
    sw = default_sweep()
    c4 = sw.comparison(COAXIAL_4X)
    c2 = sw.comparison(COAXIAL_2X)
    ca = sw.comparison(COAXIAL_ASYM)
    c50 = sw.comparison(COAXIAL_4X, iface_lat=hw.CXL_LAT_PESSIMISTIC_NS)
    fig3 = cpu_model.variance_experiment()
    edp = edp_report(COAXIAL_4X, cmp=c4)
    return dict(
        gm_4x=c4.geomean_speedup,
        gm_2x=c2.geomean_speedup,
        gm_asym=ca.geomean_speedup,
        gm_50ns=c50.geomean_speedup,
        lbm_speedup=float(c4.speedup[NAMES.index("lbm")]),
        n_above_2x=c4.n_above_2x,
        n_regressions=c4.n_regressions,
        worst=c4.worst,
        queue_share=c4.summary()["queue_share_of_latency"],
        max_queue_share=c4.summary()["max_queue_share"],
        mean_base_queue_ns=c4.summary()["mean_base_queue_ns"],
        mean_coax_queue_ns=c4.summary()["mean_queue_ns"],
        stream_copy=c4.row("stream-copy"),
        fig3_geomeans=[v["geomean"] for v in fig3.values()],
        edp_ratio=edp["edp_ratio"],
        gm_1core=sw.comparison(COAXIAL_4X, n_active=1).geomean_speedup,
        gm_8core=sw.comparison(COAXIAL_4X, n_active=8).geomean_speedup,
        util_base=edp["baseline"]["util"],
        util_coax=edp["coaxial"]["util"],
    )
