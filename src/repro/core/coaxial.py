"""End-to-end COAXIAL evaluation engine (paper §4-§6, Tables 2 & 5).

Everything the paper reports is derivable from here:

  * :func:`sweep` -- the design-space engine: one jitted pass over
    designs x interface latencies x active-core counts, returning a
    :class:`SweepResult` from which all figures slice;
  * :func:`evaluate` -- per-workload speedups, latency breakdowns and
    utilizations for any design point (Figs 5, 7, 8, 9);
  * :func:`register_design` / :func:`get_design` / :func:`all_designs` --
    the design registry (configs and the planner can add points);
  * :func:`area_report` / :func:`pin_report` -- Table 1/2 accounting;
  * :func:`edp_report` -- the §6.6 power and energy-delay-product model
    (Table 5);
  * :func:`sensitivity_latency` / :func:`sensitivity_cores` -- §6.4 / §6.5.

The sweep engine is what makes dense grids cheap: ``sweep()`` stacks the
design points into a :class:`~repro.core.cpu_model.MemSystemArrays` pytree
and calls the vmapped solver once, so a 100-point channels x latency grid
costs one XLA compile instead of 100.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import cpu_model, hw
from repro.core.cpu_model import (COAXIAL_2X, COAXIAL_4X, COAXIAL_5X,
                                  COAXIAL_ASYM, DDR_BASELINE, DESIGNS,
                                  MemSystem, ModelResult, geomean, solve,
                                  solve_batch)
from repro.core.workloads import NAMES, WORKLOADS

__all__ = [
    "COAXIAL_2X", "COAXIAL_4X", "COAXIAL_5X", "COAXIAL_ASYM", "DDR_BASELINE",
    "DESIGNS", "MemSystem", "evaluate", "Comparison", "SweepResult", "sweep",
    "default_sweep", "register_design", "unregister_design", "get_design",
    "all_designs", "area_report", "pin_report", "edp_report",
    "sensitivity_latency", "sensitivity_cores",
]


# ---------------------------------------------------------------------------
# Design registry.  Seeded with the paper's Table-2 points; configs and the
# planner register additional points (e.g. channel-count sweeps) at runtime.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, MemSystem] = {}


def register_design(sys: MemSystem, *, overwrite: bool = False) -> MemSystem:
    """Add a design point to the registry (and to every future sweep)."""
    if not overwrite and sys.name in _REGISTRY:
        raise ValueError(f"design {sys.name!r} already registered")
    _REGISTRY[sys.name] = sys
    default_sweep.cache_clear()
    return sys


def unregister_design(name: str) -> MemSystem:
    """Remove a registered design point (the seed points may be removed
    too, but the DDR baseline is always re-added by :func:`sweep`)."""
    sys = _REGISTRY.pop(name)
    default_sweep.cache_clear()
    return sys


def get_design(name: str) -> MemSystem:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown design {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def all_designs() -> tuple[MemSystem, ...]:
    """All registered design points, registration-ordered."""
    return tuple(_REGISTRY.values())


for _d in DESIGNS:
    _REGISTRY[_d.name] = _d
del _d


@dataclasses.dataclass
class Comparison:
    """A design point evaluated against the DDR baseline."""

    sys: MemSystem
    base: ModelResult
    res: ModelResult
    names: tuple

    @property
    def speedup(self) -> np.ndarray:
        return self.res.speedup_vs(self.base)

    @property
    def geomean_speedup(self) -> float:
        return geomean(self.speedup)

    @property
    def n_above_2x(self) -> int:
        return int(np.sum(self.speedup > 2.0))

    @property
    def n_regressions(self) -> int:
        return int(np.sum(self.speedup < 0.995))

    @property
    def worst(self) -> tuple[str, float]:
        i = int(np.argmin(self.speedup))
        return self.names[i], float(self.speedup[i])

    @property
    def best(self) -> tuple[str, float]:
        i = int(np.argmax(self.speedup))
        return self.names[i], float(self.speedup[i])

    def row(self, name: str) -> dict:
        i = self.names.index(name)
        return dict(
            name=name, speedup=float(self.speedup[i]),
            base_latency_ns=float(self.base.latency_ns[i]),
            base_queue_ns=float(self.base.queue_ns[i]),
            latency_ns=float(self.res.latency_ns[i]),
            queue_ns=float(self.res.queue_ns[i]),
            base_rho=float(self.base.rho[i]), rho=float(self.res.rho[i]),
        )

    def summary(self) -> dict:
        return dict(
            design=self.sys.name,
            geomean_speedup=self.geomean_speedup,
            best=self.best, worst=self.worst,
            n_above_2x=self.n_above_2x, n_regressions=self.n_regressions,
            mean_base_queue_ns=float(np.mean(self.base.queue_ns)),
            mean_queue_ns=float(np.mean(self.res.queue_ns)),
            mean_base_rho=float(np.mean(self.base.rho)),
            mean_rho=float(np.mean(self.res.rho)),
            queue_share_of_latency=float(np.mean(
                self.base.queue_ns / self.base.latency_ns)),
            max_queue_share=float(np.max(
                self.base.queue_ns / self.base.latency_ns)),
        )


# ---------------------------------------------------------------------------
# The sweep engine.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Stacked model results over a designs x latencies x cores grid.

    ``results`` arrays have shape ``(D, L, C, n_workloads)`` matching
    ``designs`` / ``iface_lats`` / ``cores``.  Individual
    :class:`ModelResult` slices and baseline :class:`Comparison` objects
    are views into the one batched solve -- no further compilation or
    fixed-point iteration happens after construction.
    """

    designs: tuple[MemSystem, ...]
    iface_lats: tuple           # entries: float override or None (= default)
    cores: tuple[int, ...]
    names: tuple[str, ...]
    results: ModelResult
    baseline_name: str = DDR_BASELINE.name

    def design_index(self, sys) -> int:
        name = sys.name if isinstance(sys, MemSystem) else sys
        for i, d in enumerate(self.designs):
            if d.name == name:
                return i
        raise KeyError(f"design {name!r} not in sweep "
                       f"{[d.name for d in self.designs]}")

    def _lat_index(self, sys, iface_lat) -> int:
        if iface_lat in self.iface_lats:
            return self.iface_lats.index(iface_lat)
        # A design's own premium and an equal explicit override are the
        # same grid column for that design (the solver masks per-design).
        d = self.designs[self.design_index(sys)]
        if iface_lat is None and d.iface_lat_ns in self.iface_lats:
            return self.iface_lats.index(d.iface_lat_ns)
        if iface_lat == d.iface_lat_ns and None in self.iface_lats:
            return self.iface_lats.index(None)
        raise KeyError(f"iface_lat {iface_lat!r} not in sweep grid "
                       f"{self.iface_lats}")

    def _indices(self, sys, iface_lat, n_active) -> tuple[int, int, int]:
        return (self.design_index(sys), self._lat_index(sys, iface_lat),
                self.cores.index(n_active))

    def result(self, sys, *, iface_lat=None,
               n_active: int = hw.SIM_CORES) -> ModelResult:
        """The ``(n_workloads,)`` ModelResult slice for one grid point."""
        return self.results[self._indices(sys, iface_lat, n_active)]

    def comparison(self, sys, *, iface_lat=None,
                   n_active: int = hw.SIM_CORES) -> Comparison:
        """``sys`` vs the DDR baseline at the same core count.

        The baseline ignores the latency override (it has no CXL
        interface), so any latency column serves as its reference.
        """
        i, j, k = self._indices(sys, iface_lat, n_active)
        b = self.design_index(self.baseline_name)
        return Comparison(sys=self.designs[i], base=self.results[b, j, k],
                          res=self.results[i, j, k], names=self.names)

    def geomean_grid(self) -> np.ndarray:
        """Geomean speedup vs baseline for every grid point: ``(D, L, C)``."""
        b = self.design_index(self.baseline_name)
        ratio = self.results.ipc / self.results.ipc[b][None]
        return np.exp(np.mean(np.log(ratio), axis=-1))


def sweep(designs=None, *, iface_lat_grid=(None,),
          n_active_grid=(hw.SIM_CORES,), workloads=WORKLOADS,
          baseline: MemSystem = DDR_BASELINE) -> SweepResult:
    """Solve a whole design-space grid in one jitted, vmapped pass.

    ``designs`` defaults to every registered design; the baseline is
    prepended if absent so comparisons can always be sliced.
    ``iface_lat_grid`` entries override the CXL premium of CXL designs
    (``None`` = each design's own value).  ``n_active_grid`` are active
    core counts; calibration is redone per core count, as in the paper.
    """
    designs = tuple(designs) if designs is not None else all_designs()
    if not any(d.name == baseline.name for d in designs):
        designs = (baseline,) + designs
    seen: dict[str, MemSystem] = {}
    for d in designs:
        prev = seen.setdefault(d.name, d)
        if prev != d:
            # Results are sliced by name -- two different designs under one
            # name would silently shadow each other.
            raise ValueError(
                f"two different designs named {d.name!r} in one sweep")
    designs = tuple(seen.values())
    res = solve_batch(designs, n_active_grid=n_active_grid,
                      iface_lat_grid=iface_lat_grid, baseline=baseline,
                      workloads=workloads)
    return SweepResult(
        designs=designs, iface_lats=tuple(iface_lat_grid),
        cores=tuple(int(n) for n in n_active_grid),
        names=tuple(w.name for w in workloads), results=res,
        baseline_name=baseline.name)


@functools.lru_cache(maxsize=None)
def default_sweep() -> SweepResult:
    """The shared grid behind every figure/table: all registered designs,
    both §6.4 latency points, all §6.5 core counts.  One compile serves the
    entire benchmark report; cache is invalidated when the registry changes.
    """
    return sweep(iface_lat_grid=(None, hw.CXL_LAT_PESSIMISTIC_NS),
                 n_active_grid=(1, 4, 8, hw.SIM_CORES))


def _unshadow(sys: MemSystem) -> MemSystem:
    """Rename a modified design that still carries the baseline's name.

    Sweep results are name-keyed; without the rename such a design would
    either shadow the comparator or be rejected by sweep()'s dedup check.
    """
    if sys.name == DDR_BASELINE.name and sys != DDR_BASELINE:
        return dataclasses.replace(sys, name=f"{sys.name}*")
    return sys


def evaluate(sys: MemSystem = COAXIAL_4X, *, n_active: int = hw.SIM_CORES,
             iface_lat_ns: float | None = None,
             workloads=WORKLOADS) -> Comparison:
    res_sys = sys
    if iface_lat_ns is not None and not sys.is_cxl:
        # The sweep grid's latency override only reaches CXL designs, but
        # evaluate() historically applied an explicit premium to any design
        # -- bake it into the design point.
        res_sys = dataclasses.replace(
            sys, name=f"{sys.name}@{iface_lat_ns:g}ns",
            iface_lat_ns=float(iface_lat_ns))
    res_sys = _unshadow(res_sys)
    sw = sweep((DDR_BASELINE, res_sys), iface_lat_grid=(iface_lat_ns,),
               n_active_grid=(n_active,), workloads=workloads)
    cmp = sw.comparison(res_sys, iface_lat=iface_lat_ns, n_active=n_active)
    if res_sys is not sys:
        cmp = dataclasses.replace(cmp, sys=sys)
    return cmp


def sensitivity_latency(latencies_ns=(hw.CXL_LAT_NS,
                                      hw.CXL_LAT_PESSIMISTIC_NS),
                        sys: MemSystem = COAXIAL_4X) -> dict:
    """§6.4: COAXIAL speedup at 30ns vs 50ns CXL premium (Fig 8)."""
    if not sys.is_cxl:
        # Latency overrides bypass non-CXL designs inside the grid; per-
        # point evaluate() bakes the premium in (still one compile total).
        return {lat: evaluate(sys, iface_lat_ns=lat) for lat in latencies_ns}
    sys = _unshadow(sys)
    sw = sweep((DDR_BASELINE, sys), iface_lat_grid=tuple(latencies_ns))
    return {lat: sw.comparison(sys, iface_lat=lat) for lat in latencies_ns}


def sensitivity_cores(cores=(1, 4, 8, 12), sys: MemSystem = COAXIAL_4X):
    """§6.5: speedup vs active cores; baseline at the same core count."""
    sys = _unshadow(sys)
    sw = sweep((DDR_BASELINE, sys), n_active_grid=tuple(cores))
    return {n: sw.comparison(sys, n_active=n) for n in cores}


# ---------------------------------------------------------------------------
# Table 1 / Table 2: area and pins for the full 144-core server.
# ---------------------------------------------------------------------------

FULL_CORES = 144
FULL_DDR_CHANNELS = 12


def _die_area(cores, llc_mb, ddr_ch, pcie_x8):
    return (cores * hw.AREA_ZEN3_CORE + llc_mb * hw.AREA_L3_PER_MB +
            ddr_ch * hw.AREA_DDR_CH + pcie_x8 * hw.AREA_PCIE_X8)


def area_report(designs=None) -> dict:
    """Reproduces Table 2's relative-area column from Table 1's entries.

    Derived from each registered design's own fields (LLC per core, links,
    channels) scaled 12-core slice -> 144-core server, so registry
    additions get Table-2 accounting for free.
    """
    base = _die_area(FULL_CORES, FULL_CORES * 2, FULL_DDR_CHANNELS, 0)
    scale = FULL_CORES // hw.SIM_CORES
    out = {}
    for sys in (designs if designs is not None else all_designs()):
        llc_mb = FULL_CORES * sys.llc_mb_per_core
        ddr_ch = 0 if sys.is_cxl else sys.dram_channels * scale
        pcie_x8 = sys.links * scale
        area = _die_area(FULL_CORES, llc_mb, ddr_ch, pcie_x8)
        pins = ddr_ch * hw.DDR5_PINS + pcie_x8 * hw.PCIE_X8_PINS
        out[sys.name] = dict(rel_area=area / base, mem_pins=pins,
                             rel_pins=pins / (12 * hw.DDR5_PINS))
    return out


def pin_report() -> dict:
    """§4.1: pins and peak bandwidth per interface choice."""
    ddr_per_pin = hw.DDR5_CH_BW_GBPS / hw.DDR5_PINS
    # The paper's "4x" compares PCIe's *per-direction* bandwidth per pin
    # against DDR's combined-direction figure (conservative: PCIe moves the
    # same bytes in the other direction simultaneously, §2.3).
    x8_per_pin_dir = hw.PCIE_X8_GBPS_PER_DIR / hw.PCIE_X8_PINS
    return dict(
        ddr5_pins=hw.DDR5_PINS,
        ddr5_peak_gbps=hw.DDR5_CH_BW_GBPS,
        ddr5_gbps_per_pin=ddr_per_pin,
        x8_pins=hw.PCIE_X8_PINS,
        x8_peak_gbps_per_dir=hw.PCIE_X8_GBPS_PER_DIR,
        x8_gbps_per_pin_per_dir=x8_per_pin_dir,
        x8_gbps_per_pin_duplex=2 * hw.PCIE_X8_GBPS_PER_DIR / hw.PCIE_X8_PINS,
        bw_per_pin_ratio=x8_per_pin_dir / ddr_per_pin,
        bw_per_pin_ratio_duplex=2 * x8_per_pin_dir / ddr_per_pin,
    )


# ---------------------------------------------------------------------------
# Table 5: power and EDP for the 144-core server.
# ---------------------------------------------------------------------------

def _dimm_power(channels, util):
    return channels * (hw.DIMM_STATIC_W_PER_CH + hw.DIMM_DYN_W_PER_CH * util)


def edp_report(sys: MemSystem = COAXIAL_4X, *,
               cmp: Comparison | None = None) -> dict:
    """§6.6 power/EDP model.  Pass ``cmp`` (e.g. a sweep slice) to reuse an
    already-solved comparison instead of re-evaluating."""
    if cmp is None:
        cmp = evaluate(sys)
    # Scale channel counts 12-core sim -> 144-core server (x12).
    scale = FULL_CORES // hw.SIM_CORES
    base_ch = DDR_BASELINE.dram_channels * scale
    sys_ch = sys.dram_channels * scale
    lanes = sys.links * scale * 8

    util_base = float(np.mean(cmp.base.rho))
    util_sys = float(np.mean(cmp.res.rho))

    p_base = dict(
        package_w=hw.PKG_POWER_W,
        ddr_mc_phy_w=base_ch * hw.DDR_MC_PHY_W_PER_CH,
        dimm_w=_dimm_power(base_ch, util_base),
        cxl_iface_w=0.0)
    p_sys = dict(
        package_w=hw.PKG_POWER_W,
        ddr_mc_phy_w=sys_ch * hw.DDR_MC_PHY_W_PER_CH,
        dimm_w=_dimm_power(sys_ch, util_sys),
        cxl_iface_w=lanes * hw.PCIE_LANE_POWER_W)

    total_base = sum(p_base.values())
    total_sys = sum(p_sys.values())
    cpi_base = geomean(cmp.base.cpi)
    cpi_sys = geomean(cmp.res.cpi)
    edp_base = total_base * cpi_base**2
    edp_sys = total_sys * cpi_sys**2
    return dict(
        baseline=dict(**p_base, total_w=total_base, cpi=cpi_base,
                      util=util_base, edp=edp_base),
        coaxial=dict(**p_sys, total_w=total_sys, cpi=cpi_sys,
                     util=util_sys, edp=edp_sys),
        edp_ratio=edp_sys / edp_base,
        power_ratio=total_sys / total_base,
    )


# ---------------------------------------------------------------------------
# Convenience: the full headline table for tests / EXPERIMENTS.md.
# ---------------------------------------------------------------------------

def headline() -> dict:
    """All headline numbers, sliced out of ONE batched sweep."""
    sw = default_sweep()
    c4 = sw.comparison(COAXIAL_4X)
    c2 = sw.comparison(COAXIAL_2X)
    ca = sw.comparison(COAXIAL_ASYM)
    c50 = sw.comparison(COAXIAL_4X, iface_lat=hw.CXL_LAT_PESSIMISTIC_NS)
    fig3 = cpu_model.variance_experiment()
    edp = edp_report(COAXIAL_4X, cmp=c4)
    return dict(
        gm_4x=c4.geomean_speedup,
        gm_2x=c2.geomean_speedup,
        gm_asym=ca.geomean_speedup,
        gm_50ns=c50.geomean_speedup,
        lbm_speedup=float(c4.speedup[NAMES.index("lbm")]),
        n_above_2x=c4.n_above_2x,
        n_regressions=c4.n_regressions,
        worst=c4.worst,
        queue_share=c4.summary()["queue_share_of_latency"],
        max_queue_share=c4.summary()["max_queue_share"],
        mean_base_queue_ns=c4.summary()["mean_base_queue_ns"],
        mean_coax_queue_ns=c4.summary()["mean_queue_ns"],
        stream_copy=c4.row("stream-copy"),
        fig3_geomeans=[v["geomean"] for v in fig3.values()],
        edp_ratio=edp["edp_ratio"],
        gm_1core=sw.comparison(COAXIAL_4X, n_active=1).geomean_speedup,
        gm_8core=sw.comparison(COAXIAL_4X, n_active=8).geomean_speedup,
        util_base=edp["baseline"]["util"],
        util_coax=edp["coaxial"]["util"],
    )
