"""End-to-end COAXIAL evaluation engine (paper §4-§6, Tables 2 & 5).

Everything the paper reports is derivable from here:

  * :func:`evaluate` -- per-workload speedups, latency breakdowns and
    utilizations for any design point (Figs 5, 7, 8, 9);
  * :func:`area_report` / :func:`pin_report` -- Table 1/2 accounting;
  * :func:`edp_report` -- the §6.6 power and energy-delay-product model
    (Table 5);
  * :func:`sensitivity_latency` / :func:`sensitivity_cores` -- §6.4 / §6.5.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cpu_model, hw
from repro.core.cpu_model import (COAXIAL_2X, COAXIAL_4X, COAXIAL_5X,
                                  COAXIAL_ASYM, DDR_BASELINE, DESIGNS,
                                  MemSystem, ModelResult, geomean, solve)
from repro.core.workloads import NAMES, WORKLOADS

__all__ = [
    "COAXIAL_2X", "COAXIAL_4X", "COAXIAL_5X", "COAXIAL_ASYM", "DDR_BASELINE",
    "DESIGNS", "MemSystem", "evaluate", "Comparison", "area_report",
    "pin_report", "edp_report", "sensitivity_latency", "sensitivity_cores",
]


@dataclasses.dataclass
class Comparison:
    """A design point evaluated against the DDR baseline."""

    sys: MemSystem
    base: ModelResult
    res: ModelResult
    names: tuple

    @property
    def speedup(self) -> np.ndarray:
        return self.res.speedup_vs(self.base)

    @property
    def geomean_speedup(self) -> float:
        return geomean(self.speedup)

    @property
    def n_above_2x(self) -> int:
        return int(np.sum(self.speedup > 2.0))

    @property
    def n_regressions(self) -> int:
        return int(np.sum(self.speedup < 0.995))

    @property
    def worst(self) -> tuple[str, float]:
        i = int(np.argmin(self.speedup))
        return self.names[i], float(self.speedup[i])

    @property
    def best(self) -> tuple[str, float]:
        i = int(np.argmax(self.speedup))
        return self.names[i], float(self.speedup[i])

    def row(self, name: str) -> dict:
        i = self.names.index(name)
        return dict(
            name=name, speedup=float(self.speedup[i]),
            base_latency_ns=float(self.base.latency_ns[i]),
            base_queue_ns=float(self.base.queue_ns[i]),
            latency_ns=float(self.res.latency_ns[i]),
            queue_ns=float(self.res.queue_ns[i]),
            base_rho=float(self.base.rho[i]), rho=float(self.res.rho[i]),
        )

    def summary(self) -> dict:
        return dict(
            design=self.sys.name,
            geomean_speedup=self.geomean_speedup,
            best=self.best, worst=self.worst,
            n_above_2x=self.n_above_2x, n_regressions=self.n_regressions,
            mean_base_queue_ns=float(np.mean(self.base.queue_ns)),
            mean_queue_ns=float(np.mean(self.res.queue_ns)),
            mean_base_rho=float(np.mean(self.base.rho)),
            mean_rho=float(np.mean(self.res.rho)),
            queue_share_of_latency=float(np.mean(
                self.base.queue_ns / self.base.latency_ns)),
            max_queue_share=float(np.max(
                self.base.queue_ns / self.base.latency_ns)),
        )


def evaluate(sys: MemSystem = COAXIAL_4X, *, n_active: int = hw.SIM_CORES,
             iface_lat_ns: float | None = None,
             workloads=WORKLOADS) -> Comparison:
    base = solve(DDR_BASELINE, n_active=n_active, workloads=workloads)
    res = solve(sys, n_active=n_active, iface_lat_ns=iface_lat_ns,
                workloads=workloads)
    return Comparison(sys=sys, base=base, res=res,
                      names=tuple(w.name for w in workloads))


def sensitivity_latency(latencies_ns=(hw.CXL_LAT_NS,
                                      hw.CXL_LAT_PESSIMISTIC_NS),
                        sys: MemSystem = COAXIAL_4X) -> dict:
    """§6.4: COAXIAL speedup at 30ns vs 50ns CXL premium (Fig 8)."""
    return {lat: evaluate(sys, iface_lat_ns=lat) for lat in latencies_ns}


def sensitivity_cores(cores=(1, 4, 8, 12), sys: MemSystem = COAXIAL_4X):
    """§6.5: speedup vs active cores; baseline at the same core count."""
    return {n: evaluate(sys, n_active=n) for n in cores}


# ---------------------------------------------------------------------------
# Table 1 / Table 2: area and pins for the full 144-core server.
# ---------------------------------------------------------------------------

FULL_CORES = 144
FULL_DDR_CHANNELS = 12


def _die_area(cores, llc_mb, ddr_ch, pcie_x8):
    return (cores * hw.AREA_ZEN3_CORE + llc_mb * hw.AREA_L3_PER_MB +
            ddr_ch * hw.AREA_DDR_CH + pcie_x8 * hw.AREA_PCIE_X8)


def area_report() -> dict:
    """Reproduces Table 2's relative-area column from Table 1's entries."""
    base = _die_area(FULL_CORES, FULL_CORES * 2, FULL_DDR_CHANNELS, 0)
    rows = {
        "ddr-baseline": (_die_area(FULL_CORES, 288, 12, 0), 12 * hw.DDR5_PINS),
        "coaxial-5x": (_die_area(FULL_CORES, 288, 0, 60), 60 * hw.PCIE_X8_PINS),
        "coaxial-2x": (_die_area(FULL_CORES, 288, 0, 24), 24 * hw.PCIE_X8_PINS),
        "coaxial-4x": (_die_area(FULL_CORES, 144, 0, 48), 48 * hw.PCIE_X8_PINS),
        "coaxial-asym": (_die_area(FULL_CORES, 144, 0, 48),
                         48 * hw.PCIE_X8_PINS),
    }
    return {name: dict(rel_area=a / base, mem_pins=p,
                       rel_pins=p / (12 * hw.DDR5_PINS))
            for name, (a, p) in rows.items()}


def pin_report() -> dict:
    """§4.1: pins and peak bandwidth per interface choice."""
    ddr_per_pin = hw.DDR5_CH_BW_GBPS / hw.DDR5_PINS
    # The paper's "4x" compares PCIe's *per-direction* bandwidth per pin
    # against DDR's combined-direction figure (conservative: PCIe moves the
    # same bytes in the other direction simultaneously, §2.3).
    x8_per_pin_dir = 32.0 / hw.PCIE_X8_PINS
    return dict(
        ddr5_pins=hw.DDR5_PINS,
        ddr5_peak_gbps=hw.DDR5_CH_BW_GBPS,
        ddr5_gbps_per_pin=ddr_per_pin,
        x8_pins=hw.PCIE_X8_PINS,
        x8_peak_gbps_per_dir=32.0,
        x8_gbps_per_pin_per_dir=x8_per_pin_dir,
        x8_gbps_per_pin_duplex=2 * 32.0 / hw.PCIE_X8_PINS,
        bw_per_pin_ratio=x8_per_pin_dir / ddr_per_pin,
        bw_per_pin_ratio_duplex=2 * x8_per_pin_dir / ddr_per_pin,
    )


# ---------------------------------------------------------------------------
# Table 5: power and EDP for the 144-core server.
# ---------------------------------------------------------------------------

def _dimm_power(channels, util):
    return channels * (hw.DIMM_STATIC_W_PER_CH + hw.DIMM_DYN_W_PER_CH * util)


def edp_report(sys: MemSystem = COAXIAL_4X) -> dict:
    cmp = evaluate(sys)
    # Scale channel counts 12-core sim -> 144-core server (x12).
    scale = FULL_CORES // hw.SIM_CORES
    base_ch = DDR_BASELINE.dram_channels * scale
    sys_ch = sys.dram_channels * scale
    lanes = sys.links * scale * 8

    util_base = float(np.mean(cmp.base.rho))
    util_sys = float(np.mean(cmp.res.rho))

    p_base = dict(
        package_w=hw.PKG_POWER_W,
        ddr_mc_phy_w=base_ch * hw.DDR_MC_PHY_W_PER_CH,
        dimm_w=_dimm_power(base_ch, util_base),
        cxl_iface_w=0.0)
    p_sys = dict(
        package_w=hw.PKG_POWER_W,
        ddr_mc_phy_w=sys_ch * hw.DDR_MC_PHY_W_PER_CH,
        dimm_w=_dimm_power(sys_ch, util_sys),
        cxl_iface_w=lanes * hw.PCIE_LANE_POWER_W)

    total_base = sum(p_base.values())
    total_sys = sum(p_sys.values())
    cpi_base = geomean(cmp.base.cpi)
    cpi_sys = geomean(cmp.res.cpi)
    edp_base = total_base * cpi_base**2
    edp_sys = total_sys * cpi_sys**2
    return dict(
        baseline=dict(**p_base, total_w=total_base, cpi=cpi_base,
                      util=util_base, edp=edp_base),
        coaxial=dict(**p_sys, total_w=total_sys, cpi=cpi_sys,
                     util=util_sys, edp=edp_sys),
        edp_ratio=edp_sys / edp_base,
        power_ratio=total_sys / total_base,
    )


# ---------------------------------------------------------------------------
# Convenience: the full headline table for tests / EXPERIMENTS.md.
# ---------------------------------------------------------------------------

def headline() -> dict:
    c4 = evaluate(COAXIAL_4X)
    c2 = evaluate(COAXIAL_2X)
    ca = evaluate(COAXIAL_ASYM)
    c50 = evaluate(COAXIAL_4X, iface_lat_ns=hw.CXL_LAT_PESSIMISTIC_NS)
    fig3 = cpu_model.variance_experiment()
    edp = edp_report()
    cores = sensitivity_cores()
    return dict(
        gm_4x=c4.geomean_speedup,
        gm_2x=c2.geomean_speedup,
        gm_asym=ca.geomean_speedup,
        gm_50ns=c50.geomean_speedup,
        lbm_speedup=float(c4.speedup[NAMES.index("lbm")]),
        n_above_2x=c4.n_above_2x,
        n_regressions=c4.n_regressions,
        worst=c4.worst,
        queue_share=c4.summary()["queue_share_of_latency"],
        max_queue_share=c4.summary()["max_queue_share"],
        mean_base_queue_ns=c4.summary()["mean_base_queue_ns"],
        mean_coax_queue_ns=c4.summary()["mean_queue_ns"],
        stream_copy=c4.row("stream-copy"),
        fig3_geomeans=[v["geomean"] for v in fig3.values()],
        edp_ratio=edp["edp_ratio"],
        gm_1core=sensitivity_cores((1,))[1].geomean_speedup,
        gm_8core=cores[8].geomean_speedup,
        util_base=edp["baseline"]["util"],
        util_coax=edp["coaxial"]["util"],
    )
