"""DES-derived queue-wait lookup surface: the mechanism as a solver backend.

``cpu_model``'s fixed point needs, per workload and per iteration, the
DRAM-side queue wait at an operating point (utilization ``rho``, burstiness
``kappa``, closed-loop population ``outstanding``, DRAM-sensitivity ``eta``).
The closed form (``queueing.effective_queue_wait_ns``) answers that
analytically; this module answers it *mechanistically*: one batched
``coaxial.distribution_sweep`` runs the DES (``memsim``) over a
(rho, kappa, outstanding, eta) grid -- ``outstanding`` is a real simulated
field (the finite in-flight population that caps the FIFO backlog) and
``eta`` scales the blocking-episode probability at fixed mean service
time (the per-workload DRAM-sensitivity knob, now INSIDE the mechanism
instead of a post-hoc multiplier on the wait) -- and the resulting
latency distributions are reduced to four tables (mean wait / p90 wait /
p99 wait / latency stdev).

:class:`QueueLUT` is a pytree of those tables plus their grids, with
**differentiable multilinear interpolation**: the lookup is piecewise
linear in the query point (quadrilinear over the 4-D grid, with the
``outstanding`` axis located in LOG space -- its grid is geometric, so
log-space fractions interpolate the curvature instead of chord-cutting
it), clamped to the grid hull, and pure ``jnp`` -- so ``cpu_model`` can
pass a LUT straight into its jitted cell solver (any named-axis grid
still lowers to ONE trace per flattened cell count) and
``design_gradient`` can differentiate through the fixed point *and* the
table.  Passing ``lut=None`` to the solver selects the closed form; the
pytree-structure difference is what keys the jit cache, no static flags
needed.

Build cost: the default surface (14 x 6 x 6 x 4 grid) is one batched run
of the per-request event engine -- the 4th axis is what the
device-parallel DES (``memsim``'s ``devices`` knob, ``core/shardsim``)
buys; pass ``devices=`` (or set ``$REPRO_DES_DEVICES``) to shard the
build, bit-identically.  :func:`default_queue_lut` caches it per
(steps, seed, reps, engine), so a whole session pays for it once.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import hw

#: Default utilization grid: denser near saturation, where the open-loop
#: hyperbola is steep and linear interpolation would otherwise smear the
#: knee of the load-latency curve.  One notch finer than the original
#: 12-point grid (extra knee points at 0.62..0.91) -- affordable because
#: the default build engine is the per-request event engine, the first
#: step of the ROADMAP's LUT-resolution study.
DEFAULT_RHO_GRID = (0.05, 0.15, 0.25, 0.35, 0.45, 0.55, 0.62, 0.68,
                    0.74, 0.79, 0.84, 0.88, 0.91, 0.93)
#: Default burstiness grid (covers the Table-4 suite values 1.3..1.6 and
#: the synthetic-sweep range up to 3.2; 2.7 fills the former 2.2 -> 3.2
#: gap).
DEFAULT_KAPPA_GRID = (1.0, 1.3, 1.6, 2.2, 2.7, 3.2)
#: Default closed-loop population grid: ``n_active * MAX_MLP /
#: dram_channels`` spans ~2 (8 channels, 1 core) to 192 (the 12-core,
#: 1-channel DDR baseline); GEOMETRIC spacing -- the lookup interpolates
#: this axis in log space, where these points are near-uniform.
DEFAULT_OUTSTANDING_GRID = (2.0, 4.0, 8.0, 24.0, 64.0, 192.0)
#: Default DRAM-sensitivity grid: the Table-4 suite's eta spans ~0.05
#: (cache-friendly codes barely touch the far tail) to 1.0 (stream-like
#: codes take every blocking episode); the surface is near-linear in eta
#: (the wait is dominated by the episode-probability term), so four
#: points carry it.
DEFAULT_ETA_GRID = (0.05, 0.30, 0.60, 1.0)
#: Optional 5th axis: lent-time fraction of the idle-I/O harvesting chain
#: (arXiv 2511.12349 on top of CoaXiaL).  The table is built at the
#: REFERENCE lent bandwidth :data:`HARVEST_REF_BW_GBPS` -- one DDR5
#: channel's worth, which is also what one lent CXL x8 link contributes
#: (26 + 13 GB/s goodput) -- so the axis coordinate is "fraction of time
#: one extra channel's bandwidth is present".  Queries at other lent
#: bandwidths map through ``duty_eff = duty * bw / ref`` (see
#: ``cpu_model._latency_terms``).  Chosen over a plain effective-rho
#: mapping, which violates the designer's 35%/4ns verification envelope
#: by up to 227% in the bursty open-loop corner (see
#: docs/ARCHITECTURE.md "Harvesting").
DEFAULT_HARVEST_GRID = (0.0, 0.25, 0.5, 0.75)
HARVEST_REF_BW_GBPS = hw.DDR5_CH_BW_GBPS
#: Default DES budget per cell (ns simulated) and replicas per cell.
DEFAULT_STEPS = 120_000
DEFAULT_REPS = 2
#: Default build engine: the per-request event engine (the timestep
#: reference builds the same surface several times slower --
#: ``benchmarks/memsim_speed.py`` times both and cross-checks the
#: tables).
DEFAULT_ENGINE = "event"


class QueueLUT(NamedTuple):
    """DES-measured queue-wait surface over (rho, kappa, outstanding, eta).

    A pytree of nine array leaves: four ascending coordinate grids and
    four ``(R, K, O, E)`` tables -- mean queue wait, p90 queue wait, p99
    queue wait, and latency standard deviation (all ns).  :meth:`lookup`
    interpolates all four multilinearly (clamped at the hull; the
    ``outstanding`` axis in log space), vectorizes over any broadcastable
    query shapes, works inside ``jit``, and is differentiable in the
    query point.  The p99 table is what makes the solver's tail path
    mechanistic: the event engine records every request exactly, so the
    99th percentile costs nothing extra at build time, and downstream the
    designer's SLO constraint differentiates straight through it.

    Example (a hand-built two-point surface; real tables come from
    :func:`build_queue_lut`)::

        >>> import jax.numpy as jnp
        >>> from repro.core.queuelut import QueueLUT
        >>> z = jnp.zeros((2, 2, 2, 2))
        >>> lut = QueueLUT(rho_grid=jnp.array([0.0, 1.0]),
        ...                kappa_grid=jnp.array([1.0, 2.0]),
        ...                outstanding_grid=jnp.array([1.0, 100.0]),
        ...                eta_grid=jnp.array([0.0, 1.0]),
        ...                wait_ns=z.at[1].set(80.0),
        ...                p90_wait_ns=z, p99_wait_ns=z, sigma_ns=z)
        >>> float(lut.wait(0.5, 1.0, 1.0, 1.0))  # halfway up the rho edge
        40.0
        >>> float(lut.wait(2.0, 1.0, 1.0, 1.0))  # clamped at the grid hull
        80.0
        >>> float(lut.wait(0.5, 1.0, 10.0, 1.0))  # log-space outstanding:
        40.0
    """

    rho_grid: jnp.ndarray          # (R,) ascending
    kappa_grid: jnp.ndarray        # (K,) ascending
    outstanding_grid: jnp.ndarray  # (O,) ascending, positive
    eta_grid: jnp.ndarray          # (E,) ascending
    wait_ns: jnp.ndarray           # (R, K, O, E[, H]) mean queue wait
    p90_wait_ns: jnp.ndarray       # (R, K, O, E[, H]) p90 queue wait
    p99_wait_ns: jnp.ndarray       # (R, K, O, E[, H]) p99 queue wait
    sigma_ns: jnp.ndarray          # (R, K, O, E[, H]) latency stdev
    #: Optional 5th axis (None => 4-D tables): lent-time fraction of the
    #: idle-I/O harvesting chain at the reference lent bandwidth.
    harvest_grid: jnp.ndarray | None = None

    def lookup(self, rho, kappa, outstanding, eta=1.0, harvest=0.0):
        """Interpolated ``(mean wait, p90 wait, p99 wait, sigma)``.

        Queries broadcast together; out-of-grid coordinates clamp to the
        nearest hull face (constant extrapolation -- the DES was not run
        there, so the table refuses to invent a steeper law).  The
        ``outstanding`` fraction is computed in log space: its grid is
        geometric, and a query like 96 on a (64, 192) cell should sit
        near the geometric midpoint, not 1/4 from the top.

        ``harvest`` queries the optional 5th axis; on a 4-D surface
        (``harvest_grid is None``) it is IGNORED -- callers that need the
        harvested mechanism must build with ``harvest=`` (``cpu_model``
        resolves the right surface and raises on a mismatch).  A
        ``harvest=0.0`` query on a 5-D surface lands exactly on the
        duty-0 grid plane (the grid starts at 0), so unharvested lookups
        interpolate the same cells either way.
        """
        q = (rho, kappa, outstanding, eta)
        logs = (False, False, True, False)
        grids = (self.rho_grid, self.kappa_grid, self.outstanding_grid,
                 self.eta_grid)
        if self.harvest_grid is not None:
            q += (harvest,)
            logs += (False,)
            grids += (self.harvest_grid,)
        pts = jnp.broadcast_arrays(*(jnp.asarray(x, self.wait_ns.dtype)
                                     for x in q))
        loc = [_locate(g, p, log=lg)
               for g, p, lg in zip(grids, pts, logs)]
        return tuple(_blend(t, loc) for t in
                     (self.wait_ns, self.p90_wait_ns, self.p99_wait_ns,
                      self.sigma_ns))

    def wait(self, rho, kappa, outstanding, eta=1.0, harvest=0.0):
        """Interpolated mean queue wait alone (ns)."""
        return self.lookup(rho, kappa, outstanding, eta, harvest)[0]


def _locate(grid, x, log: bool = False):
    """(lower index, fraction) of ``x`` on an ascending grid, clamped.

    The fraction is what gradients flow through (piecewise linear); the
    index is integer and carries none, which is exactly the derivative a
    multilinear surface has.  ``log=True`` computes the fraction between
    the bracketing points in log space -- true geometric interpolation
    for geometrically spaced grids (the grid must be positive).
    """
    x = jnp.clip(x, grid[0], grid[-1])
    i = jnp.clip(jnp.searchsorted(grid, x, side="right") - 1,
                 0, grid.shape[0] - 2)
    lo, hi = grid[i], grid[i + 1]
    if log:
        t = jnp.log(x / lo) / jnp.log(hi / lo)
    else:
        t = (x - lo) / (hi - lo)
    return i, jnp.clip(t, 0.0, 1.0)


def _blend(table, loc):
    """Multilinear blend of the ``2**d`` corner cells around a located
    point (``d = len(loc)`` grid axes)."""
    out = 0.0
    for corner in range(2 ** len(loc)):
        w = 1.0
        idx = []
        for d, (i, t) in enumerate(loc):
            hi = (corner >> d) & 1
            w = w * (t if hi else 1.0 - t)
            idx.append(i + hi)
        out = out + w * table[tuple(idx)]
    return out


def _check_grid(name, grid, positive: bool = False):
    g = np.asarray(grid, np.float64)
    if g.ndim != 1 or g.size < 2:
        raise ValueError(f"{name} grid needs >= 2 points, got {g.shape}")
    if not np.all(np.diff(g) > 0):
        raise ValueError(f"{name} grid must be strictly ascending: "
                         f"{g.tolist()}")
    if positive and g[0] <= 0:
        raise ValueError(f"{name} grid must be positive (it interpolates "
                         f"in log space): {g.tolist()}")
    return tuple(float(v) for v in g)


def build_queue_lut(*, rho=DEFAULT_RHO_GRID, kappa=DEFAULT_KAPPA_GRID,
                    outstanding=DEFAULT_OUTSTANDING_GRID,
                    eta=DEFAULT_ETA_GRID, harvest=None,
                    harvest_bw_gbps: float = HARVEST_REF_BW_GBPS,
                    steps: int = DEFAULT_STEPS, seed: int = 0,
                    reps: int = DEFAULT_REPS, base=None,
                    engine: str = DEFAULT_ENGINE,
                    devices=None) -> QueueLUT:
    """Run ONE batched distribution sweep and reduce it to a QueueLUT.

    The whole (rho x kappa x outstanding x eta) grid lowers to one jitted
    simulation (``coaxial.distribution_sweep``); the wait tables are
    the DES latency means/p90s minus the unloaded DRAM service time, and
    the sigma table is the DES latency stdev verbatim -- the measured
    replacement for ``queueing.stdev_latency_ns``'s heuristic.
    ``engine`` picks the memsim engine; ``devices`` shards the build's
    flattened cell batch over host devices (``None`` consults
    ``$REPRO_DES_DEVICES``) -- the default 4-D grid is what the sharded
    DES buys, and the tables are bit-identical at any device count.

    ``harvest`` (a duty grid in [0, 1), e.g.
    :data:`DEFAULT_HARVEST_GRID`) grows the optional 5th axis: the sweep
    gains a ``harvest_duty`` dimension and the base channel lends
    ``harvest_bw_gbps`` while lent (default: the reference one-channel
    bandwidth, see :data:`HARVEST_REF_BW_GBPS`).

    Example (tiny grid, doctest-sized budget)::

        >>> from repro.core.queuelut import build_queue_lut
        >>> lut = build_queue_lut(rho=(0.2, 0.6), kappa=(1.0, 2.0),
        ...                       outstanding=(8.0, 192.0),
        ...                       eta=(0.1, 1.0), steps=4000, reps=1)
        >>> lut.wait_ns.shape
        (2, 2, 2, 2)
        >>> bool(lut.wait(0.6, 1.0, 192.0, 1.0) >
        ...      lut.wait(0.2, 1.0, 192.0, 1.0))
        True
        >>> hlut = build_queue_lut(rho=(0.2, 0.6), kappa=(1.0, 2.0),
        ...                        outstanding=(8.0, 192.0),
        ...                        eta=(0.1, 1.0), harvest=(0.0, 0.5),
        ...                        steps=4000, reps=1)
        >>> hlut.wait_ns.shape
        (2, 2, 2, 2, 2)
    """
    from repro.core import coaxial, memsim  # runtime: import cycle
    rho = _check_grid("rho", rho)
    kappa = _check_grid("kappa", kappa)
    outstanding = _check_grid("outstanding", outstanding, positive=True)
    eta = _check_grid("eta", eta)
    axes = dict(rho=rho, kappa=kappa, outstanding=outstanding, eta=eta)
    if harvest is not None:
        harvest = _check_grid("harvest", harvest)
        if harvest[0] < 0.0 or harvest[-1] >= 1.0:
            raise ValueError(f"harvest (duty) grid must lie in [0, 1): "
                             f"{list(harvest)}")
        axes["harvest_duty"] = harvest
        if base is None:
            base = memsim.ChannelConfig(
                rho=0.5, harvest_bw_gbps=float(harvest_bw_gbps))
    sw = coaxial.distribution_sweep(
        **axes, base=base, steps=int(steps), seed=int(seed),
        reps=int(reps), engine=engine, devices=devices)
    stats = sw.stats
    to_j = lambda x: jnp.asarray(np.asarray(x, np.float64))
    return QueueLUT(
        rho_grid=to_j(rho), kappa_grid=to_j(kappa),
        outstanding_grid=to_j(outstanding), eta_grid=to_j(eta),
        wait_ns=to_j(np.maximum(stats.mean_ns - hw.DRAM_SERVICE_NS, 0.0)),
        p90_wait_ns=to_j(np.maximum(stats.p90_ns - hw.DRAM_SERVICE_NS, 0.0)),
        p99_wait_ns=to_j(np.maximum(stats.p99_ns - hw.DRAM_SERVICE_NS, 0.0)),
        sigma_ns=to_j(stats.stdev_ns),
        harvest_grid=None if harvest is None else to_j(harvest))


@functools.lru_cache(maxsize=None)
def default_queue_lut(steps: int = DEFAULT_STEPS, seed: int = 0,
                      reps: int = DEFAULT_REPS,
                      engine: str = DEFAULT_ENGINE,
                      harvest: bool = False) -> QueueLUT:
    """The shared default-grid surface; built once per (steps, seed,
    reps, engine, harvest).

    This is what ``cpu_model.solve(..., queue_model="memsim")`` uses when
    no explicit LUT is passed (``harvest=True`` when any solved design
    harvests -- the tables gain the :data:`DEFAULT_HARVEST_GRID` axis).
    The build honours ``$REPRO_DES_DEVICES`` (via ``devices=None``), and
    the tables are device-count-invariant, so the cache key need not
    include it.
    """
    return build_queue_lut(steps=steps, seed=seed, reps=reps,
                           engine=engine,
                           harvest=DEFAULT_HARVEST_GRID if harvest
                           else None)
