"""DES-derived queue-wait lookup surface: the mechanism as a solver backend.

``cpu_model``'s fixed point needs, per workload and per iteration, the
DRAM-side queue wait at an operating point (utilization ``rho``, burstiness
``kappa``, closed-loop population ``outstanding``, DRAM-sensitivity ``eta``).
The closed form (``queueing.effective_queue_wait_ns``) answers that
analytically; this module answers it *mechanistically*: one batched
``coaxial.distribution_sweep`` runs the DES (``memsim``) over a
(rho, kappa, outstanding, eta) grid -- ``outstanding`` is a real simulated
field (the finite in-flight population that caps the FIFO backlog) and
``eta`` scales the blocking-episode probability at fixed mean service
time (the per-workload DRAM-sensitivity knob, now INSIDE the mechanism
instead of a post-hoc multiplier on the wait) -- and the resulting
latency distributions are reduced to four tables (mean wait / p90 wait /
p99 wait / latency stdev).

:class:`QueueLUT` is a pytree of those tables plus their grids, with
**differentiable multilinear interpolation**: the lookup is piecewise
linear in the query point (quadrilinear over the 4-D grid, with the
``outstanding`` axis located in LOG space -- its grid is geometric, so
log-space fractions interpolate the curvature instead of chord-cutting
it), clamped to the grid hull, and pure ``jnp`` -- so ``cpu_model`` can
pass a LUT straight into its jitted cell solver (any named-axis grid
still lowers to ONE trace per flattened cell count) and
``design_gradient`` can differentiate through the fixed point *and* the
table.  Passing ``lut=None`` to the solver selects the closed form; the
pytree-structure difference is what keys the jit cache, no static flags
needed.

Build cost: the default surface (14 x 6 x 6 x 4 grid) is one batched run
of the per-request event engine -- the 4th axis is what the
device-parallel DES (``memsim``'s ``devices`` knob, ``core/shardsim``)
buys; pass ``devices=`` (or set ``$REPRO_DES_DEVICES``) to shard the
build, bit-identically.  :func:`default_queue_lut` caches it per
(steps, seed, reps, engine), so a whole session pays for it once.
"""

from __future__ import annotations

import hashlib
import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import hw, lutstore
from repro.core.lutstore import clear_lut_cache  # noqa: F401 -- re-export

#: Default utilization grid: denser near saturation, where the open-loop
#: hyperbola is steep and linear interpolation would otherwise smear the
#: knee of the load-latency curve.  One notch finer than the original
#: 12-point grid (extra knee points at 0.62..0.91) -- affordable because
#: the default build engine is the per-request event engine, the first
#: step of the ROADMAP's LUT-resolution study.
DEFAULT_RHO_GRID = (0.05, 0.15, 0.25, 0.35, 0.45, 0.55, 0.62, 0.68,
                    0.74, 0.79, 0.84, 0.88, 0.91, 0.93)
#: Default burstiness grid (covers the Table-4 suite values 1.3..1.6 and
#: the synthetic-sweep range up to 3.2; 2.7 fills the former 2.2 -> 3.2
#: gap).
DEFAULT_KAPPA_GRID = (1.0, 1.3, 1.6, 2.2, 2.7, 3.2)
#: Default closed-loop population grid: ``n_active * MAX_MLP /
#: dram_channels`` spans ~2 (8 channels, 1 core) to 192 (the 12-core,
#: 1-channel DDR baseline); GEOMETRIC spacing -- the lookup interpolates
#: this axis in log space, where these points are near-uniform.
DEFAULT_OUTSTANDING_GRID = (2.0, 4.0, 8.0, 24.0, 64.0, 192.0)
#: Default DRAM-sensitivity grid: the Table-4 suite's eta spans ~0.05
#: (cache-friendly codes barely touch the far tail) to 1.0 (stream-like
#: codes take every blocking episode); the surface is near-linear in eta
#: (the wait is dominated by the episode-probability term), so four
#: points carry it.
DEFAULT_ETA_GRID = (0.05, 0.30, 0.60, 1.0)
#: Optional 5th axis: lent-time fraction of the idle-I/O harvesting chain
#: (arXiv 2511.12349 on top of CoaXiaL).  The table is built at the
#: REFERENCE lent bandwidth :data:`HARVEST_REF_BW_GBPS` -- one DDR5
#: channel's worth, which is also what one lent CXL x8 link contributes
#: (26 + 13 GB/s goodput) -- so the axis coordinate is "fraction of time
#: one extra channel's bandwidth is present".  Queries at other lent
#: bandwidths map through ``duty_eff = duty * bw / ref`` (see
#: ``cpu_model._latency_terms``).  Chosen over a plain effective-rho
#: mapping, which violates the designer's 35%/4ns verification envelope
#: by up to 227% in the bursty open-loop corner (see
#: docs/ARCHITECTURE.md "Harvesting").
DEFAULT_HARVEST_GRID = (0.0, 0.25, 0.5, 0.75)
HARVEST_REF_BW_GBPS = hw.DDR5_CH_BW_GBPS
#: Default DES budget per cell (ns simulated) and replicas per cell.
DEFAULT_STEPS = 120_000
DEFAULT_REPS = 2
#: Default build engine: the per-request event engine (the timestep
#: reference builds the same surface several times slower --
#: ``benchmarks/memsim_speed.py`` times both and cross-checks the
#: tables).
DEFAULT_ENGINE = "event"


class QueueLUT(NamedTuple):
    """DES-measured queue-wait surface over (rho, kappa, outstanding, eta).

    A pytree of nine array leaves: four ascending coordinate grids and
    four ``(R, K, O, E)`` tables -- mean queue wait, p90 queue wait, p99
    queue wait, and latency standard deviation (all ns).  :meth:`lookup`
    interpolates all four multilinearly (clamped at the hull; the
    ``outstanding`` axis in log space), vectorizes over any broadcastable
    query shapes, works inside ``jit``, and is differentiable in the
    query point.  The p99 table is what makes the solver's tail path
    mechanistic: the event engine records every request exactly, so the
    99th percentile costs nothing extra at build time, and downstream the
    designer's SLO constraint differentiates straight through it.

    Example (a hand-built two-point surface; real tables come from
    :func:`build_queue_lut`)::

        >>> import jax.numpy as jnp
        >>> from repro.core.queuelut import QueueLUT
        >>> z = jnp.zeros((2, 2, 2, 2))
        >>> lut = QueueLUT(rho_grid=jnp.array([0.0, 1.0]),
        ...                kappa_grid=jnp.array([1.0, 2.0]),
        ...                outstanding_grid=jnp.array([1.0, 100.0]),
        ...                eta_grid=jnp.array([0.0, 1.0]),
        ...                wait_ns=z.at[1].set(80.0),
        ...                p90_wait_ns=z, p99_wait_ns=z, sigma_ns=z)
        >>> float(lut.wait(0.5, 1.0, 1.0, 1.0))  # halfway up the rho edge
        40.0
        >>> float(lut.wait(2.0, 1.0, 1.0, 1.0))  # clamped at the grid hull
        80.0
        >>> float(lut.wait(0.5, 1.0, 10.0, 1.0))  # log-space outstanding:
        40.0
    """

    rho_grid: jnp.ndarray          # (R,) ascending
    kappa_grid: jnp.ndarray        # (K,) ascending
    outstanding_grid: jnp.ndarray  # (O,) ascending, positive
    eta_grid: jnp.ndarray          # (E,) ascending
    wait_ns: jnp.ndarray           # (R, K, O, E[, H]) mean queue wait
    p90_wait_ns: jnp.ndarray       # (R, K, O, E[, H]) p90 queue wait
    p99_wait_ns: jnp.ndarray       # (R, K, O, E[, H]) p99 queue wait
    sigma_ns: jnp.ndarray          # (R, K, O, E[, H]) latency stdev
    #: Optional 5th axis (None => 4-D tables): lent-time fraction of the
    #: idle-I/O harvesting chain at the reference lent bandwidth.
    harvest_grid: jnp.ndarray | None = None

    def lookup(self, rho, kappa, outstanding, eta=1.0, harvest=0.0):
        """Interpolated ``(mean wait, p90 wait, p99 wait, sigma)``.

        Queries broadcast together; out-of-grid coordinates clamp to the
        nearest hull face (constant extrapolation -- the DES was not run
        there, so the table refuses to invent a steeper law).  The
        ``outstanding`` fraction is computed in log space: its grid is
        geometric, and a query like 96 on a (64, 192) cell should sit
        near the geometric midpoint, not 1/4 from the top.

        ``harvest`` queries the optional 5th axis; on a 4-D surface
        (``harvest_grid is None``) it is IGNORED -- callers that need the
        harvested mechanism must build with ``harvest=`` (``cpu_model``
        resolves the right surface and raises on a mismatch).  A
        ``harvest=0.0`` query on a 5-D surface lands exactly on the
        duty-0 grid plane (the grid starts at 0), so unharvested lookups
        interpolate the same cells either way.
        """
        q = (rho, kappa, outstanding, eta)
        logs = (False, False, True, False)
        grids = (self.rho_grid, self.kappa_grid, self.outstanding_grid,
                 self.eta_grid)
        if self.harvest_grid is not None:
            q += (harvest,)
            logs += (False,)
            grids += (self.harvest_grid,)
        pts = jnp.broadcast_arrays(*(jnp.asarray(x, self.wait_ns.dtype)
                                     for x in q))
        loc = [_locate(g, p, log=lg)
               for g, p, lg in zip(grids, pts, logs)]
        return tuple(_blend(t, loc) for t in
                     (self.wait_ns, self.p90_wait_ns, self.p99_wait_ns,
                      self.sigma_ns))

    def wait(self, rho, kappa, outstanding, eta=1.0, harvest=0.0):
        """Interpolated mean queue wait alone (ns)."""
        return self.lookup(rho, kappa, outstanding, eta, harvest)[0]


def _locate(grid, x, log: bool = False):
    """(lower index, fraction) of ``x`` on an ascending grid, clamped.

    The fraction is what gradients flow through (piecewise linear); the
    index is integer and carries none, which is exactly the derivative a
    multilinear surface has.  ``log=True`` computes the fraction between
    the bracketing points in log space -- true geometric interpolation
    for geometrically spaced grids (the grid must be positive).
    """
    x = jnp.clip(x, grid[0], grid[-1])
    i = jnp.clip(jnp.searchsorted(grid, x, side="right") - 1,
                 0, grid.shape[0] - 2)
    lo, hi = grid[i], grid[i + 1]
    if log:
        t = jnp.log(x / lo) / jnp.log(hi / lo)
    else:
        t = (x - lo) / (hi - lo)
    return i, jnp.clip(t, 0.0, 1.0)


def _blend(table, loc):
    """Multilinear blend of the ``2**d`` corner cells around a located
    point (``d = len(loc)`` grid axes)."""
    out = 0.0
    for corner in range(2 ** len(loc)):
        w = 1.0
        idx = []
        for d, (i, t) in enumerate(loc):
            hi = (corner >> d) & 1
            w = w * (t if hi else 1.0 - t)
            idx.append(i + hi)
        out = out + w * table[tuple(idx)]
    return out


def _check_grid(name, grid, positive: bool = False):
    g = np.asarray(grid, np.float64)
    if g.ndim != 1 or g.size < 2:
        raise ValueError(f"{name} grid needs >= 2 points, got {g.shape}")
    if not np.all(np.diff(g) > 0):
        raise ValueError(f"{name} grid must be strictly ascending: "
                         f"{g.tolist()}")
    if positive and g[0] <= 0:
        raise ValueError(f"{name} grid must be positive (it interpolates "
                         f"in log space): {g.tolist()}")
    return tuple(float(v) for v in g)


#: Salt of the per-cell stream-id hash (bump to re-draw every surface).
_CELL_SALT = b"qlut-cell-v1:"


def cell_stream_ids(names, coords) -> np.ndarray:
    """Per-cell uint32 stream ids keyed by the cell's COORDINATES.

    ``names`` are the axis field names, ``coords`` an ``(N, d)`` float64
    coordinate matrix; the id is the first 32 bits of a sha256 over the
    exact (hex-formatted) coordinate values.  Keying streams by
    coordinates instead of batch position -- together with the pinned
    chunk schedule (``memsim.canonical_chunk``) -- makes every LUT cell's
    DES result independent of which other cells share the batch: a grid
    grown incrementally (``build_queue_lut(base_lut=...)``) is bit-
    identical to the same grid built from scratch, and a refinement
    probe re-simulating one cell reproduces the table entry exactly.
    """
    names = tuple(names)
    coords = np.asarray(coords, np.float64)
    ids = np.empty(coords.shape[0], np.uint32)
    for i, row in enumerate(coords):
        body = ";".join(f"{n}={float(v).hex()}"
                        for n, v in zip(names, row))
        h = hashlib.sha256(_CELL_SALT + body.encode()).digest()
        ids[i] = int.from_bytes(h[:4], "little")
    return ids


def _grid_axes(rho, kappa, outstanding, eta, harvest):
    """Validate grids; returns the ordered axes dict (+ checked grids)."""
    rho = _check_grid("rho", rho)
    kappa = _check_grid("kappa", kappa)
    outstanding = _check_grid("outstanding", outstanding, positive=True)
    eta = _check_grid("eta", eta)
    axes = dict(rho=rho, kappa=kappa, outstanding=outstanding, eta=eta)
    if harvest is not None:
        harvest = _check_grid("harvest", harvest)
        if harvest[0] < 0.0 or harvest[-1] >= 1.0:
            raise ValueError(f"harvest (duty) grid must lie in [0, 1): "
                             f"{list(harvest)}")
        axes["harvest_duty"] = harvest
    return axes, harvest


def _cell_coords(axes: dict) -> np.ndarray:
    """(N, d) float64 coordinates of the C-order flattened grid --
    exactly the flat cell order of ``coaxial.distribution_sweep``."""
    mesh = np.meshgrid(*(np.asarray(g, np.float64) for g in axes.values()),
                       indexing="ij")
    return np.stack([m.ravel() for m in mesh], axis=-1)


def _base_cell_map(axes: dict, base_lut: QueueLUT):
    """(present mask, base flat indices) of target cells found in a base.

    A target cell is PRESENT when every coordinate matches a base grid
    point exactly (compared in float32 -- the dtype the grids live at in
    the pytree).  Returns the boolean ``(N,)`` mask and, for the present
    cells, their flat C-order indices into the base tables.
    """
    base_grids = [np.asarray(g) for g in
                  (base_lut.rho_grid, base_lut.kappa_grid,
                   base_lut.outstanding_grid, base_lut.eta_grid)]
    if base_lut.harvest_grid is not None:
        base_grids.append(np.asarray(base_lut.harvest_grid))
    if len(base_grids) != len(axes):
        raise ValueError(
            "base_lut axis count does not match the target grid: "
            f"{len(base_grids)} vs {len(axes)} (harvest mismatch?)")
    shape = tuple(len(g) for g in axes.values())
    maps = []
    for tgt, bg in zip(axes.values(), base_grids):
        tgt32 = np.asarray(tgt, np.float32)
        m = np.full(len(tgt32), -1, np.int64)
        for j, v in enumerate(tgt32):
            hit = np.flatnonzero(bg == v)
            if hit.size:
                m[j] = hit[0]
        maps.append(m)
    idx = np.stack(np.meshgrid(*(np.arange(s) for s in shape),
                               indexing="ij"), -1).reshape(-1, len(shape))
    base_pos = np.stack([maps[a][idx[:, a]] for a in range(len(shape))],
                        axis=-1)
    present = (base_pos >= 0).all(axis=-1)
    base_shape = tuple(len(g) for g in base_grids)
    flat = (np.ravel_multi_index(base_pos[present].T, base_shape)
            if present.any() else np.empty(0, np.int64))
    return present, flat


def build_queue_lut(*, rho=DEFAULT_RHO_GRID, kappa=DEFAULT_KAPPA_GRID,
                    outstanding=DEFAULT_OUTSTANDING_GRID,
                    eta=DEFAULT_ETA_GRID, harvest=None,
                    harvest_bw_gbps: float = HARVEST_REF_BW_GBPS,
                    steps: int = DEFAULT_STEPS, seed: int = 0,
                    reps: int = DEFAULT_REPS, base=None,
                    engine: str = DEFAULT_ENGINE,
                    devices=None, base_lut: QueueLUT | None = None
                    ) -> QueueLUT:
    """Run ONE batched distribution sweep and reduce it to a QueueLUT.

    The whole (rho x kappa x outstanding x eta) grid lowers to one jitted
    simulation (``coaxial.distribution_sweep``); the wait tables are
    the DES latency means/p90s minus the unloaded DRAM service time, and
    the sigma table is the DES latency stdev verbatim -- the measured
    replacement for ``queueing.stdev_latency_ns``'s heuristic.
    ``engine`` picks the memsim engine; ``devices`` shards the build's
    flattened cell batch over host devices (``None`` consults
    ``$REPRO_DES_DEVICES``) -- the default 4-D grid is what the sharded
    DES buys, and the tables are bit-identical at any device count.

    Every build runs under the CANONICAL stream contract: each cell's
    threefry streams are keyed by its coordinates
    (:func:`cell_stream_ids`) and the chunk schedule is width-pinned
    (``memsim.canonical_chunk``), so a cell's tables are a pure function
    of its coordinates + (steps, seed, reps, engine, base channel) --
    never of the surrounding grid.  That is what makes builds
    INCREMENTAL: ``base_lut`` (a surface previously built with the SAME
    build parameters) donates every cell whose coordinates it already
    covers; only the missing cells are simulated (one batched run) and
    the tables merged -- bit-identical to building the whole grid from
    scratch (pinned by ``tests/test_lutstore.py``).

    ``harvest`` (a duty grid in [0, 1), e.g.
    :data:`DEFAULT_HARVEST_GRID`) grows the optional 5th axis: the sweep
    gains a ``harvest_duty`` dimension and the base channel lends
    ``harvest_bw_gbps`` while lent (default: the reference one-channel
    bandwidth, see :data:`HARVEST_REF_BW_GBPS`).

    Example (tiny grid, doctest-sized budget)::

        >>> from repro.core.queuelut import build_queue_lut
        >>> lut = build_queue_lut(rho=(0.2, 0.6), kappa=(1.0, 2.0),
        ...                       outstanding=(8.0, 192.0),
        ...                       eta=(0.1, 1.0), steps=4000, reps=1)
        >>> lut.wait_ns.shape
        (2, 2, 2, 2)
        >>> bool(lut.wait(0.6, 1.0, 192.0, 1.0) >
        ...      lut.wait(0.2, 1.0, 192.0, 1.0))
        True
        >>> hlut = build_queue_lut(rho=(0.2, 0.6), kappa=(1.0, 2.0),
        ...                        outstanding=(8.0, 192.0),
        ...                        eta=(0.1, 1.0), harvest=(0.0, 0.5),
        ...                        steps=4000, reps=1)
        >>> hlut.wait_ns.shape
        (2, 2, 2, 2, 2)
    """
    from repro.core import coaxial, memsim  # runtime: import cycle
    axes, harvest = _grid_axes(rho, kappa, outstanding, eta, harvest)
    if harvest is not None and base is None:
        base = memsim.ChannelConfig(
            rho=0.5, harvest_bw_gbps=float(harvest_bw_gbps))
    coords = _cell_coords(axes)
    sids = cell_stream_ids(axes.keys(), coords)
    chunk = memsim.canonical_chunk(engine)
    shape = tuple(len(g) for g in axes.values())
    grids = tuple(axes.values())

    def stat_arrays(stats):
        return (np.maximum(np.asarray(stats.mean_ns, np.float64)
                           - hw.DRAM_SERVICE_NS, 0.0),
                np.maximum(np.asarray(stats.p90_ns, np.float64)
                           - hw.DRAM_SERVICE_NS, 0.0),
                np.maximum(np.asarray(stats.p99_ns, np.float64)
                           - hw.DRAM_SERVICE_NS, 0.0),
                np.asarray(stats.stdev_ns, np.float64))

    if base_lut is None:
        sw = coaxial.distribution_sweep(
            **axes, base=base, steps=int(steps), seed=int(seed),
            reps=int(reps), engine=engine, devices=devices,
            stream_ids=sids, chunk=chunk)
        tables = stat_arrays(sw.stats)
    else:
        present, base_flat = _base_cell_map(axes, base_lut)
        missing = np.flatnonzero(~present)
        spec = coaxial.distribution_spec(**axes)
        flat = coaxial.build_flat_memsim(spec, base=base)
        fresh = None
        if missing.size:
            cha = memsim.ChannelArrays(
                *(np.asarray(leaf)[missing] for leaf in flat["cha"]))
            ov = {f: np.asarray(v)[missing]
                  for f, v in flat["overrides"].items()}
            stats = memsim.simulate_cells(
                cha, overrides=ov, steps=int(steps), seed=int(seed),
                warmup=memsim.default_warmup(int(steps)),
                reps=int(reps), engine=engine, devices=devices,
                stream_ids=sids[missing], chunk=chunk)
            fresh = stat_arrays(stats)
        base_tables = (base_lut.wait_ns, base_lut.p90_wait_ns,
                       base_lut.p99_wait_ns, base_lut.sigma_ns)
        tables = []
        for t, bt in enumerate(base_tables):
            full = np.empty(coords.shape[0], np.float64)
            # float32 -> float64 -> float32 round-trips exactly, so
            # donated cells keep the base surface's bits.
            full[present] = np.asarray(bt, np.float64).ravel()[base_flat]
            if fresh is not None:
                full[missing] = fresh[t]
            tables.append(full)

    to_j = lambda x: jnp.asarray(np.asarray(x, np.float64))
    wait, p90, p99, sigma = (t.reshape(shape) for t in tables)
    return QueueLUT(
        rho_grid=to_j(grids[0]), kappa_grid=to_j(grids[1]),
        outstanding_grid=to_j(grids[2]), eta_grid=to_j(grids[3]),
        wait_ns=to_j(wait), p90_wait_ns=to_j(p90),
        p99_wait_ns=to_j(p99), sigma_ns=to_j(sigma),
        harvest_grid=None if harvest is None else to_j(harvest))


def _store_params(axes: dict, harvest, harvest_bw_gbps, steps, seed,
                  reps, engine, base) -> dict:
    """The canonical JSON-able param dict behind a store key.

    ``devices`` is deliberately absent: tables are device-count
    invariant (``tests/test_shardsim.py`` pins it), so any device layout
    may share one entry.
    """
    import dataclasses
    base_fields = (None if base is None
                   else {k: float(v) for k, v in
                         sorted(dataclasses.asdict(base).items())})
    return dict(schema="queue_lut",
                axes={n: list(g) for n, g in axes.items()},
                harvest_bw_gbps=(float(harvest_bw_gbps)
                                 if harvest is not None else None),
                steps=int(steps), seed=int(seed), reps=int(reps),
                engine=str(engine), base=base_fields)


def resolve_lut(*, rho=DEFAULT_RHO_GRID, kappa=DEFAULT_KAPPA_GRID,
                outstanding=DEFAULT_OUTSTANDING_GRID,
                eta=DEFAULT_ETA_GRID, harvest=None,
                harvest_bw_gbps: float = HARVEST_REF_BW_GBPS,
                steps: int = DEFAULT_STEPS, seed: int = 0,
                reps: int = DEFAULT_REPS, base=None,
                engine: str = DEFAULT_ENGINE, devices=None,
                base_lut: QueueLUT | None = None) -> QueueLUT:
    """Store-backed :func:`build_queue_lut`: memory -> disk -> simulate.

    The resolution order is (1) the bounded in-process layer, (2) the
    ``$REPRO_LUT_CACHE`` on-disk store (bit-identical read, zero DES
    traces), (3) a fresh build -- which is then persisted.  The store key
    covers every build input plus the mechanism fingerprint (see
    :mod:`repro.core.lutstore`), so simulator changes rebuild
    automatically and a warm read can never serve a stale surface.

    ``base_lut`` only matters on a full miss: the build grows the base
    incrementally instead of starting from scratch (the refinement
    loop's round-over-round warm start).
    """
    from repro.core import memsim
    axes, harvest = _grid_axes(rho, kappa, outstanding, eta, harvest)
    if harvest is not None and base is None:
        base = memsim.ChannelConfig(
            rho=0.5, harvest_bw_gbps=float(harvest_bw_gbps))
    key = lutstore.store_key(_store_params(
        axes, harvest, harvest_bw_gbps, steps, seed, reps, engine, base))
    lut = lutstore.cache_get(key)
    if lut is None:
        lut = lutstore.load(key)
        if lut is None:
            lut = build_queue_lut(
                rho=axes["rho"], kappa=axes["kappa"],
                outstanding=axes["outstanding"], eta=axes["eta"],
                harvest=harvest, harvest_bw_gbps=harvest_bw_gbps,
                steps=steps, seed=seed, reps=reps, base=base,
                engine=engine, devices=devices, base_lut=base_lut)
            lutstore.save(key, lut, meta=dict(
                engine=str(engine), steps=int(steps), seed=int(seed),
                reps=int(reps),
                shape=list(np.shape(np.asarray(lut.wait_ns))),
                harvest=harvest is not None))
        lutstore.cache_put(key, lut)
    return lut


def default_queue_lut(steps: int = DEFAULT_STEPS, seed: int = 0,
                      reps: int = DEFAULT_REPS,
                      engine: str = DEFAULT_ENGINE,
                      harvest: bool = False) -> QueueLUT:
    """The shared default-grid surface, resolved through the LUT store.

    This is what ``cpu_model.solve(..., queue_model="memsim")`` uses when
    no explicit LUT is passed (``harvest=True`` when any solved design
    harvests -- the tables gain the :data:`DEFAULT_HARVEST_GRID` axis).
    Resolution goes memory -> ``$REPRO_LUT_CACHE`` -> DES build (see
    :func:`resolve_lut`); the historical unbounded ``lru_cache`` is gone
    -- the in-process layer is bounded (``lutstore.MEM_CACHE_MAX``) and
    :func:`clear_lut_cache` empties it.  The build honours
    ``$REPRO_DES_DEVICES`` (via ``devices=None``), and the tables are
    device-count-invariant, so the key need not include it.
    """
    return resolve_lut(steps=steps, seed=seed, reps=reps, engine=engine,
                       harvest=DEFAULT_HARVEST_GRID if harvest else None)


# ---------------------------------------------------------------------------
# Adaptive grid refinement: the ROADMAP's LUT-resolution endgame.
# ---------------------------------------------------------------------------

#: The LLM serving anchor whose wave-model token p99 tracks refinement
#: (the same arch the designer CLI and the drift section anchor on).
REFINE_ARCH = "mistral-large-123b"

#: Probe anchor: the off-axis coordinates each midpoint is probed at --
#: a mid-load bursty operating point near the headline designs' fixed
#: points, where interpolation error actually moves the answers.
PROBE_ANCHOR = dict(rho=0.74, kappa=1.6, outstanding=24.0, eta=0.60,
                    harvest_duty=0.0)

#: Intervals whose probe error is below this floor are never bisected --
#: DES sampling noise, not interpolation error.
REFINE_ERR_FLOOR = 0.02


def headline_metrics(lut: QueueLUT) -> dict:
    """The two convergence metrics of :func:`refine_queue_lut`.

    ``geomean_speedup``: CoaXiaL-4x over the DDR baseline, geomean over
    the Table-4 suite, both solved on the MEMSIM backend through ``lut``
    (the fig7 headline).  ``token_p99_ms``: the capacity planner's
    wave-model token p99 for :data:`REFINE_ARCH` on CoaXiaL-4x, composed
    from the solved ``latency_p99_ns``/``ipc`` exactly as the designer's
    in-loop SLO does.  Both are pure LUT-backed fixed-point solves -- no
    DES runs, so a refinement round costs two solves plus the probe
    batch.
    """
    from repro.core import cpu_model
    from repro.core.designer import _wave_geometry
    from repro.serving.demand import (DEFAULT_BATCH, DEFAULT_CONTEXT,
                                      llm_workload)
    wls = tuple(cpu_model.WORKLOADS) + (llm_workload(REFINE_ARCH),)
    res = cpu_model.solve(cpu_model.COAXIAL_4X, queue_model="memsim",
                          lut=lut, workloads=wls)
    ref = cpu_model.solve(cpu_model.DDR_BASELINE, queue_model="memsim",
                          lut=lut, workloads=wls)
    n_suite = len(cpu_model.WORKLOADS)
    sp = (np.asarray(res.ipc, np.float64)[:n_suite]
          / np.asarray(ref.ipc, np.float64)[:n_suite])
    waves, model_coef = _wave_geometry(REFINE_ARCH, DEFAULT_BATCH,
                                       DEFAULT_CONTEXT)
    tok99_s = max(waves * float(res.latency_p99_ns[-1]) * 1e-9,
                  model_coef / float(res.ipc[-1]))
    return dict(geomean_speedup=float(np.exp(np.mean(np.log(sp)))),
                token_p99_ms=tok99_s * 1e3)


def _midpoint(axis: str, lo: float, hi: float) -> float:
    """Interval midpoint in the axis's interpolation space (geometric
    for the log-interpolated ``outstanding`` axis, arithmetic else)."""
    if axis == "outstanding":
        return float(np.sqrt(lo * hi))
    return 0.5 * (lo + hi)


def refine_queue_lut(*, rho=None, kappa=None, outstanding=None,
                     eta=None, harvest=None,
                     harvest_bw_gbps: float = HARVEST_REF_BW_GBPS,
                     steps: int = DEFAULT_STEPS, seed: int = 0,
                     reps: int = DEFAULT_REPS,
                     engine: str = DEFAULT_ENGINE, devices=None,
                     tol: float = 0.01, max_rounds: int = 4,
                     metrics=headline_metrics):
    """Adaptively refine the LUT grid until the headlines stop moving.

    Starting from the given grids (default: every-other-point
    coarsenings of the default grids, so the loop has real work), each
    round:

    1. resolves the current grid through the store
       (:func:`resolve_lut`), growing the previous round's surface
       INCREMENTALLY -- only new cells run the DES;
    2. evaluates the convergence metrics (default
       :func:`headline_metrics`: fig7 geomean speedup + wave-model token
       p99) and STOPS when both moved less than ``tol`` (relative)
       against the previous round;
    3. otherwise probes every interval midpoint per axis (off-axis
       coordinates pinned at :data:`PROBE_ANCHOR`) against ONE batched
       direct DES run, and bisects the worst-error interval of each axis
       whose error clears :data:`REFINE_ERR_FLOOR`.

    This operationalizes the ROADMAP's "push the grid finer until the
    interpolated fixed point is insensitive" as a testable criterion.
    Returns ``(lut, history)`` -- one history dict per round with the
    grids' shape, cell count, metric values, relative deltas, worst
    probe error, and wall-clock; ``history[-1]["converged"]`` says
    whether the loop stopped on the criterion (vs running out of
    rounds).  ``report --section lut`` renders the trajectory.
    """
    from repro.core import memsim  # runtime: import cycle
    grids = dict(
        rho=tuple(rho) if rho is not None else DEFAULT_RHO_GRID[::2],
        kappa=(tuple(kappa) if kappa is not None
               else DEFAULT_KAPPA_GRID[::2]),
        outstanding=(tuple(outstanding) if outstanding is not None
                     else DEFAULT_OUTSTANDING_GRID[::2]),
        eta=tuple(eta) if eta is not None else DEFAULT_ETA_GRID[::2])
    if harvest is not None:
        grids["harvest_duty"] = tuple(harvest)
    history: list[dict] = []
    lut, prev = None, None
    for rnd in range(int(max_rounds)):
        t0 = time.perf_counter()
        lut = resolve_lut(
            rho=grids["rho"], kappa=grids["kappa"],
            outstanding=grids["outstanding"], eta=grids["eta"],
            harvest=grids.get("harvest_duty"),
            harvest_bw_gbps=harvest_bw_gbps, steps=steps, seed=seed,
            reps=reps, engine=engine, devices=devices, base_lut=lut)
        m = metrics(lut)
        row = dict(round=rnd,
                   shape=tuple(len(g) for g in grids.values()),
                   cells=int(np.prod([len(g) for g in grids.values()])),
                   converged=False, worst_err=0.0,
                   seconds=round(time.perf_counter() - t0, 3), **m)
        if prev is not None:
            row["d_geomean"] = abs(m["geomean_speedup"]
                                   / prev["geomean_speedup"] - 1.0)
            row["d_token_p99"] = abs(m["token_p99_ms"]
                                     / prev["token_p99_ms"] - 1.0)
            if (row["d_geomean"] < tol and row["d_token_p99"] < tol):
                row["converged"] = True
                history.append(row)
                break
        prev = m

        # Probe every interval midpoint, one batched DES run (canonical
        # streams: the probes are reproducible cell-for-cell).
        probes, owners = [], []
        for axis, grid in grids.items():
            for j in range(len(grid) - 1):
                c = dict(PROBE_ANCHOR)
                if "harvest_duty" not in grids:
                    c.pop("harvest_duty")
                c[axis] = _midpoint(axis, grid[j], grid[j + 1])
                probes.append(c)
                owners.append((axis, j))
        names = tuple(grids)
        coords = np.asarray([[p[n] for n in names] for p in probes])
        extra = ({"harvest_bw_gbps": float(harvest_bw_gbps)}
                 if "harvest_duty" in grids else {})
        cha = memsim.stack_channels(
            [memsim.ChannelConfig(**p, **extra) for p in probes])
        stats = memsim.simulate_cells(
            cha, steps=int(steps), seed=int(seed), reps=int(reps),
            engine=engine, devices=devices,
            stream_ids=cell_stream_ids(names, coords),
            chunk=memsim.canonical_chunk(engine))
        des_wait = np.maximum(
            np.asarray(stats.mean_ns, np.float64) - hw.DRAM_SERVICE_NS,
            0.0)
        lut_wait = np.asarray([float(lut.wait(
            p["rho"], p["kappa"], p["outstanding"], p["eta"],
            p.get("harvest_duty", 0.0))) for p in probes])
        # Error relative to the TOTAL access latency (wait + service):
        # that is what the solver consumes, and it keeps low-rho cells'
        # few-ns waits from turning DES noise into huge relative errors.
        err = (np.abs(lut_wait - des_wait)
               / (des_wait + hw.DRAM_SERVICE_NS))
        row["worst_err"] = float(err.max()) if len(err) else 0.0
        history.append(row)

        # Bisect each axis's worst interval (if it clears the floor).
        grew = False
        for axis in names:
            cand = [(err[i], owners[i][1]) for i in range(len(owners))
                    if owners[i][0] == axis]
            if not cand:
                continue
            worst, j = max(cand)
            if worst <= REFINE_ERR_FLOOR:
                continue
            g = list(grids[axis])
            g.insert(j + 1, _midpoint(axis, g[j], g[j + 1]))
            grids[axis] = tuple(g)
            grew = True
        if not grew:
            # Nothing left to bisect: the next round's metrics cannot
            # move, so record the (exactly zero) deltas and stop.
            m2 = metrics(lut)
            history.append(dict(
                round=rnd + 1, shape=row["shape"], cells=row["cells"],
                converged=True, worst_err=row["worst_err"], seconds=0.0,
                d_geomean=0.0, d_token_p99=0.0, **m2))
            break
    return lut, history
