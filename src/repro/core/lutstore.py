"""Content-addressed on-disk QueueLUT store (``$REPRO_LUT_CACHE``).

The DES-built :class:`~repro.core.queuelut.QueueLUT` is the costliest
artifact every session rebuilds: CI smoke, ``python -m repro.designer``,
``repro.serving.plan`` and the tier-1 tests each pay for the full
14x6x6x4(xharvest) surface behind an in-process cache that dies with the
process.  This module persists the surfaces, mirroring the
``REPRO_COMPILE_CACHE`` idiom (``benchmarks/common.py``): set
``$REPRO_LUT_CACHE`` to a directory and every built surface is written
there once and read back bit-identically forever after -- a warm read
runs ZERO simulation (``memsim.sim_trace_count`` stays flat, pinned by
``tests/test_lutstore.py``).

Store layout -- one ``.npz`` per surface, named by its key::

    $REPRO_LUT_CACHE/qlut-<sha256[:32]>.npz

The key is a sha256 over every input that determines the tables:

* all grid tuples (rho / kappa / outstanding / eta / harvest);
* the DES build parameters (steps, seed, reps, engine,
  harvest_bw_gbps, and the base ChannelConfig's field values);
* the per-engine **mechanism fingerprint** (:func:`mechanism_fingerprint`).

The fingerprint hashes the SOURCE of the simulator stack (``memsim.py``,
``shardsim.py``, ``queuelut.py``) plus a schema version -- any simulator
change shifts the key, so stale surfaces are never read, only orphaned
(and later :func:`gc`'d).  It is deliberately coarser than the
BEHAVIORAL fingerprints sha-pinned in ``tests/test_harvest.py``
(``PRE_HARVEST_SHA``): computing those requires *running* the DES, which
is exactly what a warm read must skip; a source hash over-invalidates at
worst (one spurious rebuild per comment edit), never under-invalidates.

Integrity: writes are atomic (temp file + ``os.replace`` in the store
directory), and a corrupted or truncated artifact is QUARANTINED on read
(renamed to ``*.corrupt``) and rebuilt -- never a crash.

On top of the disk layer sits a small bounded in-process LRU
(:data:`MEM_CACHE_MAX` surfaces) -- the replacement for the historical
unbounded ``functools.lru_cache`` on ``default_queue_lut``, which pinned
every distinct surface's device arrays for process lifetime.
:func:`clear_lut_cache` empties it (tests use this to force cold reads).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from collections import OrderedDict
from pathlib import Path

import numpy as np

#: Bump to invalidate every stored surface on a format change.
SCHEMA = 1

#: Environment knob naming the store directory (unset => store disabled).
ENV_VAR = "REPRO_LUT_CACHE"

#: Source files whose bytes define the mechanism fingerprint: the
#: simulator, its sharding layer, and the table derivation.
_FINGERPRINT_SOURCES = ("memsim.py", "shardsim.py", "queuelut.py")

#: Max surfaces held by the bounded in-process layer.  Each default
#: surface is ~100 KB of tables; 8 covers every (engine, harvest, steps)
#: combination a test session or benchmark run actually touches.
MEM_CACHE_MAX = 8

_mem_cache: OrderedDict[str, object] = OrderedDict()
_fingerprint_memo: str | None = None


def cache_dir() -> Path | None:
    """The store directory per ``$REPRO_LUT_CACHE``, created on demand.

    Unset or blank disables the on-disk store entirely (the bounded
    in-process layer still works) -- exactly the
    ``REPRO_COMPILE_CACHE`` contract.
    """
    path = os.environ.get(ENV_VAR, "").strip()
    if not path:
        return None
    p = Path(path)
    p.mkdir(parents=True, exist_ok=True)
    return p


def mechanism_fingerprint() -> str:
    """sha256 over the simulator stack's source + the store schema.

    Memoized per process: the sources cannot change under a running
    interpreter in any way the interpreter would notice anyway.
    """
    global _fingerprint_memo
    if _fingerprint_memo is None:
        h = hashlib.sha256(f"schema={SCHEMA}".encode())
        here = Path(__file__).parent
        for name in _FINGERPRINT_SOURCES:
            h.update(name.encode())
            h.update((here / name).read_bytes())
        _fingerprint_memo = h.hexdigest()
    return _fingerprint_memo


def store_key(params: dict) -> str:
    """Content address of a surface: sha256 over build params + fingerprint.

    ``params`` must be JSON-serializable with deterministic ordering
    (grids as tuples of floats, scalars, or None) -- the caller
    (``queuelut.resolve_lut``) canonicalizes them.
    """
    body = json.dumps({"fingerprint": mechanism_fingerprint(),
                       **params}, sort_keys=True)
    return hashlib.sha256(body.encode()).hexdigest()


def entry_path(key: str, root: Path | None = None) -> Path | None:
    root = cache_dir() if root is None else root
    if root is None:
        return None
    return root / f"qlut-{key[:32]}.npz"


def _quarantine(path: Path) -> None:
    """Move a bad artifact aside (never delete: it is evidence)."""
    try:
        path.replace(path.with_suffix(path.suffix + ".corrupt"))
    except OSError:
        pass                      # racing process already moved it


def save(key: str, lut, meta: dict | None = None) -> Path | None:
    """Persist a QueueLUT atomically; returns the path (None = disabled).

    Leaves are written as raw numpy arrays (float32 under the default
    jax config); the round trip back through :func:`load` is bit-exact.
    """
    path = entry_path(key)
    if path is None:
        return None
    arrays = {f: np.asarray(leaf) for f, leaf in zip(lut._fields, lut)
              if leaf is not None}
    meta = dict(meta or {}, schema=SCHEMA, key=key,
                fingerprint=mechanism_fingerprint(),
                unix_time=int(time.time()))
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, meta_json=np.frombuffer(
                json.dumps(meta).encode(), np.uint8), **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def load(key: str):
    """Read a stored surface; None on miss.  Corruption => quarantine.

    Returns the reconstructed ``QueueLUT`` (imported lazily -- queuelut
    imports this module at top level).  Any failure to read, parse, or
    validate the artifact quarantines the file and reports a miss, so a
    torn write or a flipped bit costs one rebuild, never a crash.
    """
    path = entry_path(key)
    if path is None or not path.exists():
        return None
    from repro.core.queuelut import QueueLUT
    import jax.numpy as jnp
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(bytes(z["meta_json"]).decode())
            if meta.get("schema") != SCHEMA or meta.get("key") != key:
                raise ValueError("schema/key mismatch")
            if meta.get("fingerprint") != mechanism_fingerprint():
                raise ValueError("fingerprint mismatch")
            fields = {f: jnp.asarray(z[f]) for f in QueueLUT._fields
                      if f in z.files}
        for f in QueueLUT._fields[:8]:        # grids + the four tables
            if f not in fields:
                raise ValueError(f"missing field {f}")
        return QueueLUT(**fields)
    except Exception:             # noqa: BLE001 -- ANY read failure
        _quarantine(path)
        return None


def read_meta(path: Path) -> dict | None:
    """Best-effort meta block of one store entry (None if unreadable)."""
    try:
        with np.load(path, allow_pickle=False) as z:
            return json.loads(bytes(z["meta_json"]).decode())
    except Exception:             # noqa: BLE001 -- inspect never raises
        return None


def entries() -> list[dict]:
    """Every store entry with its meta (for ``python -m repro.lut``)."""
    root = cache_dir()
    if root is None:
        return []
    out = []
    for path in sorted(root.glob("qlut-*.npz")):
        meta = read_meta(path) or {}
        out.append(dict(path=str(path), bytes=path.stat().st_size,
                        **meta))
    return out


def gc(max_age_days: float | None = None, everything: bool = False) -> dict:
    """Drop stale entries (and all ``*.corrupt`` quarantine files).

    ``everything=True`` empties the store; otherwise entries older than
    ``max_age_days`` (by recorded build time, falling back to mtime) and
    entries whose fingerprint no longer matches the current simulator
    are removed.  Returns ``{"removed": n, "bytes": freed}``.
    """
    root = cache_dir()
    if root is None:
        return dict(removed=0, bytes=0)
    removed = freed = 0
    now = time.time()
    fp = mechanism_fingerprint()
    for path in list(root.glob("qlut-*.npz")) + \
            list(root.glob("*.corrupt")):
        drop = everything or path.suffix == ".corrupt"
        if not drop:
            meta = read_meta(path)
            if meta is None or meta.get("fingerprint") != fp:
                drop = True
            elif max_age_days is not None:
                built = meta.get("unix_time", path.stat().st_mtime)
                drop = (now - built) > max_age_days * 86_400.0
        if drop:
            try:
                size = path.stat().st_size
                path.unlink()
                removed += 1
                freed += size
            except OSError:
                pass
    return dict(removed=removed, bytes=freed)


# ---------------------------------------------------------------------------
# Bounded in-process layer.
# ---------------------------------------------------------------------------

def cache_get(key: str):
    """In-process LRU lookup (refreshes recency on hit)."""
    lut = _mem_cache.get(key)
    if lut is not None:
        _mem_cache.move_to_end(key)
    return lut


def cache_put(key: str, lut) -> None:
    _mem_cache[key] = lut
    _mem_cache.move_to_end(key)
    while len(_mem_cache) > MEM_CACHE_MAX:
        _mem_cache.popitem(last=False)


def clear_lut_cache() -> None:
    """Empty the bounded in-process layer (tests force cold reads with
    this; the on-disk store is untouched)."""
    _mem_cache.clear()
