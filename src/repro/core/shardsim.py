"""Lane-axis device parallelism for the DES: a 1-D mesh over host devices.

``memsim``'s flattened ``(cells x reps)`` batch is embarrassingly parallel
-- lanes are independent Markov chains that never exchange data -- so the
device-parallel story is the simplest one ``shard_map`` can tell: build a
1-D :class:`~jax.sharding.Mesh` whose single axis is the **lane axis**,
pad the batch to a multiple of the device count (NaN lanes, the same
masked-override idiom ``memsim`` already uses -- a NaN channel never
records an arrival, so padding lanes park all their histogram mass in the
overflow slot the host drops anyway), and run the *same jitted chunk
kernel* on every device over its lane slice.

This is the ``core/``-side sibling of ``repro.distributed.sharding`` (the
model-parameter rules engine): that module maps *logical tensor axes*
onto a training mesh; this one owns the single ``"lanes"`` axis the DES
needs and stays importable from ``core`` (jax-only, no model deps).

Determinism contract (pinned by ``tests/test_shardsim.py``):

  * every random stream is keyed by the **logical lane index** (threefry
    ``fold_in(chunk_key, lane)``), never by batch width or device count,
    so a lane draws the same uniforms whether it is simulated alone, in a
    wider batch, on one device or on eight;
  * chunk lengths and budgets derive from the UNPADDED flat width, so
    padding (a device-count artifact) cannot perturb them;
  * histogram indices are ``lane * N_BINS + bin`` with *global* lane ids,
    so per-shard emissions concatenate into one flat index space and the
    host's integer ``bincount`` merges them exactly -- counts are small
    integers, exact in any accumulation order.

Together these make the sharded path **bit-identical** to the unsharded
path per cell, which is why ``devices`` can default to an environment
knob (``REPRO_DES_DEVICES``) without perturbing a single pinned test.

Use ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (SNIPPETS
idiom) to split one host CPU into N XLA devices; on real multi-device
hosts the flag is unnecessary.
"""

from __future__ import annotations

import functools
import os

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

#: The single mesh axis name: the flattened (cells x reps [x pad]) axis.
AXIS = "lanes"

#: Environment knob consulted when ``devices=None``: an integer device
#: count, or ``auto`` for every local device.  Unset means 1 (the exact
#: historical single-device path).
ENV_DEVICES = "REPRO_DES_DEVICES"


def resolve_devices(devices=None) -> int:
    """Resolve a ``devices=`` knob to a concrete device count.

    ``None`` consults ``$REPRO_DES_DEVICES`` (unset -> 1); ``"auto"``
    means every local device; an int (or int-like string) is validated
    against the local device count.  Results never depend on the choice
    -- only wall-clock does -- so callers may cache across values.
    """
    if devices is None:
        env = os.environ.get(ENV_DEVICES, "").strip()
        if not env:
            return 1
        devices = env
    if isinstance(devices, str):
        if devices.lower() == "auto":
            return len(jax.devices())
        try:
            devices = int(devices)
        except ValueError:
            raise ValueError(
                f"devices must be an int, 'auto' or None; got {devices!r} "
                f"(via ${ENV_DEVICES}?)") from None
    n = int(devices)
    avail = len(jax.devices())
    if n < 1:
        raise ValueError(f"devices must be >= 1, got {n}")
    if n > avail:
        raise ValueError(
            f"devices={n} exceeds the {avail} local device(s); force more "
            f"host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return n


def pad_width(n: int, ndev: int) -> int:
    """Lanes to append so ``n`` divides evenly over ``ndev`` devices."""
    return (-int(n)) % int(ndev)


@functools.lru_cache(maxsize=None)
def lane_mesh(ndev: int) -> Mesh:
    """The 1-D lane mesh over the first ``ndev`` local devices."""
    return Mesh(np.array(jax.devices()[:ndev]), (AXIS,))


def lanes(dim: int = 0) -> P:
    """PartitionSpec sharding axis ``dim`` over the lane mesh axis."""
    return P(*((None,) * dim + (AXIS,)))


def replicated() -> P:
    return P()


def jit_lanes(body, ndev: int, in_specs, out_specs):
    """Jit ``body``; for ``ndev > 1`` wrap it in ``shard_map`` first.

    ``in_specs`` / ``out_specs`` are pytree prefixes of the body's args /
    results (a single :func:`lanes` spec covers a whole ``ChannelArrays``
    or state-tuple subtree).  ``ndev == 1`` skips ``shard_map`` entirely:
    the sharded path is bit-identical, but the plain jit is the exact
    historical code path and free of partitioning overhead.  Either way
    the body traces ONCE per compile, so trace-count pins hold.
    """
    if ndev == 1:
        return jax.jit(body)
    return jax.jit(shard_map(body, mesh=lane_mesh(ndev),
                             in_specs=in_specs, out_specs=out_specs,
                             check_rep=False))
