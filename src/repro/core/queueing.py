"""Load -> latency queuing models for channelized memory (paper §3.1, Fig 2a).

The paper's central quantitative object is the load-latency curve of a
DDR5-4800 channel (Fig 2a), whose anchors it states explicitly:

  * unloaded latency ~= 40 ns;
  * average latency rises 3x at 50% utilization and 4x at 60%;
  * p90 latency rises 4.7x and 7.1x at the same points.

We reproduce the curve with a calibrated M/G/1-style closed form.  The
average-latency anchors are matched *exactly* by

    L(rho) = 40 + 80 * rho / (1 - rho)          [ns]

(check: L(.5) = 120 = 3*40, L(.6) = 160 = 4*40), and the p90 anchors by

    P90(rho) = 40 + 148 * (rho / (1 - rho))**1.232

(check: P90(.5) = 188 = 4.7*40, P90(.6) ~= 284 = 7.1*40).

These closed forms also reproduce the worked example of §3.1: moving a 60%
utilized DDR system to 15% (a 4x bandwidth boost) plus a 30 ns CXL premium
gives ~50% lower average latency and ~68% lower p90 -- exactly the paper's
numbers.  Tests pin all of these.

On top of the open-loop curve we model three real-system effects the paper
discusses in §3.1/§6.2:

  * **burstiness** (bwaves: 32% average utilization but 390 ns queuing):
    requests arrive in bursts with a peak-to-mean ratio ``kappa``; a fraction
    ``phi`` of requests observe the burst-utilization queue;
  * **bank/channel balance** (kmeans / streamcluster: high utilization but
    low queuing thanks to evenly spread accesses): a multiplicative factor
    ``eta`` <= 1 on the queue wait;
  * **closed-loop saturation**: a finite number of outstanding misses
    (cores x MLP) bounds the queue length, so the open-loop hyperbola is
    capped at ``outstanding_per_channel * t_transfer``.

All functions are pure jax/jnp and vectorize over arbitrary batch dims.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hw

# Calibrated to the paper's Fig 2a anchor points -- do not tune.
AVG_Q_COEF_NS = 80.0
P90_Q_COEF_NS = 148.0
P90_Q_EXP = 1.232

#: Latency-stdev model: a base dispersion from DRAM bank/row state plus a
#: queue-wait-proportional term.  Calibrated against the paper's
#: streamcluster case study (§6.2: baseline mean 69 ns / stdev 88;
#: COAXIAL mean 76 ns / stdev 76).
SIGMA_BASE_NS = 75.0
SIGMA_Q_COEF = 1.0

#: Utilization ceiling -- keeps the open-loop hyperbola finite; the
#: closed-loop cap is what actually binds near saturation.
RHO_MAX = 0.97


def _clip_rho(rho):
    return jnp.clip(rho, 0.0, RHO_MAX)


def queue_wait_ns(rho):
    """Open-loop average queue wait at utilization ``rho`` (ns)."""
    r = _clip_rho(rho)
    return AVG_Q_COEF_NS * r / (1.0 - r)


def avg_latency_ns(rho):
    """Average loaded access latency of one DDR5-4800 channel (ns)."""
    return hw.DRAM_SERVICE_NS + queue_wait_ns(rho)


def p90_latency_ns(rho):
    """p90 loaded access latency of one DDR5-4800 channel (ns)."""
    r = _clip_rho(rho)
    x = r / (1.0 - r)
    return hw.DRAM_SERVICE_NS + P90_Q_COEF_NS * x**P90_Q_EXP


def burst_queue_wait_ns(rho, kappa=1.0):
    """Queue wait under bursty (MMPP-like) arrivals.

    ``kappa`` is the peak-to-mean arrival-rate ratio.  In M/G/1-with-batches
    the mean wait scales with the arrival index of dispersion, i.e. with
    ``kappa**2`` -- which is how a workload like bwaves can see ~390 ns of
    queuing at only 32% average utilization (§6.2).  ``kappa = 1`` degrades
    to the calibrated Poisson-ish open-loop wait.
    """
    return kappa**2 * queue_wait_ns(rho)


def closed_loop_cap_ns(outstanding_per_channel, channel_bw_gbps):
    """Upper bound on queue wait from a finite outstanding-miss population.

    With at most N requests in flight per channel and a data-bus transfer
    time of 64B / BW, the FIFO wait cannot exceed N * t_transfer.
    """
    t_xfer = hw.CACHE_LINE_B / channel_bw_gbps  # ns (B / (GB/s) = ns)
    return outstanding_per_channel * t_xfer


def effective_queue_wait_ns(
    rho,
    *,
    kappa=1.0,
    eta=1.0,
    outstanding_per_channel=hw.SIM_CORES * hw.MAX_MLP,
    channel_bw_gbps=hw.DDR5_CH_BW_GBPS,
):
    """Queue wait combining burstiness, balance and the closed-loop cap.

    The cap is *architectural* (MSHR/ROB bound on outstanding misses): with
    at most N requests in flight per channel the FIFO wait cannot exceed
    N * t_transfer, no matter what the open-loop hyperbola says.  The queue
    only holds that many requests when the system actually drives them, so
    the cap is scaled by the *burst* occupancy min(1, rho * kappa) -- during
    a burst the MSHRs are full even if average utilization is modest (this
    is the paper's bwaves case: ~390 ns queuing at 32% utilization).
    """
    w_open = eta * burst_queue_wait_ns(rho, kappa)
    cap = closed_loop_cap_ns(outstanding_per_channel, channel_bw_gbps)
    occupancy = jnp.minimum(1.0, rho * kappa)
    return jnp.minimum(w_open, cap * occupancy)


def stdev_latency_ns(queue_wait):
    """Latency standard deviation given the average queue wait (ns).

    sigma^2 = sigma_base^2 + (c * W_q)^2: a load-independent dispersion from
    DRAM bank/row-buffer state plus a queue-driven heavy-tail term.
    """
    return jnp.sqrt(SIGMA_BASE_NS**2 + (SIGMA_Q_COEF * queue_wait) ** 2)


def closed_form_stats(rho, *, kappa=1.0, cxl_lat_ns=0.0) -> dict:
    """The closed-form latency anchors at one operating point (ns).

    The cross-validation contract between the two halves of the
    reproduction: ``coaxial.validate_calibration`` compares the DES's
    mean / p90 / stdev against exactly these numbers.  ``kappa``
    generalizes both curves with the burst index of dispersion
    (``kappa**2`` on the queueing term, degrading to the calibrated
    Fig-2a anchors at ``kappa = 1``); ``cxl_lat_ns`` adds the fixed CXL
    interface premium.  Vectorizes over ``rho`` like everything else
    here.
    """
    wait = burst_queue_wait_ns(rho, kappa)
    r = _clip_rho(rho)
    x = kappa**2 * r / (1.0 - r)
    return dict(
        mean_ns=hw.DRAM_SERVICE_NS + wait + cxl_lat_ns,
        p90_ns=hw.DRAM_SERVICE_NS + P90_Q_COEF_NS * x**P90_Q_EXP
        + cxl_lat_ns,
        stdev_ns=stdev_latency_ns(wait),
    )


def link_queue_wait_ns(rho_link, service_ns, kappa=1.0):
    """Queue wait at a serial (CXL/PCIe) link with given per-request service.

    Modeled as M/D/1-like: W = S * rho / (2 * (1 - rho)), with the same
    kappa**2 burst dispersion as the DRAM-side queue.  The service time of a
    64B flit on a 26 GB/s link is ~2.5 ns, so this term is small unless the
    link is the bottleneck -- matching the paper's claim that an x8 CXL link
    supports a full DDR5 channel "without becoming a choke point" (§4.1).
    """
    r = _clip_rho(rho_link)
    return kappa**2 * service_ns * r / (2.0 * (1.0 - r))
