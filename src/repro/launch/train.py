"""Training launcher: end-to-end driver wiring every substrate together.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires: synthetic data pipeline (prefetch thread) -> pjit'd train step
(FSDP/TP sharding rules on whatever mesh exists) -> AdamW (+ optional int8
error-feedback grad compression) -> async checkpointing -> resilient loop
(retry / restore-from-checkpoint / straggler monitor / heartbeat).

On this CPU container it drives reduced configs (--smoke); on a real slice
the same file runs the full configs (the mesh and sharding rules are
identical code paths -- proven by the dry-run).
"""

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import PrefetchIterator, SyntheticDataset
from repro.distributed import fault, sharding as shd
from repro.distributed.step import (TrainStepConfig, init_train_state,
                                    make_train_step, train_state_specs)
from repro.launch.mesh import make_host_mesh
from repro.models.config import smoke_variant
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig


def build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if args.remat:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    model = Model(cfg)
    mesh = make_host_mesh(model_axis=args.model_axis)
    step_cfg = TrainStepConfig(
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5)),
        compress_grads=args.compress_grads,
        param_dtype=cfg.dtype)
    rules = shd.train_rules(mesh, cfg)
    p_sh = shd.param_shardings(model, mesh, rules)
    state_specs = train_state_specs(model, step_cfg)
    state_sh = dict(params=p_sh, opt=dict(master=p_sh, mu=p_sh, nu=p_sh),
                    step=shd.replicated(mesh, state_specs["step"]))
    if step_cfg.compress_grads:
        state_sh["ef"] = p_sh
    train_step = jax.jit(make_train_step(model, step_cfg),
                         in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
    return cfg, model, mesh, step_cfg, state_sh, train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, model, mesh, step_cfg, state_sh, train_step = build(args)
    print(f"[train] arch={cfg.name} params={model.param_count():,} "
          f"mesh={dict(mesh.shape)}")

    start_step = 0
    state = None
    checkpointer = None
    if args.ckpt_dir:
        checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            specs = train_state_specs(model, step_cfg)
            state, start_step = ckpt.restore(specs, args.ckpt_dir,
                                             shardings=state_sh)
            print(f"[train] restored step {start_step} from {args.ckpt_dir}")
    if state is None:
        state = init_train_state(model, jax.random.PRNGKey(args.seed),
                                 step_cfg)
        state = jax.device_put(state, state_sh)

    ds = SyntheticDataset(cfg, args.batch, args.seq, seed=args.seed + 1)
    it = PrefetchIterator(ds, start_step=start_step)
    monitor = fault.StragglerMonitor()
    heartbeat = (fault.Heartbeat(os.path.join(args.ckpt_dir, "heartbeat"))
                 if args.ckpt_dir else None)

    losses = []
    completed = False
    try:
        for _ in range(start_step, args.steps):
            step_no, batch = next(it)
            monitor.start()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if monitor.stop():
                print(f"[train] straggler at step {step_no} "
                      f"(median {monitor.median_s*1e3:.0f} ms)")
            if heartbeat:
                heartbeat.beat(step_no)
            if checkpointer and (step_no + 1) % args.ckpt_every == 0:
                checkpointer.save(state, step_no + 1)
            if step_no % args.log_every == 0 or step_no == args.steps - 1:
                print(f"[train] step {step_no:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")
        completed = True
    finally:
        it.close()
        if checkpointer:
            if completed:
                # Final checkpoint only on clean completion -- a crash must
                # leave the last *good* checkpoint as the restore point.
                checkpointer.save(state, args.steps)
            checkpointer.close()

    if losses:
        print(f"[train] done: first loss {losses[0]:.4f} -> "
              f"last {losses[-1]:.4f}")
    else:
        print("[train] nothing to do (already at target step)")
    return losses


if __name__ == "__main__":
    main()
