"""Serving launcher: batched prefill + greedy decode with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Demonstrates the serving substrate: cache construction, batched prefill,
the decode hot loop (the function the decode dry-run cells lower at
production shapes), and per-phase timing including the channelized-KV
sharding when the mesh has a model axis.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticDataset
from repro.launch.mesh import make_host_mesh
from repro.models.config import smoke_variant
from repro.models.model import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] arch={cfg.name} params={model.param_count():,}")

    ds = SyntheticDataset(cfg, args.batch, args.prompt_len,
                          seed=args.seed + 1)
    batch = ds.batch_at(0)
    prompt = {k: v for k, v in batch.items()
              if k not in ("targets", "loss_mask")}

    cache = model.make_cache(args.batch, args.prompt_len + args.gen)
    prefill = jax.jit(model.prefill)
    t0 = time.time()
    logits, cache = prefill(params, prompt, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    generate = jax.jit(model.greedy_generate, static_argnames=("steps",))
    t0 = time.time()
    toks, cache = generate(params, prompt, model.make_cache(
        args.batch, args.prompt_len + args.gen), steps=args.gen)
    toks = np.asarray(jax.block_until_ready(toks))
    t_gen = time.time() - t0

    tok_s = args.batch * args.gen / max(t_gen, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} tokens: "
          f"{t_prefill*1e3:.1f} ms")
    print(f"[serve] decode {args.gen} steps: {t_gen*1e3:.1f} ms "
          f"({tok_s:.1f} tok/s, batch {args.batch})")
    print(f"[serve] sample continuation (batch 0): {toks[0][:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
