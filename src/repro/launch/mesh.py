"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state -- jax locks the device count on first use,
and only dryrun.py is allowed to fake 512 host devices.

Mesh shapes:
  single pod:  (16, 16)    axes ("data", "model")  -- 256 chips
  multi pod:   (2, 16, 16) axes ("pod", "data", "model") -- 512 chips

``data`` (x ``pod``) carries batch/FSDP; ``model`` carries TP/EP and the
channelized KV-sequence sharding.  The ``pod`` axis only ever appears in
batch/FSDP shardings, so cross-pod traffic is gradient reduce-scatters and
parameter all-gathers -- the collectives that tolerate the higher cross-pod
latency (same trade the paper makes: bandwidth-parallel channels behind a
latency premium).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """A mesh over whatever devices actually exist (CPU tests/examples)."""
    n = len(jax.devices())
    if n % model_axis:
        model_axis = 1
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
