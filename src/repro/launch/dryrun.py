import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run may fake 512 host devices (smoke tests and
benches see the real single device).

Per cell this script:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod);
  2. builds ShapeDtypeStruct stand-ins for every input (no allocation);
  3. jit-lowers the train_step (train/prefill shapes) or serve_step
     (decode shapes) with the full sharding rules;
  4. ``.compile()``s it -- sharding mismatches, unsupported collectives or
     partitioning bugs fail HERE, which is the point;
  5. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the compiled HLO into results/dryrun/<cell>.json for the
     roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k \
      [--multi-pod] [--kv-channels N] [--remat dots]
  python -m repro.launch.dryrun --all [--multi-pod]   # every cell, in-proc
"""

import argparse
import contextlib
import dataclasses
import json
import re
import sys
import time

_nullcontext = contextlib.nullcontext

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_status, get_config, get_shape
from repro.core import hloparse
from repro.distributed import context
from repro.distributed import sharding as shd
from repro.distributed.step import (TrainStepConfig, make_serve_step,
                                    make_train_step, train_state_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model, batch_spec, decode_batch_spec

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        size = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                size *= int(d)
        out[op] += size
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def input_specs(arch: str, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins + shardings for one cell's inputs."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = Model(cfg)
    n_data = shd.axis_size(mesh, shd.fsdp_axes(mesh))

    if shape.kind in ("train", "prefill"):
        batch = batch_spec(cfg, shape.global_batch, shape.seq_len)
        return dict(kind="train", batch=batch)
    # decode: one new token against a seq_len cache
    step_batch = decode_batch_spec(cfg, shape.global_batch)
    cache = jax.eval_shape(
        lambda: model.make_cache(shape.global_batch, shape.seq_len))
    return dict(kind="decode", batch=step_batch, cache=cache)


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str
    seconds: float = 0.0
    flops_per_chip: float = 0.0       # loop-scaled, from hloparse
    bytes_per_chip: float = 0.0       # loop-scaled op-boundary proxy
    hbm_bytes_per_chip: float = 0.0   # loop-scaled fused-boundary proxy
    xla_flops: float = 0.0            # raw cost_analysis (loop bodies x1)
    xla_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    memory: dict = dataclasses.field(default_factory=dict)
    chips: int = 0
    error: str = ""
    variant: str = "baseline"

    def to_json(self):
        return dataclasses.asdict(self)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             remat: str | None = None, kv_channels: bool = True,
             compress_grads: bool = False, act_shard: str = "none",
             fsdp_gather: bool = False, microbatch: int = 1,
             kv_select_update: bool = False,
             variant: str = "baseline") -> CellResult:
    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    status = cell_status(cfg, shape)
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name,
                     status=status, variant=variant)
    if status != "ok":
        return res

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    res.chips = mesh.size
    model = Model(cfg)

    from repro.distributed.sharding import fsdp_axes
    act_rules = {"batch": fsdp_axes(mesh)}
    if act_shard == "seq":
        act_rules["seq"] = "model"
    if fsdp_gather:
        act_rules["fsdp_gather"] = True
    if kv_select_update:
        act_rules["kv_select_update"] = True
        act_rules["kv_partials"] = True
        act_rules["kv_seq"] = "model"
    ctx = (context.activation_rules(mesh, act_rules)
           if (act_shard != "none" or fsdp_gather or kv_select_update)
           else _nullcontext())
    try:
      with ctx:
        if shape.kind in ("train", "prefill"):
            rules = shd.train_rules(mesh, cfg)
            step_cfg = TrainStepConfig(compress_grads=compress_grads,
                                       microbatch=microbatch)
            state_specs = train_state_specs(model, step_cfg)
            p_sh = shd.param_shardings(model, mesh, rules)
            state_sh = dict(
                params=p_sh,
                opt=dict(master=p_sh, mu=p_sh, nu=p_sh),
                step=shd.replicated(mesh, state_specs["step"]))
            if compress_grads:
                state_sh["ef"] = p_sh
            batch = batch_spec(cfg, shape.global_batch, shape.seq_len)
            b_sh = shd.batch_shardings(mesh, batch)
            fn = make_train_step(model, step_cfg)
            lowered = jax.jit(
                fn, in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,)).lower(state_specs, batch)
        else:
            rules = shd.decode_rules(mesh, cfg)
            p_sh = shd.param_shardings(model, mesh, rules)
            params_specs = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            step_batch = decode_batch_spec(cfg, shape.global_batch)
            cache = jax.eval_shape(
                lambda: model.make_cache(shape.global_batch, shape.seq_len))
            b_sh = shd.batch_shardings(mesh, step_batch)
            c_sh = shd.cache_shardings(cfg, mesh, cache,
                                       kv_channels=kv_channels)
            fn = make_serve_step(model)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,)).lower(
                    params_specs, step_batch, cache)
        compiled = lowered.compile()
        res.seconds = time.time() - t0

        ca = compiled.cost_analysis() or {}
        # cost_analysis is per-device for SPMD modules -- but counts while
        # bodies once; hloparse re-derives loop-scaled totals.
        res.xla_flops = float(ca.get("flops", 0.0))
        res.xla_bytes = float(ca.get("bytes accessed", 0.0))
        hlo_text = compiled.as_text()
        cost = hloparse.analyze(hlo_text)
        res.flops_per_chip = float(cost.flops)
        res.bytes_per_chip = float(cost.bytes)
        res.hbm_bytes_per_chip = float(cost.bytes_hbm)
        try:
            ma = compiled.memory_analysis()
            res.memory = dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
            )
        except Exception as e:      # pragma: no cover
            res.memory = dict(error=str(e))
        res.collectives = dict(cost.coll, total=cost.coll_total,
                               unscaled=collective_bytes(hlo_text))
    except Exception as e:          # noqa: BLE001 -- record, don't crash --all
        res.status = "error"
        res.error = f"{type(e).__name__}: {e}"[:2000]
        res.seconds = time.time() - t0
    return res


def result_path(res: CellResult) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{res.arch}__{res.shape}__{res.mesh}__{res.variant}.json"
    return os.path.join(RESULTS_DIR, name)


def collective_proof(multi_pod: bool = False) -> dict:
    """H4': compile-level proof that the shard_map int8 reducer moves ~4x
    fewer collective bytes than a plain f32 psum on the production mesh."""
    from repro.distributed import int8_collectives as i8

    mesh = make_production_mesh(multi_pod=multi_pod)
    grads = {
        "wq": jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
        "wi": jax.ShapeDtypeStruct((4096, 11008), jnp.float32),
        "head": jax.ShapeDtypeStruct((4096, 32000), jnp.float32),
    }
    out = {}
    for mode in ("f32", "int8"):
        reducer = i8.make_reducer(mesh, axis="data", int8=(mode == "int8"))
        compiled = jax.jit(reducer).lower(grads).compile()
        cost = hloparse.analyze(compiled.as_text())
        out[mode] = dict(collective_bytes=cost.coll_total,
                         by_op={k: v for k, v in cost.coll.items() if v})
    out["reduction_factor"] = (out["f32"]["collective_bytes"] /
                               max(out["int8"]["collective_bytes"], 1.0))
    # The byte meter counts an all-reduce output once, but a ring
    # all-reduce moves ~2x its size (reduce-scatter + all-gather); the
    # int8 path's a2a+ag is counted at its true wire volume.  So the
    # wire-level reduction is ~2x the metric ratio.
    out["wire_level_factor_estimate"] = 2.0 * out["reduction_factor"]
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "int8_proof.json"), "w") as f:
        json.dump(out, f, indent=2)
    print(f"[proof] f32 coll bytes/chip:  {out['f32']['collective_bytes']:.3e}")
    print(f"[proof] int8 coll bytes/chip: {out['int8']['collective_bytes']:.3e}")
    print(f"[proof] reduction: {out['reduction_factor']:.2f}x (metric) / "
          f"~{out['wire_level_factor_estimate']:.0f}x wire-level")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--no-kv-channels", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--act-shard", default="none", choices=["none", "seq"])
    ap.add_argument("--fsdp-gather", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--kv-select-update", action="store_true")
    ap.add_argument("--collective-proof", action="store_true")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args(argv)

    if args.collective_proof:
        collective_proof(multi_pod=args.multi_pod)
        return 0

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        res = run_cell(arch, shape, multi_pod=args.multi_pod,
                       remat=args.remat,
                       kv_channels=not args.no_kv_channels,
                       compress_grads=args.compress_grads,
                       act_shard=args.act_shard,
                       fsdp_gather=args.fsdp_gather,
                       microbatch=args.microbatch,
                       kv_select_update=args.kv_select_update,
                       variant=args.variant)
        with open(result_path(res), "w") as f:
            json.dump(res.to_json(), f, indent=2)
        tag = res.status if res.status != "ok" else (
            f"ok  {res.seconds:6.1f}s  flops/chip={res.flops_per_chip:.3e} "
            f"coll={res.collectives.get('total', 0):.3e}B "
            f"temp={res.memory.get('temp_bytes', 0)/2**30:.2f}GiB")
        print(f"[dryrun] {arch:22s} {shape:12s} {res.mesh:8s} {tag}",
              flush=True)
        if res.status == "error":
            failures += 1
            print("         " + res.error.splitlines()[0][:160], flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
