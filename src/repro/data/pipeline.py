"""Deterministic synthetic data pipeline with background prefetch.

Generates LM token streams (or modality-stub frame/vision batches) from a
seeded threefry stream -- fully reproducible across restarts (the batch for
step N is a pure function of (seed, N), which is what makes checkpoint/
restart exactly resumable without data-state snapshots) -- and overlaps host
batch construction with device compute via a double-buffered prefetch
thread, the standard input-pipeline optimization.
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import FRONTEND_DIM


class SyntheticDataset:
    """Pure-function batches: batch(step) is reproducible by construction."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 1234):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        b, s = self.batch, self.seq
        out = {}
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (b, s, FRONTEND_DIM)).astype(np.float32)
            out["targets"] = rng.integers(0, cfg.vocab, (b, s),
                                          dtype=np.int32)
            # HuBERT-style masked prediction: ~8% mask starts, span 10.
            mask = rng.random((b, s)) < 0.08
            out["loss_mask"] = np.asarray(mask, np.int32)
        else:
            # Markov-ish token stream: correlated tokens so the loss is
            # learnable (quickstart demonstrates loss decreasing).
            base = rng.integers(0, cfg.vocab, (b, s + 1), dtype=np.int32)
            repeat = rng.random((b, s + 1)) < 0.5
            tokens = base.copy()
            for t in range(1, s + 1):
                tokens[:, t] = np.where(repeat[:, t], tokens[:, t - 1],
                                        base[:, t])
            out["tokens"] = tokens[:, :-1]
            out["targets"] = tokens[:, 1:].astype(np.int32)
            out["loss_mask"] = np.ones((b, s), np.int32)
        if cfg.mrope_sections:
            pos = np.arange(s, dtype=np.int32)[None, :, None]
            out["positions"] = np.broadcast_to(pos, (b, s, 3)).copy()
        else:
            out["positions"] = np.broadcast_to(
                np.arange(s, dtype=np.int32)[None], (b, s)).copy()
        if cfg.family == "vlm":
            out["vision_embeds"] = rng.standard_normal(
                (b, s, FRONTEND_DIM)).astype(np.float32)
            vm = np.zeros((b, s), bool)
            vm[:, : min(64, s // 4)] = True     # leading image tokens
            out["vision_mask"] = vm
        return out


class PrefetchIterator:
    """Builds batch(step+1) on a host thread while step runs on device."""

    def __init__(self, dataset: SyntheticDataset, start_step: int = 0,
                 depth: int = 2, sharding=None):
        self.dataset = dataset
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step)
            if self.sharding is not None:
                batch = jax.device_put(batch, self.sharding)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
