"""CLI for the gradient-based designer: ``python -m repro.designer``.

Optimizes a memory system under an area/pin budget and a p99 token-
latency SLO by projected gradient ascent through the differentiable
performance model (see :mod:`repro.core.designer`), then re-verifies
the returned optimum with one direct event-driven DES run.

    python -m repro.designer --area-budget 1.2 --slo-ms 500

Exit status 0 when the returned design meets the budget and the SLO and
the DES re-verification agrees within the calibration tolerance; 1
otherwise (the design is still printed so the miss can be audited).
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.designer",
        description="Gradient-ascend a CXL memory-system design under an "
                    "area/pin budget and a p99 token-latency SLO.")
    p.add_argument("--area-budget", type=float, default=1.2,
                   help="max rel_area vs the DDR baseline (default 1.2)")
    p.add_argument("--pin-budget", type=float, default=None,
                   help="max rel_pins vs the DDR baseline (default: "
                        "unbounded)")
    p.add_argument("--slo-ms", type=float, default=500.0,
                   help="p99 token-latency SLO in ms; 0 disables the "
                        "constraint (default 500)")
    p.add_argument("--arch", default="stablelm-1.6b",
                   help="serving arch whose token p99 carries the SLO")
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--context", type=int, default=2048)
    p.add_argument("--iters", type=int, default=None,
                   help="max ascent iterations")
    p.add_argument("--lr", type=float, default=None, help="step size")
    p.add_argument("--steps", type=int, default=None,
                   help="DES steps for the LUT build and verification "
                        "(default: honors $REPRO_DES_STEPS)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--engine", default="event",
                   choices=("event", "timestep"),
                   help="DES engine for the LUT build (verification is "
                        "always event-driven)")
    p.add_argument("--cost", default="rel_area",
                   choices=("rel_area", "rel_pins"),
                   help="frontier cost axis for the knee start")
    p.add_argument("--trajectory", action="store_true",
                   help="print the per-iteration ascent trajectory")
    args = p.parse_args(argv)

    from repro.core import designer

    kwargs = dict(area_budget=args.area_budget,
                  pin_budget=args.pin_budget,
                  slo_ms=None if args.slo_ms <= 0 else args.slo_ms,
                  arch=args.arch, batch=args.batch, context=args.context,
                  cost=args.cost, steps=args.steps, seed=args.seed,
                  engine=args.engine)
    if args.iters is not None:
        kwargs["iters"] = args.iters
    if args.lr is not None:
        kwargs["lr"] = args.lr
    try:
        res = designer.optimize_design(**kwargs)
    except ValueError as e:
        print(f"designer: {e}", file=sys.stderr)
        return 1

    if args.trajectory:
        for t in res.trajectory:
            print(f"  it={t['iter']:3d} ch={t['dram_channels']:.3f} "
                  f"llc={t['llc_mb_per_core']:.3f} obj={t['objective']:.4f} "
                  f"gm={t['gm']:.4f} tok99={t['token_p99_s'] * 1e3:.2f}ms")
    print(res.summary())
    ok = res.meets_budget and res.meets_slo and res.verify["ok"]
    print(f"DESIGN {'OK' if ok else 'MISS'} "
          f"ch={float(res.design.dram_channels):.2f} "
          f"links={float(res.design.links):.2f} "
          f"llc={float(res.design.llc_mb_per_core):.2f}MB "
          f"area={res.rel_area:.3f} gm={res.gm_speedup:.3f}x")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
