"""Make `benchmarks` importable when pytest runs from the repo root."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# Give the test session multiple virtual host devices so the sharded DES
# path (repro.core.shardsim) is exercised for real, not just at ndev=1.
# Must run before jax initialises its backends; conftest import precedes
# every test module, so guard only against an already-imported jax.
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = \
            (_flags + " --xla_force_host_platform_device_count=4").strip()
